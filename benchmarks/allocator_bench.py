"""Online-allocator ILP assembly benchmark (paper §4.3 online stage).

Times model *construction* separately from the HiGHS *solve* for the
two assembly paths — the seed per-var reference
(``allocate_reference``: one Python ``add_var``/``add_constr`` call per
(region, template) pair) and the columnar ``AllocatorState`` (array
selection + one COO block) — at the core (12-config / 3-model) and
paper (20-config / 6-model) scales, and checks that both paths land on
the same objective within the MIP gap.  A second ``AllocatorState``
call with perturbed demand/availability measures the cross-epoch
re-solve, which reuses the assembled structure and warm-starts from the
incumbent.

Results go to ``artifacts/BENCH_allocator.json`` (tracked reference
points live in ``tools/bench_reference.json``; compare with
``python tools/check_bench.py`` or ``benchmarks/run.py --check``).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

# allow direct invocation (python benchmarks/allocator_bench.py)
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import (ART, Row, cached_library, make_avail,
                               make_demands, scenario)
from repro.core.allocator import (AllocProblem, AllocatorState,
                                  allocate_reference)

# the paper-scale library is served from the artifacts cache; n_max=4
# keeps a cold rebuild tolerable on this container while the ILP itself
# still sees the full 20-config x 6-model universe (the var-cap knob
# bounds templates per demand either way)
EXT_N_MAX = 4
GAP_TOL = 5e-4          # both solves run at gap=1e-4; allow both gaps
# container timing noise ~2x: assembly is timed over BUILD_REPS
# build-only passes (time_limit ~0 so HiGHS returns immediately and only
# build_seconds matters) and reported best-of; the objective check runs
# one full solve per path
BUILD_REPS = 5


def _problem(extended: bool):
    models, configs, regions, wls = scenario(extended)
    name = "ext" if extended else "core"
    lib = cached_library(name, models, configs, wls,
                         n_max=EXT_N_MAX if extended else None)
    rate = 25.0 if extended else 10.0
    abundance = 64 if extended else 40
    avail = make_avail(regions, configs, 2, abundance, seed=0)
    demands = make_demands(models, wls, rate)
    return models, configs, regions, lib, avail, demands, wls, rate


def _bench(extended: bool) -> dict:
    tag = "ext" if extended else "core"
    (models, configs, regions, lib, avail, demands, wls,
     rate) = _problem(extended)

    def prob(epoch=0, current=None, time_limit=120.0):
        return AllocProblem(regions, configs, dict(avail[epoch]), demands,
                            lib, current=dict(current or {}),
                            time_limit=time_limit)

    # full solves once per path: the objective equivalence check
    ref = allocate_reference(prob())
    state = AllocatorState()
    col = state(prob())
    ref_build, col_build, upd_build = (ref.build_seconds,
                                       col.build_seconds, np.inf)
    # build-only repetitions (best-of): assembly time without the solve
    for _ in range(BUILD_REPS):
        ref_build = min(ref_build, allocate_reference(
            prob(time_limit=1e-9)).build_seconds)
        col_build = min(col_build,
                        AllocatorState()(prob(time_limit=1e-9)).build_seconds)
        # cross-epoch re-solve: new availability, warm incumbent,
        # reused structure — no full rebuild
        upd_build = min(upd_build, state(
            prob(epoch=1, current=col.instances,
                 time_limit=1e-9)).build_seconds)
    rel = abs(ref.objective - col.objective) \
        / max(abs(ref.objective), 1e-9)
    out = {
        "scale": tag,
        "n_models": len(models), "n_configs": len(configs),
        "n_regions": len(regions), "n_vars": int(col.n_vars),
        "ref_build_s": ref_build, "ref_solve_s": ref.solve_seconds,
        "col_build_s": col_build, "col_solve_s": col.solve_seconds,
        "update_build_s": upd_build,
        "build_speedup": ref_build / max(col_build, 1e-9),
        "update_speedup": ref_build / max(upd_build, 1e-9),
        "objective_ref": ref.objective, "objective_col": col.objective,
        "objective_rel_diff": rel, "objective_ok": bool(rel <= GAP_TOL),
    }
    Row.add(f"allocator_build_{tag}", col_build * 1e6,
            f"vars={out['n_vars']};speedup={out['build_speedup']:.1f}x;"
            f"update={out['update_speedup']:.1f}x;obj_rel={rel:.1e}")
    return out


def run() -> None:
    results = [_bench(extended=False), _bench(extended=True)]
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_allocator.json"), "w") as f:
        json.dump({"gap": 1e-4, "results": results}, f, indent=1)
    for r in results:
        print(f"[{r['scale']}] {r['n_vars']} vars: "
              f"build {r['ref_build_s']:.3f}s -> {r['col_build_s']:.3f}s "
              f"({r['build_speedup']:.1f}x), epoch update "
              f"{r['update_build_s']*1e3:.1f}ms "
              f"({r['update_speedup']:.1f}x), solve {r['col_solve_s']:.2f}s, "
              f"obj rel diff {r['objective_rel_diff']:.2e}")
    assert all(r["objective_ok"] for r in results), \
        "columnar objective diverged from the per-var reference"


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_allocator.csv"))
