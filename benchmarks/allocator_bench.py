"""Online-allocator ILP assembly benchmark (paper §4.3 online stage).

Times model *construction* separately from the HiGHS *solve* for the
two assembly paths — the seed per-var reference
(``allocate_reference``: one Python ``add_var``/``add_constr`` call per
(region, template) pair) and the columnar ``AllocatorState`` (array
selection + one COO block) — at the core (12-config / 3-model) and
paper (20-config / 6-model) scales, and checks that both paths land on
the same objective within the MIP gap.  A second ``AllocatorState``
call with perturbed demand/availability measures the cross-epoch
re-solve, which reuses the assembled structure and warm-starts from the
incumbent.

Since the decomposition PR the online solve itself is three-tiered
(price-coordinated per-model decomposition -> LP-relax + greedy
rounding -> monolithic MIP, each tier certified against a valid lower
bound before it may answer).  Three further sections measure that
ladder:

* ``resolve_stream`` — an epoch stream at the extended scale, solved
  twice with identical inputs (``solve_mode="auto"`` vs forced
  ``"monolithic"``); reports warm re-solve p50/p95 wall times per
  mode, their ratios, per-epoch objective parity, and the tier each
  auto epoch landed on.
* ``escalation`` — tiers 2 and 3 forced on the same extended problem,
  checking each returns its own ``solve_path`` at objective parity
  (the escalation ladder is exercised, not just trusted).
* ``scenario_parity`` — two consecutive epochs of every named
  control-plane scenario (core scale), auto vs monolithic.

Results go to ``artifacts/BENCH_allocator.json`` (tracked reference
points live in ``tools/bench_reference.json``; compare with
``python tools/check_bench.py`` or ``benchmarks/run.py --check``).
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

# allow direct invocation (python benchmarks/allocator_bench.py)
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import (ART, Row, cached_library, make_avail,
                               make_demands, scenario)
from repro.control.scenarios import SCENARIO_NAMES, make_scenario
from repro.core.allocator import (AllocProblem, AllocatorState,
                                  allocate_reference)
# shared nearest-rank semantics (bit-identical to the local helper
# this replaced, so the pinned p50/p95 references are unchanged)
from repro.obs.percentiles import percentile as _percentile

# the paper-scale library is served from the artifacts cache; n_max=4
# keeps a cold rebuild tolerable on this container while the ILP itself
# still sees the full 20-config x 6-model universe (the var-cap knob
# bounds templates per demand either way)
EXT_N_MAX = 4
GAP_TOL = 5e-4          # both solves run at gap=1e-4; allow both gaps
# container timing noise ~2x: assembly is timed over BUILD_REPS
# build-only passes (time_limit ~0 so HiGHS returns immediately and only
# build_seconds matters) and reported best-of; the objective check runs
# one full solve per path
BUILD_REPS = 5
# auto (certified within ACCEPT_GAP=5e-4 of a lower bound) vs
# monolithic (MIP_GAP=1e-4) can legitimately differ by the sum of both
# gaps; in practice the measured stream diff is ~1e-15
PARITY_TOL = 2e-3
STREAM_EPOCHS = 6       # warm re-solves measured over epochs 1..N-1


def _problem(extended: bool):
    models, configs, regions, wls = scenario(extended)
    name = "ext" if extended else "core"
    lib = cached_library(name, models, configs, wls,
                         n_max=EXT_N_MAX if extended else None)
    rate = 25.0 if extended else 10.0
    abundance = 64 if extended else 40
    avail = make_avail(regions, configs, 2, abundance, seed=0)
    demands = make_demands(models, wls, rate)
    return models, configs, regions, lib, avail, demands, wls, rate


def _bench(extended: bool) -> dict:
    tag = "ext" if extended else "core"
    (models, configs, regions, lib, avail, demands, wls,
     rate) = _problem(extended)

    # assembly metrics are monolithic-path by construction: the section
    # times the full-model COO build against the per-var reference, so
    # the fast tiers (which skip that assembly entirely) must not run
    def prob(epoch=0, current=None, time_limit=120.0):
        return AllocProblem(regions, configs, dict(avail[epoch]), demands,
                            lib, current=dict(current or {}),
                            time_limit=time_limit,
                            solve_mode="monolithic")

    # full solves once per path: the objective equivalence check
    ref = allocate_reference(prob())
    state = AllocatorState()
    col = state(prob())
    ref_build, col_build, upd_build = (ref.build_seconds,
                                       col.build_seconds, np.inf)
    # build-only repetitions (best-of): assembly time without the solve
    for _ in range(BUILD_REPS):
        ref_build = min(ref_build, allocate_reference(
            prob(time_limit=1e-9)).build_seconds)
        col_build = min(col_build,
                        AllocatorState()(prob(time_limit=1e-9)).build_seconds)
        # cross-epoch re-solve: new availability, warm incumbent,
        # reused structure — no full rebuild
        upd_build = min(upd_build, state(
            prob(epoch=1, current=col.instances,
                 time_limit=1e-9)).build_seconds)
    rel = abs(ref.objective - col.objective) \
        / max(abs(ref.objective), 1e-9)
    out = {
        "scale": tag,
        "n_models": len(models), "n_configs": len(configs),
        "n_regions": len(regions), "n_vars": int(col.n_vars),
        "ref_build_s": ref_build, "ref_solve_s": ref.solve_seconds,
        "col_build_s": col_build, "col_solve_s": col.solve_seconds,
        "update_build_s": upd_build,
        "build_speedup": ref_build / max(col_build, 1e-9),
        "update_speedup": ref_build / max(upd_build, 1e-9),
        "objective_ref": ref.objective, "objective_col": col.objective,
        "objective_rel_diff": rel, "objective_ok": bool(rel <= GAP_TOL),
    }
    Row.add(f"allocator_build_{tag}", col_build * 1e6,
            f"vars={out['n_vars']};speedup={out['build_speedup']:.1f}x;"
            f"update={out['update_speedup']:.1f}x;obj_rel={rel:.1e}")
    return out


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-9)


def _bench_resolve_stream() -> dict:
    """Warm online re-solves over an extended-scale epoch stream:
    auto (three-tier) vs forced monolithic on identical inputs."""
    (models, configs, regions, lib, avail, demands, wls,
     rate) = _problem(extended=True)
    avail = make_avail(regions, configs, STREAM_EPOCHS, 64, seed=7)
    rng = np.random.default_rng(11)
    # per-epoch demand jitter so every re-solve sees moved RHS values;
    # drawn ONCE so both modes solve the identical epoch problems
    epoch_demands = [
        [type(d)(d.model, d.phase,
                 d.tokens_per_s * (0.8 + 0.4 * rng.random()))
         for d in demands]
        for _ in range(STREAM_EPOCHS)]
    streams = [[AllocProblem(
        regions, configs, dict(avail[e]), epoch_demands[e],
        lib, time_limit=120.0, solve_mode=mode)
        for e in range(STREAM_EPOCHS)]
        for mode in ("auto", "monolithic")]

    times = {"auto": [], "monolithic": []}
    paths = []
    objs = {"auto": [], "monolithic": []}
    currents = [{}]         # the shared warm-start input trajectory
    n_vars = 0
    # the monolithic stream runs first and defines the per-epoch
    # ``current`` inputs for BOTH modes: degenerate optima mean the two
    # modes would otherwise hold different instances at equal cost,
    # making later epochs (whose init penalties depend on ``current``)
    # genuinely different problems — parity would be meaningless
    for mode in ("monolithic", "auto"):
        st = AllocatorState()
        probs = streams[0 if mode == "auto" else 1]
        for e, p in enumerate(probs):
            p.current = dict(currents[e])
            a = st(p)
            assert a.ok, f"{mode} stream epoch {e} failed"
            if mode == "monolithic":
                currents.append(a.instances)
            objs[mode].append(a.objective)
            n_vars = max(n_vars, int(a.n_vars))
            if mode == "auto":
                paths.append(a.solve_path)
            if e > 0:               # epoch 0 is the cold build
                times[mode].append(a.solve_seconds)
    parity = [_rel(za, zm) for za, zm
              in zip(objs["auto"], objs["monolithic"])]
    auto_p50 = _percentile(times["auto"], 0.50)
    auto_p95 = _percentile(times["auto"], 0.95)
    mono_p50 = _percentile(times["monolithic"], 0.50)
    mono_p95 = _percentile(times["monolithic"], 0.95)
    out = {
        "n_epochs": STREAM_EPOCHS,
        "n_vars": n_vars,
        "auto_p50_s": auto_p50, "auto_p95_s": auto_p95,
        "mono_p50_s": mono_p50, "mono_p95_s": mono_p95,
        "resolve_speedup_p50": mono_p50 / max(auto_p50, 1e-9),
        "resolve_speedup_p95": mono_p95 / max(auto_p95, 1e-9),
        "resolve_sub_s": bool(auto_p50 < 1.0),
        "paths": paths,
        "n_escalated": sum(1 for pth in paths if pth != "decomposed"),
        "max_parity_rel_diff": max(parity),
        "parity_ok": bool(max(parity) <= PARITY_TOL),
    }
    Row.add("allocator_resolve_ext", auto_p50 * 1e6,
            f"p95={auto_p95*1e3:.0f}ms;mono_p50={mono_p50*1e3:.0f}ms;"
            f"speedup={out['resolve_speedup_p50']:.1f}x;"
            f"paths={'/'.join(paths)}")
    return out


def _bench_escalation() -> dict:
    """Force tiers 2 and 3 on the extended problem: each must answer on
    its own ``solve_path`` at objective parity with the monolithic
    optimum — proving the ladder's upper rungs work, not just that the
    first rung never needed them."""
    (models, configs, regions, lib, avail, demands, wls,
     rate) = _problem(extended=True)

    def prob(mode):
        return AllocProblem(regions, configs, dict(avail[0]), demands,
                            lib, time_limit=120.0, solve_mode=mode)

    mono = AllocatorState()(prob("monolithic"))
    tiers = {}
    for mode in ("decomposed", "rounded_lp", "monolithic"):
        a = AllocatorState()(prob(mode))
        tiers[mode] = {
            "ok": a.ok, "path": a.solve_path,
            "solve_s": a.solve_seconds,
            "rel_diff": _rel(a.objective, mono.objective),
            "objective": a.objective,
        }
    # a *forced* rounded_lp answers even when it could not certify (in
    # auto mode it would escalate instead — the resolve_stream section
    # counts exactly those escalations); required of it here is only a
    # genuine feasible upper bound on its own solve_path.  The
    # certifying tiers must hit parity with the monolithic optimum.
    exercised = all(t["ok"] and t["path"] == mode
                    for mode, t in tiers.items()) \
        and tiers["decomposed"]["rel_diff"] <= PARITY_TOL \
        and tiers["monolithic"]["rel_diff"] <= PARITY_TOL \
        and tiers["rounded_lp"]["objective"] \
        >= mono.objective * (1.0 - 1e-9)
    for mode, t in tiers.items():
        Row.add(f"allocator_tier_{mode}", t["solve_s"] * 1e6,
                f"path={t['path']};rel={t['rel_diff']:.1e}")
    return {"tiers": tiers, "escalation_ok": bool(exercised)}


def _bench_scenario_parity() -> list:
    """Auto vs monolithic on two consecutive epochs (cold + warm) of
    every named control-plane scenario at the core scale."""
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    out = []
    for name in SCENARIO_NAMES:
        sc = make_scenario(name, models, regions, configs, wls, seed=0)
        e0 = sc.n_epochs // 2           # mid-run: schedules have moved
        res = {}
        currents = [{}]                 # shared input trajectory (see
        for mode in ("monolithic", "auto"):     # _bench_resolve_stream)
            st = AllocatorState()
            allocs = []
            for i, e in enumerate((e0, e0 + 1)):
                p = AllocProblem(regions, configs,
                                 dict(sc.availability[e]),
                                 sc.truth_demands[e], lib,
                                 current=dict(currents[i]),
                                 time_limit=120.0, solve_mode=mode)
                a = st(p)
                assert a.ok, f"{name}/{mode} epoch {e} failed"
                if mode == "monolithic":
                    currents.append(a.instances)
                allocs.append(a)
            res[mode] = allocs
        rel = max(_rel(a.objective, m.objective)
                  for a, m in zip(res["auto"], res["monolithic"]))
        row = {
            "scenario": name,
            "paths": [a.solve_path for a in res["auto"]],
            "auto_warm_s": res["auto"][1].solve_seconds,
            "mono_warm_s": res["monolithic"][1].solve_seconds,
            "rel_diff": rel,
            "parity_ok": bool(rel <= PARITY_TOL),
        }
        Row.add(f"allocator_parity_{name}",
                res["auto"][1].solve_seconds * 1e6,
                f"rel={rel:.1e};paths={'/'.join(row['paths'])}")
        out.append(row)
    return out


def run() -> None:
    results = [_bench(extended=False), _bench(extended=True)]
    stream = _bench_resolve_stream()
    escalation = _bench_escalation()
    parity = _bench_scenario_parity()
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_allocator.json"), "w") as f:
        json.dump({"gap": 1e-4, "results": results,
                   "resolve_stream": stream, "escalation": escalation,
                   "scenario_parity": parity}, f, indent=1)
    for r in results:
        print(f"[{r['scale']}] {r['n_vars']} vars: "
              f"build {r['ref_build_s']:.3f}s -> {r['col_build_s']:.3f}s "
              f"({r['build_speedup']:.1f}x), epoch update "
              f"{r['update_build_s']*1e3:.1f}ms "
              f"({r['update_speedup']:.1f}x), solve {r['col_solve_s']:.2f}s, "
              f"obj rel diff {r['objective_rel_diff']:.2e}")
    print(f"[resolve-stream ext] {stream['n_vars']} vars: warm re-solve "
          f"auto p50 {stream['auto_p50_s']*1e3:.0f}ms / "
          f"p95 {stream['auto_p95_s']*1e3:.0f}ms vs monolithic "
          f"p50 {stream['mono_p50_s']*1e3:.0f}ms "
          f"({stream['resolve_speedup_p50']:.1f}x), paths "
          f"{'/'.join(stream['paths'])}, "
          f"max parity diff {stream['max_parity_rel_diff']:.2e}")
    for mode, t in escalation["tiers"].items():
        print(f"[tier {mode}] {t['solve_s']:.3f}s path={t['path']} "
              f"rel diff {t['rel_diff']:.2e}")
    for r in parity:
        print(f"[{r['scenario']}] auto warm {r['auto_warm_s']*1e3:.0f}ms "
              f"vs mono {r['mono_warm_s']*1e3:.0f}ms, "
              f"rel diff {r['rel_diff']:.2e}, "
              f"paths {'/'.join(r['paths'])}")
    assert all(r["objective_ok"] for r in results), \
        "columnar objective diverged from the per-var reference"
    # PR acceptance: sub-second warm re-solves at the extended scale,
    # every tier answering at parity with the monolithic optimum
    assert stream["resolve_sub_s"], \
        f"auto p50 re-solve {stream['auto_p50_s']:.2f}s >= 1s"
    assert stream["parity_ok"], "auto stream diverged from monolithic"
    assert escalation["escalation_ok"], \
        "a forced tier failed or broke objective parity"
    assert all(r["parity_ok"] for r in parity), \
        "a control scenario diverged from the monolithic optimum"


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_allocator.csv"))
