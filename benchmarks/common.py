"""Shared benchmark scenarios (paper §6.1 experiment setup).

Core setup: 3 models (qwen3-32b, gpt-oss-20b, phi4-14b) x 12 configs
(L40S/L4/A10G x 1/2/4/8) x 2 regions.
Extended setup: +3 models (qwen3-235b, gpt-oss-120b, llama3-70b),
+8 configs (H100/A100 x 1/2/4/8), +1 region.

Libraries are cached on disk: the offline Serving Template generation is
a one-time cost per setup (paper §4.2).
"""
from __future__ import annotations

import glob
import os
import pickle
import time
from typing import Dict, List, Tuple

from repro.core.allocator import AllocatorState, Demand
from repro.core.baselines import homo_library
from repro.core.hardware import (CORE_CONFIGS, CORE_REGIONS, EXT_CONFIGS,
                                 EXT_REGIONS)
from repro.core.modelspec import CORE_MODELS, EXT_MODELS, PAPER_MODELS
from repro.core.templates import build_library
from repro.traces.workloads import (default_base_availability,
                                    gen_availability, gen_requests,
                                    workload_stats)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
FAST = os.environ.get("BENCH_FAST", "1") != "0"

# template-generation caps. The memoized/vectorized PlacementCache path
# (repro.core.placement, ~35x) retired the old BENCH_FAST trim of
# (n_max=4, rho=8) for the core 12-config setup, which now always runs
# the paper defaults (6, 12). The extended 20-config setup enumerates
# 1.48M combos at n_max=6 (~500 combos/s on this 1-core container ->
# ~40 min), so FAST caps it at n_max=5 (~370k combos, ~5 min one-time,
# cached; the seed FAST used n_max=4 AND rho=8) and BENCH_FAST=0 runs
# the full paper default.
N_MAX = 6
N_MAX_EXT_FAST = 5
RHO = 12.0


def n_max_for(configs) -> int:
    """Scenario-aware template-generation cap (see note above)."""
    return N_MAX_EXT_FAST if (FAST and len(configs) > 12) else N_MAX


def scenario(extended: bool = False):
    models = {m: PAPER_MODELS[m]
              for m in (EXT_MODELS if extended else CORE_MODELS)}
    configs = EXT_CONFIGS if extended else CORE_CONFIGS
    regions = EXT_REGIONS if extended else CORE_REGIONS
    wls = {m: workload_stats(models[m].trace) for m in models}
    return models, configs, regions, wls


def cached_library(name: str, models, configs, wls, homo: bool = False,
                   n_max: int = None, rho: float = None):
    n_max = n_max or n_max_for(configs)
    rho = rho or RHO
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"lib_{name}_{'homo' if homo else 'coral'}"
                             f"_{n_max}_{rho}.pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    t0 = time.time()
    if homo:
        lib = homo_library(list(models.values()), configs, wls,
                           n_max=n_max, rho=rho)
    else:
        # incremental rebuild: seed from the newest cached Coral library
        # with matching (n_max, rho) — other caps are guaranteed
        # fingerprint misses; (model, phase) pairs whose generation
        # fingerprint (config universe, n_max, rho, SLO, workload) is
        # unchanged are reused
        reuse = None
        pat = os.path.join(ART, f"lib_*_coral_{n_max}_{rho}.pkl")
        for cand in sorted(glob.glob(pat),
                           key=os.path.getmtime, reverse=True):
            try:
                with open(cand, "rb") as f:
                    reuse = pickle.load(f)
                break
            except Exception:                           # noqa: BLE001
                continue
        lib = build_library(list(models.values()), configs, wls,
                            n_max=n_max, rho=rho, reuse=reuse)
    lib.build_seconds = time.time() - t0
    with open(path, "wb") as f:
        pickle.dump(lib, f)
    return lib


def coral_allocator() -> AllocatorState:
    """A fresh persistent columnar allocator for one epoch-loop run.

    ``AllocatorState`` is callable as an ``AllocatorFn`` and keeps the
    assembled ILP structure (plus the incumbent warm-start) across the
    run's epoch re-solves; use one instance per ``ClusterRuntime``.
    """
    return AllocatorState()


def make_demands(models, wls, rate: float, skew: Dict[str, float] = None):
    """Per-(model, phase) token demand from arrival rate req/s."""
    skew = skew or {}
    out = []
    for m in models:
        r = rate * skew.get(m, 1.0)
        wl = wls[m]
        out.append(Demand(m, "prefill", r * wl.avg_prompt))
        out.append(Demand(m, "decode", r * wl.avg_output))
    return out


def make_requests(models, rate: float, duration: float, seed: int = 0,
                  skew: Dict[str, float] = None):
    skew = skew or {}
    reqs = []
    for i, m in enumerate(sorted(models)):
        r = rate * skew.get(m, 1.0)
        if r <= 0:
            continue
        reqs += gen_requests(m, models[m].trace, r, duration,
                             seed=seed * 101 + i, rid0=i * 10_000_000)
    reqs.sort(key=lambda x: x.arrival)
    return reqs


def make_avail(regions, configs, n_epochs, abundance, seed=0, scarcity=None):
    base = default_base_availability(configs, abundance=abundance)
    return gen_availability(regions, configs, n_epochs, base, seed=seed,
                            scarcity=scarcity)


class Row:
    """CSV rows in the required ``name,us_per_call,derived`` format."""
    rows: List[Tuple[str, float, str]] = []

    @classmethod
    def add(cls, name: str, us_per_call: float, derived: str):
        cls.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")

    @classmethod
    def flush(cls, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in cls.rows:
                f.write(f"{n},{u:.1f},{d}\n")
