"""Shared benchmark scenarios (paper §6.1 experiment setup).

Core setup: 3 models (qwen3-32b, gpt-oss-20b, phi4-14b) x 12 configs
(L40S/L4/A10G x 1/2/4/8) x 2 regions.
Extended setup: +3 models (qwen3-235b, gpt-oss-120b, llama3-70b),
+8 configs (H100/A100 x 1/2/4/8), +1 region.

Libraries are cached on disk: the offline Serving Template generation is
a one-time cost per setup (paper §4.2).
"""
from __future__ import annotations

import glob
import os
import pickle
import time
from typing import Dict, List, Tuple

from repro.core.allocator import AllocatorState, Demand
from repro.core.baselines import homo_library
from repro.core.hardware import (CORE_CONFIGS, CORE_REGIONS, EXT_CONFIGS,
                                 EXT_REGIONS)
from repro.core.modelspec import CORE_MODELS, EXT_MODELS, PAPER_MODELS
from repro.core.templates import (TemplateLibrary, build_library,
                                  generation_fingerprint)
from repro.traces.workloads import (default_base_availability,
                                    gen_availability, gen_requests,
                                    workload_stats)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
FAST = os.environ.get("BENCH_FAST", "1") != "0"

# template-generation caps. The memoized/vectorized PlacementCache path
# (repro.core.placement, ~35x, PR 1) retired the old BENCH_FAST trim of
# (n_max=4, rho=8) for the core 12-config setup, and the level-wise
# dominance-pruned frontier (repro.core.templates._frontier_generate,
# PR 4) retired the extended-setup n_max=5 cap: the full 20-config
# extended library at the paper defaults (n_max=6, rho=12) now builds
# in single-digit minutes on this 1-core container (one-time, cached),
# so every scenario always runs the paper parameters.
N_MAX = 6
RHO = 12.0


def n_max_for(configs) -> int:
    """Template-generation cap — the paper default for every scenario
    since the PR-4 frontier (see note above)."""
    return N_MAX


def scenario(extended: bool = False):
    models = {m: PAPER_MODELS[m]
              for m in (EXT_MODELS if extended else CORE_MODELS)}
    configs = EXT_CONFIGS if extended else CORE_CONFIGS
    regions = EXT_REGIONS if extended else CORE_REGIONS
    wls = {m: workload_stats(models[m].trace) for m in models}
    return models, configs, regions, wls


def _homo_fingerprint(models, configs, wls, n_max, rho):
    """Everything a homo_library build depends on: one per-config
    generation fingerprint per (model, phase) — mirrors
    tests/_libcache.py so stale pickles never survive a generation
    change (n_max/rho/SLO/workload drift or a GENERATION_VERSION bump)."""
    return tuple(
        generation_fingerprint(m, phase, [c], wls[m.name], n_max, rho,
                               True, "fast", None)
        for m in models for phase in ("prefill", "decode")
        for c in sorted(configs, key=lambda c: c.name))


def cached_library(name: str, models, configs, wls, homo: bool = False,
                   n_max: int = None, rho: float = None):
    """Disk-cached Serving-Template library, fingerprint-checked.

    A cached pickle is only served when every (model, phase) pair's
    generation fingerprint still matches; otherwise the affected pairs
    are regenerated (Coral libraries incrementally via
    ``build_library(reuse=...)``, homogeneous ones wholesale) and the
    pickle is rewritten.
    """
    n_max = n_max or n_max_for(configs)
    rho = rho or RHO
    os.makedirs(ART, exist_ok=True)
    path = os.path.join(ART, f"lib_{name}_{'homo' if homo else 'coral'}"
                             f"_{n_max}_{rho}.pkl")
    cached = None
    if os.path.exists(path):
        try:
            with open(path, "rb") as f:
                cached = pickle.load(f)
        except Exception:                               # noqa: BLE001
            cached = None
    t0 = time.time()
    if homo:
        fp = _homo_fingerprint(list(models.values()), configs, wls,
                               n_max, rho)
        if isinstance(cached, dict) and cached.get("fp") == fp:
            return cached["lib"]
        lib = homo_library(list(models.values()), configs, wls,
                           n_max=n_max, rho=rho)
        blob = {"fp": fp, "lib": lib}
    else:
        if not isinstance(cached, TemplateLibrary):
            cached = None
        reuse = cached
        if reuse is None:
            # cold start: seed from the newest cached Coral library
            # with matching (n_max, rho) — every reused (model, phase)
            # pair is still fingerprint-gated by build_library
            pat = os.path.join(ART, f"lib_*_coral_{n_max}_{rho}.pkl")
            for cand in sorted(glob.glob(pat),
                               key=os.path.getmtime, reverse=True):
                try:
                    with open(cand, "rb") as f:
                        reuse = pickle.load(f)
                    break
                except Exception:                       # noqa: BLE001
                    continue
        lib = build_library(list(models.values()), configs, wls,
                            n_max=n_max, rho=rho, reuse=reuse)
        if cached is not None and all(
                s.get("reused") for s in lib.stats.values()):
            return cached                   # unchanged: keep mtime
        blob = lib
    lib.build_seconds = time.time() - t0
    with open(path, "wb") as f:
        pickle.dump(blob, f)
    return lib


def coral_allocator() -> AllocatorState:
    """A fresh persistent columnar allocator for one epoch-loop run.

    ``AllocatorState`` is callable as an ``AllocatorFn`` and keeps the
    assembled ILP structure (plus the incumbent warm-start) across the
    run's epoch re-solves; use one instance per ``ClusterRuntime``.
    """
    return AllocatorState()


def make_demands(models, wls, rate: float, skew: Dict[str, float] = None):
    """Per-(model, phase) token demand from arrival rate req/s."""
    skew = skew or {}
    out = []
    for m in models:
        r = rate * skew.get(m, 1.0)
        wl = wls[m]
        out.append(Demand(m, "prefill", r * wl.avg_prompt))
        out.append(Demand(m, "decode", r * wl.avg_output))
    return out


def make_requests(models, rate: float, duration: float, seed: int = 0,
                  skew: Dict[str, float] = None):
    skew = skew or {}
    reqs = []
    for i, m in enumerate(sorted(models)):
        r = rate * skew.get(m, 1.0)
        if r <= 0:
            continue
        reqs += gen_requests(m, models[m].trace, r, duration,
                             seed=seed * 101 + i, rid0=i * 10_000_000)
    reqs.sort(key=lambda x: x.arrival)
    return reqs


def make_avail(regions, configs, n_epochs, abundance, seed=0, scarcity=None):
    base = default_base_availability(configs, abundance=abundance)
    return gen_availability(regions, configs, n_epochs, base, seed=seed,
                            scarcity=scarcity)


class Row:
    """CSV rows in the required ``name,us_per_call,derived`` format."""
    rows: List[Tuple[str, float, str]] = []

    @classmethod
    def add(cls, name: str, us_per_call: float, derived: str):
        cls.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}")

    @classmethod
    def flush(cls, path: str):
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write("name,us_per_call,derived\n")
            for n, u, d in cls.rows:
                f.write(f"{n},{u:.1f},{d}\n")
