"""Control-loop scenario benchmark (online stage, paper §5/§6.4).

Runs the *closed* loop — estimator-driven demands + churn-aware
re-solve triggers + transition-planned warm starts, no oracle inputs —
against two references on each named control-plane scenario
(repro.control.scenarios), over the core Serving-Template library:

* ``oracle``  — truth per-epoch demands, re-solve every epoch: the
  upper bound the paper's evaluation protocol assumes.
* ``static``  — one solve against the demand observed at deployment
  time (epoch 0 truth), never re-solved (reconcile still replaces
  failed capacity *within* the frozen target, capped by availability):
  what "provision once" buys.  Run-mean demand would be the wrong
  baseline — the mean already encodes the whole future trace (a flash
  crowd inflates it before the crowd arrives), which is exactly the
  oracle knowledge a static deployment lacks.

Reported per scenario (the tracked gate metrics are noise-robust
ratios, all higher-is-better):

* ``cost_parity``    = oracle cost / estimated cost — 1.0 means the
  closed loop is as cheap as the oracle; the acceptance envelope is
  >= 0.85 (within 15%).
* ``goodput_parity`` = estimated coverage / oracle coverage, where
  *coverage* is demand-weighted per-epoch goodput
  ``mean_e min(goodput_e, demand_e) / mean_e demand_e`` — unlike raw
  tokens/s it does not credit late backlog catch-up, so reactive lag
  shows.  Envelope >= 0.85.
* ``goodput_vs_static`` (flash_crowd, spot_preemption) — the closed
  loop must beat the static allocation where adaptation matters.

The first ``WARMUP`` epochs are excluded from cost/coverage: they mix
the INIT_DELAY cold start (identical for all methods) with the
estimator's spin-up from its prior, which is a one-off transient, not
the steady-state behavior the gate tracks.  Resolve counts cover the
whole run.

Under BENCH_FAST the suite runs three scenarios (the two the
acceptance criteria name plus diurnal); ``fast_trimmed`` lists the
rest so the bench gate skips — not fails — their reference points.
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import ART, FAST, Row, cached_library, scenario
from repro.control import (DemandEstimator, ReSolveController,
                           SCENARIO_NAMES, TransitionPlanner, make_scenario)
from repro.core.allocator import AllocatorState, Demand
from repro.runtime.cluster import ClusterRuntime

# identical epoch count in FAST and full mode: the gate compares a
# metric against its pinned reference, so both must measure the same
# configuration — BENCH_FAST only trims the *scenario list*
N_EPOCHS = 10
EPOCH_S = 240.0
BASE_RATE = 2.0
WARMUP = 2
SEED = 2
SCENARIOS_FAST = ("diurnal", "flash_crowd", "spot_preemption")


class _StaticAllocator:
    """Solve once (first epoch), then return the frozen allocation."""

    def __init__(self):
        self._inner = AllocatorState()
        self._alloc = None

    def __call__(self, prob):
        if self._alloc is None:
            self._alloc = self._inner(prob)
        return self._alloc


def _static_demands(sc):
    """Deployment-time demand, frozen: epoch 0's truth every epoch."""
    return [sc.truth_demands[0]] * sc.n_epochs


def _coverage(res, sc):
    """Demand-weighted goodput coverage over the post-warmup epochs.
    The min is per model — one model's surplus (e.g. backlog catch-up)
    must not credit another model's shortfall."""
    cov = tot = 0.0
    for e in res.epochs[WARMUP:]:
        for d in sc.truth_demands[e.epoch]:
            if d.phase != "decode":
                continue
            cov += min(e.goodput.get(d.model, 0.0), d.tokens_per_s)
            tot += d.tokens_per_s
    return cov / max(tot, 1e-9)


def _one_run(mode, name, models, regions, configs, wls, lib):
    # regenerate the scenario per run: the simulator mutates Request
    # objects in place, so methods must never share a trace instance
    sc = make_scenario(name, models, regions, configs, wls,
                       n_epochs=N_EPOCHS, epoch_s=EPOCH_S,
                       base_rate=BASE_RATE, seed=SEED)
    alloc_fn = _StaticAllocator() if mode == "static" else AllocatorState()
    rt = ClusterRuntime(models, regions, configs, lib, alloc_fn, wls,
                        epoch_s=sc.epoch_s, spot_market=sc.spot_market)
    t0 = time.time()
    if mode == "oracle":
        res = rt.run(sc.requests, sc.availability, sc.truth_demands)
    elif mode == "static":
        res = rt.run(sc.requests, sc.availability, _static_demands(sc))
    else:                                   # the closed loop
        res = rt.run(sc.requests, sc.availability,
                     estimator=DemandEstimator(list(models), wls),
                     controller=ReSolveController(),
                     planner=TransitionPlanner(lib, regions, rt.init_k))
    wall = time.time() - t0
    eps = res.epochs[WARMUP:]
    return {
        "cost": sum(e.cost_per_hour for e in eps) / len(eps),
        "coverage": _coverage(res, sc),
        "resolves": res.n_resolves(),
        "preempted": sum(e.n_preempted for e in res.epochs),
        "reasons": [e.trigger_reason for e in res.epochs],
        # per-model TTFT/TBT percentiles + SLO attainment over the
        # post-warmup window (same exclusion as cost/coverage)
        "slo": res.slo_report.window(WARMUP * EPOCH_S,
                                     N_EPOCHS * EPOCH_S),
        "wall_s": wall,
    }, sc


def run() -> None:
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    names = SCENARIOS_FAST if FAST else SCENARIO_NAMES
    results = []
    for name in names:
        out = {}
        for mode in ("oracle", "est", "static"):
            out[mode], sc = _one_run(mode, name, models, regions, configs,
                                     wls, lib)
        o, e, s = out["oracle"], out["est"], out["static"]
        row = {
            "scenario": name,
            "n_epochs": N_EPOCHS, "epoch_s": EPOCH_S,
            "base_rate": BASE_RATE, "warmup": WARMUP,
            "spot_market": sc.spot_market,
            "cost": {m: out[m]["cost"] for m in out},
            "coverage": {m: out[m]["coverage"] for m in out},
            "resolves": {m: out[m]["resolves"] for m in out},
            "preempted": {m: out[m]["preempted"] for m in out},
            "est_reasons": e["reasons"],
            "cost_parity": o["cost"] / max(e["cost"], 1e-9),
            "goodput_parity": e["coverage"] / max(o["coverage"], 1e-9),
            "goodput_vs_static": e["coverage"] / max(s["coverage"], 1e-9),
            "resolve_savings": 1.0 - e["resolves"] / N_EPOCHS,
            # closed-loop tail latency: the gate pins inverse p99 TTFT
            # and SLO attainment per model (tools/check_bench.py)
            "slo_est": e["slo"],
        }
        if name in ("flash_crowd", "spot_preemption") \
                and row["goodput_vs_static"] <= 1.0:
            # the ISSUE acceptance criterion is absolute, not relative
            # to a pinned reference — fail the benchmark (and CI) if
            # the closed loop stops beating static provisioning
            raise AssertionError(
                f"{name}: estimated-demand coverage no longer beats "
                f"static allocation "
                f"(vs_static={row['goodput_vs_static']:.3f} <= 1.0)")
        results.append(row)
        Row.add(f"control_loop_{name}",
                (e["wall_s"] + o["wall_s"] + s["wall_s"]) * 1e6 / N_EPOCHS,
                f"cost_par={row['cost_parity']:.2f}"
                f";gp_par={row['goodput_parity']:.2f}"
                f";vs_static={row['goodput_vs_static']:.2f}"
                f";resolves={e['resolves']}/{N_EPOCHS}")

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_control_loop.json"), "w") as f:
        json.dump({
            "setup": "core", "n_epochs": N_EPOCHS, "epoch_s": EPOCH_S,
            "base_rate": BASE_RATE, "warmup": WARMUP, "seed": SEED,
            # scenarios trimmed by BENCH_FAST — the bench gate skips
            # exactly these reference metrics (tools/check_bench.py)
            "fast_trimmed": [n for n in SCENARIO_NAMES if n not in names],
            "results": results,
        }, f, indent=1)


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_control_loop.csv"))
