"""Fault-recovery benchmark (ROADMAP item 4: judge the storm scenarios
on time-to-recover, not end-of-run cost).

Every fault scenario (repro.control.scenarios.FAULT_SCENARIO_NAMES)
is replayed — identical seeded requests, availability, and fault plan —
under two recovery disciplines:

* ``naive``    — the seed's fault handling made honest about detection:
  no health-probe subsystem (a crashed node is only noticed after a
  full epoch, during which it black-holes routed requests AND looks
  alive to reconcile), instant unconditional restarts (no backoff, no
  budget, no availability check), no admission control, and a router
  blind to per-node degradation.
* ``hardened`` — the fault-tolerant runtime: 15 s health probes,
  ``RestartPolicy`` (exponential backoff per crash streak, per-epoch
  restart budget, availability-checked replacements), ``ShedPolicy``
  admission control, and the straggler-aware router weight.

Both run the oracle demand path with an every-epoch re-solve, so the
deltas isolate the recovery machinery rather than estimator or trigger
quality.

Reported per scenario (gate metrics are higher-is-better ratios):

* ``recovery_speedup`` = naive TTR / hardened TTR, where TTR is the
  time from the first injected fault until demand-weighted coverage
  re-crosses ``RECOVER_FRAC`` of its pre-fault mean and holds it for
  ``SUSTAIN_WINDOWS`` consecutive samples after the outage onset (a
  dip usually starts after the fault instant, so naive first-crossing
  semantics would measure nothing; ambient noise dips long after
  recovery must not re-open the outage).  Never-recovered runs are
  capped at the remaining run length and both TTRs are floored at one
  sampling window, so the ratio stays finite and conservative.
* ``coverage_ratio`` = hardened / naive mean coverage over the
  post-fault windows (goodput *not* lost during the fault).  Coverage
  is sampled in ``WINDOW_S`` windows straight from the simulator's
  token log — epoch-end samples would quantize TTR coarser than the
  detection latencies under test.

The JSON artifact additionally records restart / detected-failure /
shed counts and the goodput-lost integral per discipline.  The
acceptance criterion — hardened beats naive TTR on ``crash_storm`` and
``crash_loop`` — is asserted absolutely in here (not just gated
against a pinned reference).

Under BENCH_FAST the suite runs the CI smoke pair (crash_storm,
straggler); ``fast_trimmed`` lists the rest so the bench gate skips —
not fails — their reference points.
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import ART, FAST, Row, cached_library, scenario
from repro.control import (FAULT_SCENARIO_NAMES, FaultInjector,
                           RestartPolicy, goodput_lost, make_scenario,
                           time_to_recover)
from repro.core.allocator import AllocatorState
from repro.runtime.cluster import ClusterRuntime
from repro.simulator.sim import ShedPolicy

N_EPOCHS = 12
EPOCH_S = 240.0
BASE_RATE = 2.0
WARMUP = 2
SEED = 2
RECOVER_FRAC = 0.9              # coverage must re-cross 90% of pre-fault
SUSTAIN_WINDOWS = 3             # ...and hold it for 3 windows straight
SCENARIOS_FAST = ("crash_storm", "straggler")

HARDENED_PROBE_S = 15.0
NAIVE_PROBE_S = EPOCH_S         # no probe subsystem: an epoch goes by
#                                 before anyone notices a dead node


WINDOW_S = 60.0                 # recovery-metric sampling; epoch-end
#                                 samples (240 s) would quantize TTR
#                                 coarser than the detection latencies
#                                 under test


def _coverage_series(rt, sc):
    """Demand-weighted decode coverage in WINDOW_S windows, read from
    the simulator's token log (window-end timestamps)."""
    times, vals = [], []
    n_win = int(round(sc.n_epochs * sc.epoch_s / WINDOW_S))
    for w in range(n_win):
        t0, t1 = w * WINDOW_S, (w + 1) * WINDOW_S
        e = min(int(t0 // sc.epoch_s), sc.n_epochs - 1)
        cov = tot = 0.0
        for d in sc.truth_demands[e]:
            if d.phase != "decode":
                continue
            cov += min(rt.sim.goodput(d.model, t0, t1), d.tokens_per_s)
            tot += d.tokens_per_s
        times.append(t1)
        vals.append(cov / max(tot, 1e-9))
    return times, vals


def _one_run(mode, name, models, regions, configs, wls, lib):
    # regenerate the scenario per run: the simulator mutates Request
    # objects in place, so disciplines must never share a trace
    sc = make_scenario(name, models, regions, configs, wls,
                       n_epochs=N_EPOCHS, epoch_s=EPOCH_S,
                       base_rate=BASE_RATE, seed=SEED)
    if mode == "hardened":
        rt = ClusterRuntime(
            models, regions, configs, lib, AllocatorState(), wls,
            epoch_s=sc.epoch_s, spot_market=sc.spot_market,
            health_check_s=HARDENED_PROBE_S,
            restart_policy=RestartPolicy(backoff_base_s=20.0,
                                         backoff_mult=2.0,
                                         backoff_max_s=300.0,
                                         budget_per_epoch=4),
            shed_policy=ShedPolicy(max_queue_per_instance=32.0))
    else:
        rt = ClusterRuntime(
            models, regions, configs, lib, AllocatorState(), wls,
            epoch_s=sc.epoch_s, spot_market=sc.spot_market,
            health_check_s=NAIVE_PROBE_S,
            restart_policy=RestartPolicy(check_availability=False))
        rt.sim.straggler_aware = False
    inj = FaultInjector(sc.faults)
    t0 = time.time()
    res = rt.run(sc.requests, sc.availability, sc.truth_demands,
                 fault_injector=inj)
    wall = time.time() - t0
    times, vals = _coverage_series(rt, sc)
    t_fault = inj.first_fault_t
    if t_fault is None:         # feed-only faults plan no events: the
        # stress starts when the lying window opens
        t_fault = sc.faults.start_epoch * sc.epoch_s
    pre = [v for t, v in zip(times, vals)
           if WARMUP * sc.epoch_s <= t <= t_fault]
    pre_cov = sum(pre) / max(len(pre), 1)
    thr = RECOVER_FRAC * pre_cov
    t_end = sc.n_epochs * sc.epoch_s
    ttr = min(time_to_recover(times, vals, t_fault, thr,
                              sustain=SUSTAIN_WINDOWS),
              t_end - t_fault)
    post = [v for t, v in zip(times, vals) if t >= t_fault]
    return {
        "coverage_pre": pre_cov,
        "coverage_post": sum(post) / max(len(post), 1),
        "ttr_s": ttr,
        "goodput_lost": goodput_lost(times, vals, pre_cov, t_fault,
                                     sc.epoch_s),
        "failed": res.total_failed(),
        "restarted": res.total_restarted(),
        "shed": res.total_shed(),
        "recovery_epochs": res.recovery_epochs(),
        "avg_cost": sum(e.cost_per_hour for e in res.epochs[WARMUP:])
        / max(len(res.epochs) - WARMUP, 1),
        # per-model TTFT/TBT percentiles + SLO attainment over the
        # post-warmup window: faults must show up as tail latency, not
        # just coverage dips
        "slo": res.slo_report.window(WARMUP * EPOCH_S,
                                     N_EPOCHS * EPOCH_S),
        "wall_s": wall,
    }, sc, inj


def run() -> None:
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    names = SCENARIOS_FAST if FAST else FAULT_SCENARIO_NAMES
    results = []
    for name in names:
        out = {}
        for mode in ("naive", "hardened"):
            out[mode], sc, inj = _one_run(mode, name, models, regions,
                                          configs, wls, lib)
        nv, hd = out["naive"], out["hardened"]
        row = {
            "scenario": name,
            "n_epochs": N_EPOCHS, "epoch_s": EPOCH_S,
            "base_rate": BASE_RATE, "warmup": WARMUP,
            "spot_market": sc.spot_market,
            "recover_frac": RECOVER_FRAC,
            "first_fault_t": inj.first_fault_t,
            "n_fault_events": len(inj.events),
            "ttr_s": {m: out[m]["ttr_s"] for m in out},
            "coverage_pre": {m: out[m]["coverage_pre"] for m in out},
            "coverage_post": {m: out[m]["coverage_post"] for m in out},
            "goodput_lost": {m: out[m]["goodput_lost"] for m in out},
            "failed": {m: out[m]["failed"] for m in out},
            "restarted": {m: out[m]["restarted"] for m in out},
            "shed": {m: out[m]["shed"] for m in out},
            "recovery_epochs": {m: out[m]["recovery_epochs"]
                                for m in out},
            "avg_cost": {m: out[m]["avg_cost"] for m in out},
            # both TTRs floored at one sampling window so a
            # zero-dip run cannot pin an unreachable reference ratio
            "recovery_speedup": max(nv["ttr_s"], WINDOW_S)
            / max(hd["ttr_s"], WINDOW_S),
            "coverage_ratio": hd["coverage_post"]
            / max(nv["coverage_post"], 1e-9),
            # hardened-discipline tail latency: the gate pins inverse
            # p99 TTFT and SLO attainment per model (check_bench.py)
            "slo_hardened": hd["slo"],
        }
        if name in ("crash_storm", "crash_loop") \
                and row["recovery_speedup"] <= 1.0:
            # the acceptance criterion is absolute, not relative to a
            # pinned reference — fail the benchmark (and CI) if the
            # hardened runtime stops beating naive recovery
            raise AssertionError(
                f"{name}: hardened time-to-recover no longer beats "
                f"naive (speedup={row['recovery_speedup']:.3f} <= 1.0; "
                f"ttr hardened={hd['ttr_s']:.0f}s "
                f"naive={nv['ttr_s']:.0f}s)")
        results.append(row)
        Row.add(f"fault_{name}",
                (nv["wall_s"] + hd["wall_s"]) * 1e6 / N_EPOCHS,
                f"ttr_naive={nv['ttr_s']:.0f}s"
                f";ttr_hard={hd['ttr_s']:.0f}s"
                f";speedup={row['recovery_speedup']:.2f}"
                f";cov_ratio={row['coverage_ratio']:.2f}"
                f";restarts={nv['restarted']}/{hd['restarted']}")

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_fault.json"), "w") as f:
        json.dump({
            "setup": "core", "n_epochs": N_EPOCHS, "epoch_s": EPOCH_S,
            "base_rate": BASE_RATE, "warmup": WARMUP, "seed": SEED,
            "recover_frac": RECOVER_FRAC, "window_s": WINDOW_S,
            "fast_trimmed": [n for n in FAULT_SCENARIO_NAMES
                             if n not in names],
            "results": results,
        }, f, indent=1)


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_fault.csv"))
