"""Fig 11: robustness to imbalanced demand — Large-Heavy / Small-Heavy
(the top/bottom third of models by size receives 80% of requests)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (FAST, Row, cached_library, coral_allocator,
                               make_avail, make_demands, make_requests,
                               scenario)
from repro.core.baselines import cauchy_allocate, homo_allocate
from repro.runtime.cluster import ClusterRuntime


def _skew(models, heavy: str):
    # rank by parameter count
    order = sorted(models, key=lambda m: models[m].params_total)
    k = max(len(order) // 3, 1)
    heavy_set = order[-k:] if heavy == "large" else order[:k]
    n_h, n_l = len(heavy_set), len(order) - len(heavy_set)
    skew = {}
    for m in order:
        skew[m] = (0.8 * len(order) / n_h) if m in heavy_set \
            else (0.2 * len(order) / max(n_l, 1))
    return skew


def run(extended: bool = False):
    t0 = time.time()
    n_epochs = 2 if FAST else 5
    epoch_s = 360.0
    rate = 3.0 if FAST else (10.0 if not extended else 25.0)
    models, configs, regions, wls = scenario(extended)
    name = "ext" if extended else "core"
    lib = cached_library(name, models, configs, wls)
    hlib = cached_library(name, models, configs, wls, homo=True)
    tag = "extended" if extended else "core"

    for heavy in ("large", "small"):
        skew = _skew(models, heavy)
        avail = make_avail(regions, configs, n_epochs,
                           40 if not extended else 64, seed=4)
        demands = [make_demands(models, wls, rate, skew)
                   for _ in range(n_epochs)]
        reqs = make_requests(models, rate, n_epochs * epoch_s, seed=5,
                             skew=skew)
        costs = {}
        for mname, library, fn in [
            ("Coral", lib, coral_allocator()),   # persistent, warm-started
            ("Homo", hlib, lambda p: homo_allocate(p, hlib)),
            ("Cauchy", hlib, lambda p: cauchy_allocate(p, hlib)),
        ]:
            rt = ClusterRuntime(models, regions, configs, library, fn, wls,
                                epoch_s=epoch_s)
            res = rt.run(list(reqs), [dict(a) for a in avail], demands)
            costs[mname] = res.avg_cost()
        ch = costs["Coral"]
        print(f"\n== Fig 11 ({tag}, {heavy}-heavy) ==")
        for mname, c in costs.items():
            print(f"{mname:7s} ${c:8.1f}/h")
        print(f"Coral: {costs['Homo']/ch:.2f}x vs Homo, "
              f"{costs['Cauchy']/ch:.2f}x vs Cauchy")
        Row.add(f"fig11_{heavy}_heavy_{tag}", (time.time() - t0) * 1e6,
                f"vs_homo={costs['Homo']/ch:.2f}x;"
                f"vs_cauchy={costs['Cauchy']/ch:.2f}x")


if __name__ == "__main__":
    run(False)
