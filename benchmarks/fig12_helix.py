"""Fig 12: comparison with Helix on its "High GPU-Heterogeneity Cluster"
(4x A100-40G, 6x V100-16G, 16x L4, 38x T4; llama3-70b; 64 GPUs).

Helix-style: one monolithic PP x DP pipeline over the whole pool.
Coral: allocates subsets of the same pool as multiple Serving Instances
via templates + the allocation ILP, under prefill/decode SLOs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row, cached_library
from repro.core.allocator import AllocProblem, Demand, allocate
from repro.core.baselines import helix_placement
from repro.core.hardware import DEVICE_TYPES, NodeConfig, Region
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.traces.workloads import workload_stats

# Helix §6.6 pool (single-GPU nodes), AWS us-east-2 prices
POOL_SPEC = [("A100-40G", 4), ("V100-16G", 6), ("L4", 16), ("T4", 38)]
HELIX_PREFILL_SLO_MS = 2090.0
HELIX_DECODE_SLO_MS = 730.0


def run():
    t0 = time.time()
    model = dataclasses.replace(PAPER_MODELS["llama3-70b"],
                                prefill_slo_ms=HELIX_PREFILL_SLO_MS,
                                decode_slo_ms=HELIX_DECODE_SLO_MS)
    wl = workload_stats(model.trace)
    configs = [NodeConfig(DEVICE_TYPES[d], 1) for d, _ in POOL_SPEC]
    region = Region("aws-us-east-2")
    pool = []
    avail = {}
    for (d, n), cfg in zip(POOL_SPEC, configs):
        pool += [cfg] * n
        avail[(region.name, cfg.name)] = n

    # --- Helix-style monolithic placement: unconstrained (as Helix runs)
    # and under the same SLOs Coral must satisfy
    helix_dec = helix_placement(model, "decode", wl, pool, slo_ms=1e7)
    helix_dec_slo = helix_placement(model, "decode", wl, pool)
    helix_cost = sum(region.node_usd_per_hour(c) for c in pool)
    helix_tput = helix_dec.throughput if helix_dec else 0.0
    helix_tput_slo = helix_dec_slo.throughput if helix_dec_slo else 0.0

    # --- Coral: allocate from the same pool under demand EXCEEDING the
    # Helix monolith's (SLO-unconstrained) throughput — the paper's
    # protocol ("arrival rate exceeding the throughput Helix reports"),
    # but with SLOs imposed on Coral only.
    # n_max=8 (vs the default 6): with bf16 weights, no <=6-node subset of
    # this pool's small GPUs can cover llama3-70b's 80 layers without the
    # four A100s; 8-node T4/L4 templates restore the multi-instance
    # decomposition the paper reports (their Fig 12 shows three L4/T4
    # decode instances).
    lib = build_library([model], configs, {model.name: wl}, n_max=8,
                        rho=12.0)
    rate = 1.1 * helix_tput / wl.avg_output
    demands = [Demand(model.name, "prefill", rate * wl.avg_prompt),
               Demand(model.name, "decode", rate * wl.avg_output)]
    alloc = allocate(AllocProblem([region], configs, avail, demands, lib,
                                  time_limit=120))
    coral_tput = alloc.served(model.name, "decode")
    print("\n== Fig 12: Helix comparison (llama3-70b, 64-GPU fixed pool) ==")
    print(f"Helix monolithic: decode T={helix_tput:.0f} tok/s "
          f"S={helix_dec.n_stages if helix_dec else '-'} "
          f"cost=${helix_cost:.1f}/h (all 64 GPUs, NO latency SLO)")
    print(f"Helix monolithic under Coral's SLOs: "
          f"T={helix_tput_slo:.0f} tok/s "
          f"S={helix_dec_slo.n_stages if helix_dec_slo else '-'}")
    print(f"Coral @ {rate:.1f} req/s: decode served={coral_tput:.0f} tok/s "
          f"cost=${alloc.cost_per_hour:.1f}/h "
          f"nodes={alloc.total_nodes}/64 under SLOs "
          f"({HELIX_PREFILL_SLO_MS:.0f}/{HELIX_DECODE_SLO_MS:.0f} ms)")
    for (r, k), n in sorted(alloc.instances.items()):
        t = alloc.templates[k]
        print(f"  {k[1]:8s} x{n} {dict(t.counts)} T={t.throughput:.0f} "
              f"S={t.placement.n_stages}")
    # cost efficiency under identical SLOs (apples-to-apples)
    eff_coral = coral_tput / max(alloc.cost_per_hour, 1e-9)
    eff_helix_slo = helix_tput_slo / helix_cost
    gain = eff_coral / max(eff_helix_slo, 1e-9)
    print(f"SLO-constrained cost efficiency (decode tok/s per $/h): "
          f"Coral {eff_coral:.0f} vs Helix {eff_helix_slo:.0f} "
          f"({gain:.2f}x): the monolithic pipeline pays cross-stage "
          f"latency that the per-stage SLO budget cannot absorb")
    Row.add("fig12_helix", (time.time() - t0) * 1e6,
            f"slo_cost_eff_gain={gain:.2f}x;coral_tput={coral_tput:.0f};"
            f"helix_slo_tput={helix_tput_slo:.0f};"
            f"helix_unconstrained={helix_tput:.0f}")


if __name__ == "__main__":
    run()
