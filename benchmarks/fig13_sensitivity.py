"""Fig 13: sensitivity of Serving Template generation to the pruning
parameters (N_max, rho) — template count, solve time, best cost
efficiency. Testbed: GPT-OSS-120B prefill (as in the paper)."""
from __future__ import annotations

import time

from benchmarks.common import FAST, Row
from repro.core.hardware import EXT_CONFIGS, US_EAST_2
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates, template_columns
from repro.traces.workloads import workload_stats


def run():
    t0 = time.time()
    model = PAPER_MODELS["gpt-oss-120b"]
    wl = workload_stats(model.trace)
    sweep = [(2, 4.0), (3, 6.0), (4, 8.0), (5, 10.0), (6, 12.0)]
    if FAST:
        sweep = sweep[:4]
    print("\n== Fig 13: (N_max, rho) sensitivity — gpt-oss-120b prefill ==")
    print(f"{'Nmax':>4} {'rho':>5} {'combos':>8} {'templates':>9} "
          f"{'secs':>7} {'best tok/s/$':>12}")
    best_effs = []
    for n_max, rho in sweep:
        temps, stats = generate_templates(model, "prefill", EXT_CONFIGS, wl,
                                          n_max=n_max, rho=rho)
        # columnar: all per-template costs in one usage @ price matmul
        cols = template_columns(temps, {c.name: c for c in EXT_CONFIGS})
        eff = float((cols.throughput
                     / cols.region_cost([US_EAST_2])[:, 0]).max()) \
            if cols.n else 0.0
        best_effs.append(eff)
        print(f"{n_max:4d} {rho:5.0f} {stats['combos']:8d} "
              f"{stats['templates']:9d} {stats['seconds']:7.1f} {eff:12.1f}")
    plateau = best_effs[-1] / max(best_effs[0], 1e-9)
    print(f"best-template efficiency plateaus: "
          f"last/first = {plateau:.3f}")
    Row.add("fig13_sensitivity", (time.time() - t0) * 1e6,
            f"plateau_gain={plateau:.3f};"
            f"best_eff={best_effs[-1]:.1f}")


if __name__ == "__main__":
    run()
