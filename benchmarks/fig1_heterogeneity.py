"""Fig 1: (a) mixed-GPU pipelines beat every pure setup on cost
efficiency for large-model prefill; (b) heterogeneous node sets fill the
throughput gaps between homogeneous plans."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, cached_library, scenario
from repro.core.hardware import EXT_CONFIGS, US_EAST_2
from repro.core.modelspec import PAPER_MODELS
from repro.traces.workloads import workload_stats


def run():
    t0 = time.time()
    # paper uses Qwen3-235B prefill (SLO 1800ms) over the 5 GPU types
    models = {"qwen3-235b": PAPER_MODELS["qwen3-235b"]}
    wls = {m: workload_stats(models[m].trace) for m in models}
    lib = cached_library("fig1", models, EXT_CONFIGS, wls)
    temps = lib.get("qwen3-235b", "prefill")
    cfg = lib.config_by_name

    def eff(t):
        return t.throughput / t.cost(US_EAST_2, cfg)

    hetero = [t for t in temps if len(t.counts) > 1]
    homo = [t for t in temps if len(t.counts) == 1]
    best_h = max(hetero, key=eff) if hetero else None
    best_o = max(homo, key=eff) if homo else None
    print("\n== Fig 1a: qwen3-235b prefill cost efficiency (tok/s/$) ==")
    if best_h:
        print(f"best heterogeneous: {dict(best_h.counts)} "
              f"S={best_h.placement.n_stages} "
              f"layers={best_h.placement.layer_counts} eff={eff(best_h):.0f}")
    if best_o:
        print(f"best homogeneous:  {dict(best_o.counts)} eff={eff(best_o):.0f}")
    ratio = eff(best_h) / eff(best_o) if best_h and best_o else 0.0

    # Fig 1b: throughput spectrum density (decode plans)
    dec = lib.get("qwen3-235b", "decode")
    th_he = sorted(t.throughput for t in dec)
    th_ho = sorted(t.throughput for t in dec if len(t.counts) == 1)

    def max_gap(v):
        g = [(b - a) / b for a, b in zip(v, v[1:]) if b > 0]
        return max(g) if g else 1.0

    print(f"Fig 1b: max relative throughput gap homo={max_gap(th_ho):.3f} "
          f"all={max_gap(th_he):.3f} (n={len(th_ho)} vs {len(th_he)})")
    Row.add("fig1_heterogeneity", (time.time() - t0) * 1e6,
            f"hetero_over_homo_eff={ratio:.3f};"
            f"gap_homo={max_gap(th_ho):.3f};gap_all={max_gap(th_he):.3f}")


if __name__ == "__main__":
    run()
