"""Fig 2: joint optimization across models vs greedy per-model
allocation under a constrained shared pool."""
from __future__ import annotations

import time

from benchmarks.common import Row, cached_library, make_demands, scenario
from repro.core.allocator import AllocProblem, allocate
from repro.core.baselines import cauchy_allocate, homo_allocate


def run():
    t0 = time.time()
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    hlib = cached_library("core", models, configs, wls, homo=True)
    # constrained pool (Fig 2's "2 GPU-A + 3 GPU-B" flavor): only small
    # nodes, a couple of each, one region — models must share
    avail = {(r.name, c.name): 0 for r in regions for c in configs}
    for c in configs:
        if c.n_devices <= 2:
            avail[(regions[0].name, c.name)] = 2
    demands = make_demands(models, wls, rate=8.0)

    coral = allocate(AllocProblem(regions, configs, dict(avail), demands,
                                  lib, time_limit=60))
    greedy = homo_allocate(AllocProblem(regions, configs, dict(avail),
                                        demands, hlib), hlib)

    def request_service(alloc):
        """Request-level service: a request needs BOTH phases, so the
        served fraction per model is the min across phases."""
        fr = []
        for m in models:
            per_phase = []
            for d in demands:
                if d.model != m:
                    continue
                per_phase.append(min(alloc.served(m, d.phase)
                                     / d.tokens_per_s, 1.0))
            fr.append(min(per_phase))
        return sum(fr) / len(fr)

    sc, sg = request_service(coral), request_service(greedy)
    print("\n== Fig 2: joint vs greedy under contention ==")
    print(f"request-level service: joint={100*sc:.1f}% "
          f"greedy={100*sg:.1f}%")
    Row.add("fig2_joint", (time.time() - t0) * 1e6,
            f"served_joint={sc:.3f};served_greedy={sg:.3f}")


if __name__ == "__main__":
    run()
