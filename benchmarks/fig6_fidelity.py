"""Fig 6 / §6.2 simulator fidelity: the event simulator, with a cost
model *fitted from profiling the real system* (the paper's methodology),
must reproduce the real runtime's per-request latencies.

"Real system" = the JAX serving engine (repro.serving.engine) running an
actual small model on this container, wall-clock timed. Compile effects
are excluded by pre-warming every prefill bucket and the decode step at
all batch sizes before the measured trace. Because the engine is
PD-aggregated (one device does prefill and decode interleaved) while the
simulator models instances, the sim instance is given the same
interleaving semantics via a fitted aggregated cost model, and fidelity
is scored on per-request prefill latency and completion-time
distributions.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Row
from repro.configs.registry import get_smoke_config
from repro.core.modelspec import from_model_config
from repro.core.placement import Placement
from repro.core.templates import ServingTemplate
from repro.models import api as mapi
from repro.serving.engine import JaxEngine, _bucket
from repro.simulator.sim import Simulator
from repro.traces.workloads import Request, workload_stats


class FittedCostModel:
    """InstanceCostModel-compatible model from measured iteration times."""

    def __init__(self, pre_a, pre_b, dec_a, dec_b, capacity, chunk):
        self.pre_a, self.pre_b = pre_a, pre_b
        self.dec_a, self.dec_b = dec_a, dec_b
        self._cap = capacity
        self.prefill_chunk = chunk

    def prefill_iter_time(self, tokens):
        return self.pre_a + self.pre_b * tokens

    def prefill_pipeline_latency(self, tokens):
        return self.pre_a + self.pre_b * tokens

    def decode_iter_time(self, batch):
        return self.dec_a + self.dec_b * batch

    def decode_pipeline_latency(self, batch):
        return self.dec_a + self.dec_b * batch

    @property
    def decode_capacity(self):
        return self._cap

    def kv_transfer_time(self, prompt_tokens):
        return 0.0


def run(n_requests: int = 24, seed: int = 0):
    t0 = time.time()
    cfg = get_smoke_config("qwen2-1.5b")
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    max_batch, max_len = 4, 128

    prompts = rng.integers(8, 48, size=n_requests)
    outs = rng.integers(4, 24, size=n_requests)
    arrivals = np.cumsum(rng.exponential(0.25, size=n_requests))

    # ---- real system (pre-warmed) ----
    eng = JaxEngine(cfg, params, max_batch=max_batch, max_len=max_len)
    warm_rid = -1
    for b in {int(_bucket(int(p))) for p in prompts}:
        eng.submit(warm_rid, rng.integers(0, cfg.vocab_size, size=(b,)),
                   max_batch + 1)
        warm_rid -= 1
    # fill all slots so decode compiles at every active-batch size
    for _ in range(max_batch):
        eng.submit(warm_rid, rng.integers(0, cfg.vocab_size, size=(9,)), 2)
        warm_rid -= 1
    eng.drain()
    eng.iteration_log.clear()

    t_start = time.time()
    submitted, finished, sub_time = 0, {}, {}
    while len(finished) < n_requests:
        now = time.time() - t_start
        while submitted < n_requests and arrivals[submitted] <= now:
            rid = submitted
            eng.submit(rid, rng.integers(0, cfg.vocab_size,
                                         size=(int(prompts[rid]),)),
                       int(outs[rid]))
            sub_time[rid] = time.time()
            submitted += 1
        if not any(eng.slots) and not eng.queue:
            if submitted < n_requests:
                time.sleep(0.002)
            continue
        reqs = {s.rid: s for s in eng.slots if s is not None}
        for rid, _t, done in eng.step():
            if done:
                finished[rid] = reqs[rid]
    real_prefill = np.array([finished[r].prefill_done - sub_time[r]
                             for r in range(n_requests)])
    real_total = np.array([finished[r].token_times[-1] - sub_time[r]
                           if finished[r].token_times else
                           finished[r].prefill_done - sub_time[r]
                           for r in range(n_requests)])

    # ---- fit the stage cost model from the profiling log (paper §5.2) --
    pre = [(n, dt) for kind, n, dt in eng.iteration_log if kind == "prefill"]
    dec = [(n, dt) for kind, n, dt in eng.iteration_log if kind == "decode"]

    def fit(pairs):
        x = np.array([p[0] for p in pairs], float)
        y = np.array([p[1] for p in pairs], float)
        keep = y <= np.percentile(y, 90)        # robust: drop GC/OS spikes
        x, y = x[keep], y[keep]
        if len(set(x)) < 2:
            return float(np.median(y)), 0.0
        b, a = np.polyfit(x, y, 1)
        return max(a, 1e-5), max(b, 0.0)

    pre_a, pre_b = fit(pre)
    dec_a, dec_b = fit(dec)

    # ---- simulator on the same trace (aggregated PD: shared instance
    # semantics approximated by serializing prefill into the decode
    # stream through the same fitted per-iteration costs) ----
    sm = from_model_config(cfg, prefill_slo_ms=10_000, decode_slo_ms=10_000)
    wl = workload_stats("burstgpt")
    pl = Placement(1, (cfg.n_layers,), (("cpu",),), 1.0)
    tp = ServingTemplate(sm.name, "prefill", 10_000, (("cpu", 1),), pl, 1e5)
    td = ServingTemplate(sm.name, "decode", 10_000, (("cpu", 1),), pl, 1e5)
    sim = Simulator({sm.name: sm}, {}, {sm.name: wl})
    cmf = FittedCostModel(pre_a, pre_b, dec_a, dec_b, capacity=max_batch,
                          chunk=max(int(_bucket(int(prompts.max()))), 64))
    sim.add_instance("local", tp, ready_delay=0.0, cm=cmf)
    sim.add_instance("local", td, ready_delay=0.0, cm=cmf)
    sim_reqs = [Request(rid, sm.name, float(arrivals[rid]),
                        int(prompts[rid]), int(outs[rid]))
                for rid in range(n_requests)]
    for r in sim_reqs:
        sim.submit(r)
    sim.run_until(1e6)
    sim_prefill = np.asarray(sim.reqlog.ttft_values(sm.name))
    sim_total = np.array([r.finish - r.arrival for r in sim.finished])

    def dev(a, b):
        return abs(np.mean(b) - np.mean(a)) / max(np.mean(a), 1e-9)

    dev_p = dev(real_prefill, sim_prefill)
    dev_t = dev(real_total, sim_total)
    print("\n== Fig 6: simulator fidelity (real JAX engine vs event sim) ==")
    print(f"prefill latency  real p50={np.percentile(real_prefill,50)*1e3:.1f}ms "
          f"p95={np.percentile(real_prefill,95)*1e3:.1f} | "
          f"sim p50={np.percentile(sim_prefill,50)*1e3:.1f} "
          f"p95={np.percentile(sim_prefill,95)*1e3:.1f}  "
          f"mean dev={dev_p*100:.1f}%")
    print(f"completion time  real p50={np.percentile(real_total,50)*1e3:.0f}ms "
          f"p95={np.percentile(real_total,95)*1e3:.0f} | "
          f"sim p50={np.percentile(sim_total,50)*1e3:.0f} "
          f"p95={np.percentile(sim_total,95)*1e3:.0f}  "
          f"mean dev={dev_t*100:.1f}%")
    Row.add("fig6_fidelity", (time.time() - t0) * 1e6,
            f"prefill_dev={dev_p:.3f};completion_dev={dev_t:.3f}")


if __name__ == "__main__":
    run()
