"""Fig 7: hourly serving cost — Coral vs Homo vs Cauchy under default
(abundant) availability, core + extended setups, with the per-model
provisioning breakdown (prefill/decode)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (FAST, Row, cached_library, coral_allocator,
                               make_avail, make_demands, make_requests,
                               scenario)
from repro.core.baselines import cauchy_allocate, homo_allocate
from repro.runtime.cluster import ClusterRuntime


def _run_setup(extended: bool, rate: float, n_epochs: int, epoch_s: float):
    models, configs, regions, wls = scenario(extended)
    name = "ext" if extended else "core"
    lib = cached_library(name, models, configs, wls)
    hlib = cached_library(name, models, configs, wls, homo=True)
    abundance = 40 if not extended else 64
    avail = make_avail(regions, configs, n_epochs, abundance, seed=0)
    demands = [make_demands(models, wls, rate) for _ in range(n_epochs)]
    reqs = make_requests(models, rate, n_epochs * epoch_s, seed=1)

    out = {}
    for mname, library, fn in [
        ("Coral", lib, coral_allocator()),       # persistent, warm-started
        ("Homo", hlib, lambda p: homo_allocate(p, hlib)),
        ("Cauchy", hlib, lambda p: cauchy_allocate(p, hlib)),
    ]:
        rt = ClusterRuntime(models, regions, configs, library, fn, wls,
                            epoch_s=epoch_s)
        res = rt.run(list(reqs), [dict(a) for a in avail], demands)
        cost = res.avg_cost()
        solve = np.mean([e.solve_seconds for e in res.epochs])
        # per-model cost breakdown from the final cluster
        breakdown = {}
        cfg = library.config_by_name
        for (rname, key), insts in rt.running.items():
            region = rt.region_by_name[rname]
            for inst in insts:
                if inst.dead:
                    continue
                k = (key[0], key[1])
                breakdown[k] = breakdown.get(k, 0.0) \
                    + inst.template.cost(region, cfg)
        out[mname] = dict(cost=cost, solve=solve, breakdown=breakdown,
                          res=res)
    return models, out


def run():
    t0 = time.time()
    n_epochs = 3 if FAST else 5
    epoch_s = 360.0
    for extended, rate in ((False, 10.0 if not FAST else 4.0),
                           (True, 25.0 if not FAST else 6.0)):
        models, out = _run_setup(extended, rate, n_epochs, epoch_s)
        tag = "extended" if extended else "core"
        print(f"\n== Fig 7 ({tag} setup, rate={rate} req/s/model) ==")
        for mname, d in out.items():
            print(f"{mname:7s} ${d['cost']:8.1f}/h  solve={d['solve']:.2f}s")
        ch = out["Coral"]["cost"]
        rh = out["Homo"]["cost"] / ch if ch else 0
        rc = out["Cauchy"]["cost"] / ch if ch else 0
        print(f"Coral reduction: {rh:.2f}x vs Homo, {rc:.2f}x vs Cauchy")
        print("per-model breakdown (Coral, $/h):")
        agg = {}
        for (m, phase), c in out["Coral"]["breakdown"].items():
            agg.setdefault(m, {})[phase] = c
        for m, d in sorted(agg.items()):
            print(f"  {m:14s} P=${d.get('prefill', 0):7.1f} "
                  f"D=${d.get('decode', 0):7.1f}")
        Row.add(f"fig7_cost_{tag}", (time.time() - t0) * 1e6,
                f"coral=${ch:.1f};vs_homo={rh:.2f}x;vs_cauchy={rc:.2f}x;"
                f"solve_s={out['Coral']['solve']:.2f}")


if __name__ == "__main__":
    run()
