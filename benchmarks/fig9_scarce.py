"""Figs 8-10: cost and per-model decode goodput under scarce resource
availability (availability scaled to a tight multiple of demand)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (FAST, Row, cached_library, coral_allocator,
                               make_avail, make_demands, make_requests,
                               scenario)
from repro.core.baselines import cauchy_allocate, homo_allocate
from repro.runtime.cluster import ClusterRuntime


def run(extended: bool = False):
    t0 = time.time()
    n_epochs = 3 if FAST else 5
    epoch_s = 360.0
    rate = 3.0 if FAST else (10.0 if not extended else 25.0)
    models, configs, regions, wls = scenario(extended)
    name = "ext" if extended else "core"
    lib = cached_library(name, models, configs, wls)
    hlib = cached_library(name, models, configs, wls, homo=True)
    # tight availability: ~25% (core) / 75% (ext) above estimated demand
    abundance = 7 if not extended else 24
    scarcity = {"H100": 0.3, "A100": 0.5}
    avail = make_avail(regions, configs, n_epochs, abundance, seed=3,
                       scarcity=scarcity)
    demands = [make_demands(models, wls, rate) for _ in range(n_epochs)]
    reqs = make_requests(models, rate, n_epochs * epoch_s, seed=2)

    tag = "extended" if extended else "core"
    print(f"\n== Figs 8-10 ({tag}): scarce availability ==")
    results = {}
    for mname, library, fn in [
        ("Coral", lib, coral_allocator()),       # persistent, warm-started
        ("Homo", hlib, lambda p: homo_allocate(p, hlib)),
        ("Cauchy", hlib, lambda p: cauchy_allocate(p, hlib)),
    ]:
        rt = ClusterRuntime(models, regions, configs, library, fn, wls,
                            epoch_s=epoch_s)
        res = rt.run(list(reqs), [dict(a) for a in avail], demands)
        gp = {m: np.mean([e.goodput[m] for e in res.epochs[1:]])
              for m in models}
        results[mname] = dict(cost=res.avg_cost(), gp=gp)
        dem = {m: rate * wls[m].avg_output for m in models}
        att = np.mean([min(gp[m] / dem[m], 1.0) for m in models])
        results[mname]["att"] = att
        print(f"{mname:7s} ${res.avg_cost():8.1f}/h  "
              f"goodput={ {m: round(v) for m, v in gp.items()} } "
              f"attain={att*100:.0f}%")
    gc = np.mean(list(results["Coral"]["gp"].values()))
    gh = np.mean(list(results["Homo"]["gp"].values()))
    gq = np.mean(list(results["Cauchy"]["gp"].values()))
    print(f"Coral goodput: {gc/max(gh,1e-9):.2f}x vs Homo, "
          f"{gc/max(gq,1e-9):.2f}x vs Cauchy")
    Row.add(f"fig9_scarce_{tag}", (time.time() - t0) * 1e6,
            f"goodput_vs_homo={gc/max(gh, 1e-9):.2f}x;"
            f"goodput_vs_cauchy={gc/max(gq, 1e-9):.2f}x;"
            f"cost_coral=${results['Coral']['cost']:.1f}")


if __name__ == "__main__":
    run(False)
    run(True)
