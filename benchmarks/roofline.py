"""Roofline analysis (deliverable g): derive the three roofline terms
per (arch x shape) cell from the cached dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO_FLOPs / bytes come from compiled.cost_analysis(); on this backend
the analysis reports the *per-device* partitioned module, so global =
per_device x n_devices (validated against 6*N*D in tests). collective
bytes are parsed from the optimized HLO (launch/dryrun.py).
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

from benchmarks.common import ART, Row
from repro.configs.base import SHAPE_BY_NAME
from repro.configs.registry import get_config
from repro.core.hardware import (TPU_V5E_HBM_BW, TPU_V5E_ICI_BW,
                                 TPU_V5E_PEAK_FLOPS)

DRYRUN_DIR = os.path.join(ART, "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D train / 2*N*D inference (N = active params)."""
    cfg = get_config(arch)
    shape = SHAPE_BY_NAME[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token/seq


def analyse_cell(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n = rec["n_devices"]
    # loop-aware HLO counters (launch/hlo_analysis.py); XLA's raw
    # cost_analysis undercounts lax.scan bodies by the trip count
    flops_dev = rec.get("hlo_flops", rec["flops_total"])
    bytes_dev = rec.get("hlo_traffic_bytes", rec["bytes_accessed"])
    coll_total = rec.get("hlo_collective_bytes_total",
                         rec["collective_bytes_total"])
    t_comp = flops_dev / TPU_V5E_PEAK_FLOPS
    t_mem = bytes_dev / TPU_V5E_HBM_BW
    t_coll = coll_total / TPU_V5E_ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / (flops_dev * n) if flops_dev > 0 else 0.0
    bound = max(terms.values())
    # roofline fraction: useful model compute per chip over peak, at the
    # step time implied by the dominant term
    frac = (mf / n / TPU_V5E_PEAK_FLOPS) / bound if bound > 0 else 0.0
    return dict(rec, t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
                dominant=dominant, model_flops=mf, useful_ratio=useful,
                roofline_fraction=frac)


def load_all(mesh: str = "16x16", tag: str = "") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        cell_tag = rec["cell"].split("__")[3] if rec["cell"].count("__") >= 3 \
            else ""
        if cell_tag != tag:
            continue
        out.append(rec)
    return out


def run(mesh: str = "16x16"):
    t0 = time.time()
    recs = load_all(mesh)
    print(f"\n== Roofline ({mesh} mesh, per-chip seconds/step) ==")
    hdr = (f"{'arch':22s} {'shape':12s} {'comp(s)':>9} {'mem(s)':>9} "
           f"{'coll(s)':>9} {'dom':>5} {'useful':>7} {'roofl%':>7}")
    print(hdr)
    rows = []
    worst = None
    for rec in recs:
        if rec.get("status") == "skipped":
            print(f"{rec['arch']:22s} {rec['shape']:12s} "
                  f"{'—':>9} {'—':>9} {'—':>9}   skip "
                  f"({rec['reason'][:40]}...)")
            continue
        a = analyse_cell(rec)
        rows.append(a)
        print(f"{a['arch']:22s} {a['shape']:12s} {a['t_compute']:9.4f} "
              f"{a['t_memory']:9.4f} {a['t_collective']:9.4f} "
              f"{a['dominant'][:4]:>5} {a['useful_ratio']:7.2f} "
              f"{100*a['roofline_fraction']:7.1f}")
        if worst is None or a["roofline_fraction"] < worst["roofline_fraction"]:
            worst = a
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"roofline_{mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    if rows:
        import numpy as np
        med = float(np.median([r["roofline_fraction"] for r in rows]))
        Row.add(f"roofline_{mesh}", (time.time() - t0) * 1e6,
                f"cells={len(rows)};median_fraction={med:.3f};"
                f"worst={worst['arch']}/{worst['shape']}="
                f"{worst['roofline_fraction']:.3f}")
    return rows


if __name__ == "__main__":
    run()
