# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV (also written to artifacts/bench_results.csv).
#
# Set BENCH_FAST=0 for the full-scale (paper-parameter) runs; the default
# trims trace durations so the whole suite completes on this 1-core CPU
# container.
#
# ``--only a,b,c``: run only the named jobs (see the ``jobs`` table) —
# the subset CI's bench smoke drives (tools/ci.sh).
#
# ``--check``: after the suite, compare the freshly written
# artifacts/BENCH_*.json against the committed reference points in
# tools/bench_reference.json (tools/check_bench.py) and exit non-zero
# on a >20% regression.
import os
import sys
import time
import traceback


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import ART, Row
    from benchmarks import (allocator_bench, control_loop, fault_bench,
                            fig1_heterogeneity, fig2_joint, fig6_fidelity,
                            fig7_cost, fig9_scarce, fig11_imbalance,
                            fig12_helix, fig13_sensitivity, roofline,
                            sim_loop, table1_specs, template_gen)

    t0 = time.time()
    jobs = [
        ("table1", table1_specs.run),
        ("template_gen", template_gen.run),
        ("sim_loop", sim_loop.run),
        ("allocator", allocator_bench.run),
        ("control_loop", control_loop.run),
        ("fault", fault_bench.run),
        ("fig1", fig1_heterogeneity.run),
        ("fig2", fig2_joint.run),
        ("fig6", fig6_fidelity.run),
        ("fig7", fig7_cost.run),
        ("fig9_core", lambda: fig9_scarce.run(extended=False)),
        ("fig9_ext", lambda: fig9_scarce.run(extended=True)),
        ("fig11_core", lambda: fig11_imbalance.run(extended=False)),
        ("fig12", fig12_helix.run),
        ("fig13", fig13_sensitivity.run),
        ("roofline_single", lambda: roofline.run("16x16")),
        ("roofline_multi", lambda: roofline.run("2x16x16")),
    ]
    args = sys.argv[1:]
    if "--only" in args:
        i = args.index("--only") + 1
        if i >= len(args):
            raise SystemExit("run.py --only: requires a comma-separated "
                             "job list")
        sel = args[i].split(",")
        known = {n for n, _ in jobs}
        unknown = [s for s in sel if s not in known]
        if unknown:
            raise SystemExit(f"run.py --only: unknown job(s) {unknown}; "
                             f"choose from {sorted(known)}")
        jobs = [(n, f) for n, f in jobs if n in sel]
    failures = []
    for name, fn in jobs:
        try:
            fn()
        except Exception:                               # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            Row.add(name, 0.0, "FAILED")
    Row.flush(os.path.join(ART, "bench_results.csv"))
    print(f"\ntotal benchmark wall time: {time.time() - t0:.0f}s")
    if failures:
        print(f"FAILED benchmarks: {failures}")
        raise SystemExit(1)
    if "--check" in args:
        from tools.check_bench import check
        raise SystemExit(check())


if __name__ == '__main__':
    main()
