"""Simulator event-loop scaling benchmark (online stage).

Times the batched decode event loop (``Simulator(batched=True)``, the
default) against the per-iteration reference oracle on paper-scale
seeded workloads over the core Serving-Template library, verifying
bit-identical accounting (finished/dropped counts, per-epoch goodput
and throughput) on every scenario, and records the trajectory in
``artifacts/BENCH_sim_loop.json``.

Scenarios:

* ``backlog_drain`` — the regime ROADMAP flagged ("at paper-scale
  request rates the heap churn dominates"): a fleet of the
  small-capacity cost-efficient templates the allocator reaches for
  under scarce availability (§6.4), each instance carrying a seeded
  admission backlog, drained to completion.  Decode iterations are the
  only events, so this isolates the event-loop hot path: the batched
  loop advances ~90 iterations per heap event (constant-batch spans
  over the queue backlog, then segmented spans over the decaying
  resident set) where the oracle pays one event each.
* ``steady_rate*`` — the same fleet fed by live seeded arrivals
  (prefill -> KV transfer -> decode joins) at per-model request rates
  around the paper's core-setup evaluation points, then drained.  KV
  joins interrupt spans, so this reports the integrated speedup with
  the full router/prefill path included.

The headline ``speedup`` in the JSON is ``backlog_drain`` — the
measure of the rebuilt event loop itself; the steady rows track the
end-to-end effect (joins cap the batch length at avg_output/batch, so
they sit lower by design, never below ~1x thanks to the adaptive
span/fallback policy).
"""
from __future__ import annotations

import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import ART, FAST, Row, cached_library, scenario
from repro.simulator.costmodel import InstanceCostModel
from repro.simulator.sim import Simulator
from repro.traces.workloads import gen_requests

EPOCH_S = 360.0
N_INST = 6                      # instances per model
BACKLOG_X = 16.0                # queue depth per instance, in capacities
STEADY_RATES_FULL = (2.0, 6.0)
STEADY_RATES = STEADY_RATES_FULL[:1] if FAST else STEADY_RATES_FULL
STEADY_DUR = 720.0


def _fleet_templates(models, lib, wls):
    """Per model, the highest-throughput decode template with a small
    SLO-bounded capacity (8..48 resident sequences) — the shapes the
    allocator picks when scarce availability rules out big combos."""
    cfg = lib.config_by_name
    picks = {}
    for mname, model in models.items():
        best = None
        for t in lib.get(mname, "decode"):
            cm = InstanceCostModel(model, "decode", t.placement, cfg,
                                   wls[mname])
            if 8 <= cm.decode_capacity <= 48 and \
                    (best is None or t.throughput > best[0].throughput):
                best = (t, cm.decode_capacity)
        if best is None:                        # fallback: smallest cap
            t = min(lib.get(mname, "decode"),
                    key=lambda t: InstanceCostModel(
                        model, "decode", t.placement, cfg,
                        wls[mname]).decode_capacity)
            best = (t, InstanceCostModel(model, "decode", t.placement,
                                         cfg, wls[mname]).decode_capacity)
        picks[mname] = best
    return picks


def _prefill_templates(models, lib):
    return {m: max(lib.get(m, "prefill"), key=lambda t: t.throughput)
            for m in models}


def _verify(models, s1, s2, t_end):
    ok = (s1.dropped == s2.dropped
          and {r.rid for r in s1.finished} == {r.rid for r in s2.finished})
    for m in models:
        ok = ok and len(s1.tokens[m]) == len(s2.tokens[m])
        t = 0.0
        while t < t_end and ok:
            ok = (s1.goodput(m, t, t + EPOCH_S)
                  == s2.goodput(m, t, t + EPOCH_S)
                  and s1.throughput(m, t, t + EPOCH_S)
                  == s2.throughput(m, t, t + EPOCH_S))
            t += EPOCH_S
    if not ok:
        raise AssertionError("batched loop diverged from the "
                             "per-iteration oracle")
    return True


def _drain_sim(batched, models, lib, wls, picks, reqlog=True,
               backlog_x=BACKLOG_X):
    sim = Simulator(models, lib.config_by_name, wls, batched=batched,
                    reqlog=reqlog)
    for mi, (mname, (tmpl, cap)) in enumerate(picks.items()):
        insts = [sim.add_instance("r0", tmpl, ready_delay=0.0)
                 for _ in range(N_INST)]
        n_req = int(N_INST * cap * backlog_x)
        reqs = gen_requests(mname, models[mname].trace, 1000.0,
                            n_req / 1000.0 + 1.0, seed=13 + mi,
                            rid0=mi * 10_000_000)[:n_req]
        # an already-prefilled admission backlog sits on each instance
        # at t=0 (KV transferred during an earlier scarcity episode);
        # seeding the queues directly keeps the measured section free
        # of injection events in both modes
        for i, r in enumerate(reqs):
            insts[i % N_INST].queue.append(r)
        for inst in insts:
            sim.ev.push(0.0, sim._maybe_start, inst)
    t0 = time.time()
    t = 0.0
    while t < 40_000.0:
        t += EPOCH_S
        sim.run_until(t)
    return sim, time.time() - t0


def _steady_sim(batched, models, lib, wls, picks, pres, rate):
    sim = Simulator(models, lib.config_by_name, wls, batched=batched)
    for mname, (tmpl, _cap) in picks.items():
        for _ in range(N_INST):
            sim.add_instance("r0", tmpl, ready_delay=0.0)
        sim.add_instance("r0", pres[mname], ready_delay=0.0)
        sim.add_instance("r0", pres[mname], ready_delay=0.0)
    for mi, mname in enumerate(picks):
        for r in gen_requests(mname, models[mname].trace, rate,
                              STEADY_DUR, seed=29 + mi,
                              rid0=mi * 10_000_000):
            sim.submit(r)
    t0 = time.time()
    t = 0.0
    while t < STEADY_DUR + 40_000.0:
        t += EPOCH_S
        sim.run_until(t)
    return sim, time.time() - t0


def run() -> None:
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    picks = _fleet_templates(models, lib, wls)
    pres = _prefill_templates(models, lib)
    results = []

    # ---- backlog drain: pure decode event loop -----------------------
    # best-of-3: the container CPU throttles unpredictably and the
    # batched wall is small, so single runs are noise-dominated
    s_b, w_b = _drain_sim(True, models, lib, wls, picks)
    s_o, w_o = _drain_sim(False, models, lib, wls, picks)
    for _ in range(2):
        w_b = min(w_b, _drain_sim(True, models, lib, wls, picks)[1])
        w_o = min(w_o, _drain_sim(False, models, lib, wls, picks)[1])
    _verify(models, s_o, s_b, 40_000.0)
    toks = sum(len(s_o.tokens[m]) for m in models)
    iters = sum(i.iters for i in s_b.instances.values())
    spans = sum(i._gen for i in s_b.instances.values())
    drain_speedup = w_o / max(w_b, 1e-9)
    results.append({
        "scenario": "backlog_drain", "tokens": toks,
        "requests": len(s_o.finished), "iters": iters,
        "iters_per_span": iters / max(spans, 1),
        "oracle_s": w_o, "batched_s": w_b, "speedup": drain_speedup,
        "equal": True,
    })
    us = w_b * 1e6 / max(toks, 1)
    Row.add("sim_loop_backlog_drain", us,
            f"speedup={drain_speedup:.1f}x"
            f";{toks/max(w_b,1e-9)/1e6:.1f}Mtok/s"
            f";iters_per_span={iters/max(spans,1):.0f}")

    # ---- observability overhead: RequestLog on vs off ----------------
    # measured on its own 4x-deeper backlog: the overhead fraction is
    # scale-invariant (requests and tokens grow together) but the
    # ~250 ms wall can actually resolve a <5% budget, which the 60 ms
    # headline drain cannot on this noisy container.  On/off runs are
    # interleaved and each side takes its min-of-3, so a CPU-throttle
    # episode hits both sides alike.  Clamped at 0 so noise can't go
    # "negative".
    w_on = w_off = float("inf")
    for _ in range(3):
        w_on = min(w_on, _drain_sim(True, models, lib, wls, picks,
                                    backlog_x=4 * BACKLOG_X)[1])
        w_off = min(w_off, _drain_sim(True, models, lib, wls, picks,
                                      reqlog=False,
                                      backlog_x=4 * BACKLOG_X)[1])
    obs_overhead_frac = max(w_on / max(w_off, 1e-9) - 1.0, 0.0)
    obs_overhead_ok = obs_overhead_frac < 0.05
    results.append({
        "scenario": "obs_overhead", "reqlog_on_s": w_on,
        "reqlog_off_s": w_off, "overhead_frac": obs_overhead_frac,
        "obs_overhead_ok": obs_overhead_ok,
    })
    Row.add("sim_loop_obs_overhead",
            obs_overhead_frac * 100.0,
            f"reqlog_on={w_on:.3f}s;off={w_off:.3f}s"
            f";ok={obs_overhead_ok}")
    if not obs_overhead_ok:
        raise AssertionError(
            f"RequestLog overhead {obs_overhead_frac:.1%} >= 5% budget "
            f"(on={w_on:.3f}s off={w_off:.3f}s)")

    # ---- steady arrivals: integrated loop ----------------------------
    for rate in STEADY_RATES:
        s_b, w_b = _steady_sim(True, models, lib, wls, picks, pres, rate)
        s_o, w_o = _steady_sim(False, models, lib, wls, picks, pres, rate)
        w_b = min(w_b, _steady_sim(True, models, lib, wls, picks, pres,
                                   rate)[1])
        w_o = min(w_o, _steady_sim(False, models, lib, wls, picks, pres,
                                   rate)[1])
        _verify(models, s_o, s_b, STEADY_DUR + 40_000.0)
        toks = sum(len(s_o.tokens[m]) for m in models)
        sp = w_o / max(w_b, 1e-9)
        results.append({
            "scenario": f"steady_rate{rate:g}", "tokens": toks,
            "requests": len(s_o.finished),
            "oracle_s": w_o, "batched_s": w_b, "speedup": sp,
            "equal": True,
        })
        Row.add(f"sim_loop_steady_rate{rate:g}",
                w_b * 1e6 / max(toks, 1),
                f"speedup={sp:.1f}x;{toks/max(w_b,1e-9)/1e6:.1f}Mtok/s")

    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_sim_loop.json"), "w") as f:
        json.dump({
            "fleet": {m: {"template": list(map(list, picks[m][0].counts)),
                          "decode_capacity": picks[m][1]}
                      for m in picks},
            "n_inst_per_model": N_INST, "backlog_x": BACKLOG_X,
            # scenarios trimmed by BENCH_FAST — the bench gate skips
            # exactly these reference metrics instead of failing on
            # them (tools/check_bench.py)
            "fast_trimmed": [f"steady_rate{r:g}"
                             for r in STEADY_RATES_FULL
                             if r not in STEADY_RATES],
            "speedup": drain_speedup,
            "obs_overhead_frac": obs_overhead_frac,
            "obs_overhead_ok": obs_overhead_ok,
            "results": results,
        }, f, indent=1)


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_sim_loop.csv"))
