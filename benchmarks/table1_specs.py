"""Table 1: GPU specs and perf-per-cost (mem / bandwidth / TFLOPs per
relative cost unit)."""
from __future__ import annotations

import time

from benchmarks.common import Row
from repro.core.hardware import A10G, A100, H100, L4, L40S


def run():
    t0 = time.time()
    print("\n== Table 1: perf per cost ==")
    print(f"{'GPU':6s} {'relcost':>7s} {'mem/GB':>7s} {'bw':>6s} {'TF':>6s}"
          f" | {'mem/c':>6s} {'bw/c':>6s} {'TF/c':>6s}")
    for d in (H100, A100, L40S, L4, A10G):
        c = d.rel_cost
        print(f"{d.name:6s} {c:7.1f} {d.mem_gb:7.0f} {d.bw_tbps:6.2f} "
              f"{d.tflops:6.0f} | {d.mem_gb/c:6.1f} {d.bw_tbps/c:6.2f} "
              f"{d.tflops/c:6.1f}")
    # paper's qualitative claim: mid-tier beats top-tier on perf-per-cost
    assert L4.mem_gb / L4.rel_cost > H100.mem_gb / H100.rel_cost
    assert L40S.tflops / L40S.rel_cost > H100.tflops / H100.rel_cost
    Row.add("table1_specs", (time.time() - t0) * 1e6,
            f"L40S_TF_per_cost={L40S.tflops/L40S.rel_cost:.0f};"
            f"H100_TF_per_cost={H100.tflops/H100.rel_cost:.0f}")


if __name__ == "__main__":
    run()
