"""Serving-Template generation scaling benchmark (offline stage 1).

Times ``generate_templates`` at two scales and records the trajectory
in ``artifacts/BENCH_template_gen.json`` so perf regressions in the
offline pipeline are caught from PR 1 onward:

* core (paper 12-config setup), qwen3-32b decode — the heaviest
  (model, phase) of the core library — at n_max in {4, 5, 6}, fast
  path vs. the reference per-combo exact solver;
* extended (paper 20-config setup), llama3-70b decode — a heavy
  (model, phase) of the extended library (~200k combos at n_max=6) —
  at n_max in {5, 6}, fast path only.

Context: the seed per-combo solver took ~192-212s at the paper-default
n_max=6 on the core setup; the memoized + vectorized PlacementCache
path (PR 1) brought that to ~6s, and the level-wise dominance-pruned
frontier (PR 4) runs the extended n_max=6 pair in ~1 min (was ~7 min),
which is what lets the benchmark suite run the extended setup at the
paper parameters instead of the old n_max=5 cap.
"""
from __future__ import annotations

import json
import os
import sys
import time

# allow direct invocation (python benchmarks/template_gen.py) as well as
# import through benchmarks.run
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import ART, Row
from repro.core.hardware import CORE_CONFIGS, EXT_CONFIGS
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.traces.workloads import workload_stats

MODEL = "qwen3-32b"
PHASE = "decode"
N_MAXES = (4, 5, 6)
EXT_MODEL = "llama3-70b"
EXT_N_MAXES = (5, 6)
RHO = 12.0
# the reference solver is ~16x slower at n_max=6; cap it where it stays
# cheap — the fast path is equivalence-tested against it separately
EXACT_N_MAX = 4
# container timing noise ~2x on short runs: the frontier made the core
# points 0.3-4s, so time them best-of-REPS (each repeat builds a fresh
# PlacementCache); the long ext n_max=6 point stays single-shot
REPS = 3


def _one(solver: str, n_max: int, wl, model, configs, scale: str,
         reps: int = REPS) -> dict:
    best = None
    for _ in range(reps):
        t0 = time.time()
        temps, stats = generate_templates(model, PHASE, configs, wl,
                                          n_max=n_max, rho=RHO,
                                          solver=solver)
        dt = time.time() - t0
        if best is None or dt < best[0]:
            best = (dt, temps, stats)
    dt, temps, stats = best
    return {"solver": solver, "scale": scale, "n_max": n_max, "seconds": dt,
            "reps": reps,
            "combos": stats["combos"], "templates": len(temps),
            "templates_raw": stats["templates_raw"],
            "dominated": stats.get("dominated", 0),
            "combos_per_s": stats["combos"] / max(dt, 1e-9),
            "templates_per_s": len(temps) / max(dt, 1e-9)}


def run() -> None:
    results = []
    model = PAPER_MODELS[MODEL]
    wl = workload_stats(model.trace)
    for n_max in N_MAXES:
        r = _one("fast", n_max, wl, model, CORE_CONFIGS, "core")
        results.append(r)
        us = r["seconds"] * 1e6 / max(r["combos"], 1)
        Row.add(f"template_gen_fast_nmax{n_max}", us,
                f"{r['combos_per_s']:.0f}combos/s"
                f";{r['templates_per_s']:.0f}templates/s"
                f";{r['seconds']:.1f}s")
    # reference-solver datapoint (cheap at EXACT_N_MAX) for the speedup
    # row; ~20s per repeat, so best-of-2
    r = _one("exact", EXACT_N_MAX, wl, model, CORE_CONFIGS, "core", reps=2)
    results.append(r)
    us = r["seconds"] * 1e6 / max(r["combos"], 1)
    fast_ref = next(x for x in results
                    if x["solver"] == "fast" and x["n_max"] == EXACT_N_MAX)
    speedup = r["seconds"] / max(fast_ref["seconds"], 1e-9)
    Row.add(f"template_gen_exact_nmax{EXACT_N_MAX}", us,
            f"{r['combos_per_s']:.0f}combos/s"
            f";fast_speedup={speedup:.1f}x")
    # extended 20-config setup: the search space the n_max=5 cap used to
    # hide — ~200k combos for this pair at n_max=6, mostly dominated
    ext_model = PAPER_MODELS[EXT_MODEL]
    ext_wl = workload_stats(ext_model.trace)
    for n_max in EXT_N_MAXES:
        r = _one("fast", n_max, ext_wl, ext_model, EXT_CONFIGS, "ext",
                 reps=2 if n_max < 6 else 1)
        results.append(r)
        us = r["seconds"] * 1e6 / max(r["combos"], 1)
        Row.add(f"template_gen_fast_ext_nmax{n_max}", us,
                f"{r['combos_per_s']:.0f}combos/s"
                f";dominated={r['dominated']}"
                f";{r['seconds']:.1f}s")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_template_gen.json"), "w") as f:
        json.dump({"core": {"model": MODEL, "phase": PHASE,
                            "configs": [c.name for c in CORE_CONFIGS]},
                   "ext": {"model": EXT_MODEL, "phase": PHASE,
                           "configs": [c.name for c in EXT_CONFIGS]},
                   "rho": RHO,
                   "results": results}, f, indent=1)


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_template_gen.csv"))
