"""Serving-Template generation scaling benchmark (offline stage 1).

Times ``generate_templates`` on the paper's core 12-config setup
(qwen3-32b decode — the heaviest (model, phase) of the core library) at
n_max in {4, 5, 6}, fast path vs. the reference per-combo exact solver,
and records the trajectory in ``artifacts/BENCH_template_gen.json`` so
perf regressions in the offline pipeline are caught from this PR onward.

Context: the seed per-combo solver took ~192-212s at the paper-default
n_max=6 on this container; the memoized + vectorized PlacementCache path
(repro.core.placement) brings that to ~6s while producing an identical
post-prune template set.
"""
from __future__ import annotations

import json
import os
import sys
import time

# allow direct invocation (python benchmarks/template_gen.py) as well as
# import through benchmarks.run
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)
from benchmarks.common import ART, Row
from repro.core.hardware import CORE_CONFIGS
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.traces.workloads import workload_stats

MODEL = "qwen3-32b"
PHASE = "decode"
N_MAXES = (4, 5, 6)
RHO = 12.0
# the reference solver is ~16x slower at n_max=6; cap it where it stays
# cheap — the fast path is equivalence-tested against it separately
EXACT_N_MAX = 4


def _one(solver: str, n_max: int, wl, model) -> dict:
    t0 = time.time()
    temps, stats = generate_templates(model, PHASE, CORE_CONFIGS, wl,
                                      n_max=n_max, rho=RHO, solver=solver)
    dt = time.time() - t0
    return {"solver": solver, "n_max": n_max, "seconds": dt,
            "combos": stats["combos"], "templates": len(temps),
            "templates_raw": stats["templates_raw"],
            "combos_per_s": stats["combos"] / max(dt, 1e-9),
            "templates_per_s": len(temps) / max(dt, 1e-9)}


def run() -> None:
    model = PAPER_MODELS[MODEL]
    wl = workload_stats(model.trace)
    results = []
    for n_max in N_MAXES:
        r = _one("fast", n_max, wl, model)
        results.append(r)
        us = r["seconds"] * 1e6 / max(r["combos"], 1)
        Row.add(f"template_gen_fast_nmax{n_max}", us,
                f"{r['combos_per_s']:.0f}combos/s"
                f";{r['templates_per_s']:.0f}templates/s"
                f";{r['seconds']:.1f}s")
    # reference-solver datapoint (cheap at EXACT_N_MAX) for the speedup row
    r = _one("exact", EXACT_N_MAX, wl, model)
    results.append(r)
    us = r["seconds"] * 1e6 / max(r["combos"], 1)
    fast_ref = next(x for x in results
                    if x["solver"] == "fast" and x["n_max"] == EXACT_N_MAX)
    speedup = r["seconds"] / max(fast_ref["seconds"], 1e-9)
    Row.add(f"template_gen_exact_nmax{EXACT_N_MAX}", us,
            f"{r['combos_per_s']:.0f}combos/s"
            f";fast_speedup={speedup:.1f}x")
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "BENCH_template_gen.json"), "w") as f:
        json.dump({"model": MODEL, "phase": PHASE, "rho": RHO,
                   "configs": [c.name for c in CORE_CONFIGS],
                   "results": results}, f, indent=1)


if __name__ == "__main__":
    run()
    Row.flush(os.path.join(ART, "bench_template_gen.csv"))
