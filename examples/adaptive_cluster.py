"""Adaptive multi-LLM cluster simulation: Coral's epoch loop reacting to
shifting demand and availability, with a node-failure injection
(fault-tolerance demo: the allocator re-solve replaces lost capacity).

Run:  PYTHONPATH=src python examples/adaptive_cluster.py
"""
from repro.core.allocator import AllocatorState, Demand
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.runtime.cluster import ClusterRuntime
from repro.traces.workloads import (default_base_availability,
                                    gen_availability, gen_requests,
                                    workload_stats)

models = {m: PAPER_MODELS[m] for m in ("phi4-14b", "gpt-oss-20b")}
configs = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
wls = {m: workload_stats(models[m].trace) for m in models}
lib = build_library(list(models.values()), configs, wls, n_max=3, rho=8.0)

n_epochs, epoch_s = 4, 240.0
rates = [2.0, 4.0, 6.0, 3.0]                    # shifting demand
reqs = []
for i, m in enumerate(models):
    off = 0
    for e, r in enumerate(rates):
        part = gen_requests(m, models[m].trace, r, epoch_s, seed=e * 7 + i,
                            rid0=i * 10**6 + e * 10**4)
        for q in part:
            q.arrival += e * epoch_s
        reqs += part
reqs.sort(key=lambda q: q.arrival)

base = default_base_availability(configs, abundance=40)
avail = gen_availability(CORE_REGIONS, configs, n_epochs, base, seed=1)
demands = [[Demand(m, "prefill", rates[e] * wls[m].avg_prompt)
            for m in models]
           + [Demand(m, "decode", rates[e] * wls[m].avg_output)
              for m in models]
           for e in range(n_epochs)]

# a persistent AllocatorState reuses the assembled ILP across the four
# epoch re-solves and warm-starts each from the previous solution
rt = ClusterRuntime(models, CORE_REGIONS, configs, lib, AllocatorState(),
                    wls, epoch_s=epoch_s)
res = rt.run(reqs, avail, demands, fail_rate_per_epoch=0.5, seed=0)
print(f"{'ep':>2} {'$/h':>8} {'inst':>5} {'new':>4} {'drain':>5} "
      f"{'solve(s)':>8}  goodput/model")
for e in res.epochs:
    gp = {m: round(v) for m, v in e.goodput.items()}
    print(f"{e.epoch:2d} {e.cost_per_hour:8.1f} {e.n_instances:5d} "
          f"{e.n_new:4d} {e.n_drained:5d} {e.solve_seconds:8.2f}  {gp}")
print("\nThe epoch-2 demand spike scales the cluster up; the failure "
      "injections are absorbed by the next re-solve (paper §5.1).")
