"""Adaptive control plane demo: Coral's epoch loop closed end-to-end —
demands are *estimated* from the observed arrival stream (no oracle
inputs), re-solves run only on demand-drift / availability-delta
triggers, and the transition planner warm-starts the allocator with the
cheapest-to-reach target.  The flash-crowd scenario ramps one model's
traffic x4; watch the trigger reasons react and the cluster scale.

Run:  PYTHONPATH=src python examples/adaptive_cluster.py
"""
from repro.control import (DemandEstimator, ReSolveController,
                           TransitionPlanner, make_scenario)
from repro.core.allocator import AllocatorState
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.runtime.cluster import ClusterRuntime
from repro.traces.workloads import workload_stats

models = {m: PAPER_MODELS[m] for m in ("phi4-14b", "gpt-oss-20b")}
configs = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
wls = {m: workload_stats(models[m].trace) for m in models}
lib = build_library(list(models.values()), configs, wls, n_max=3, rho=8.0)

sc = make_scenario("flash_crowd", models, CORE_REGIONS, configs, wls,
                   n_epochs=10, epoch_s=240.0, base_rate=2.0, seed=1)
rt = ClusterRuntime(models, CORE_REGIONS, configs, lib, AllocatorState(),
                    wls, epoch_s=sc.epoch_s, spot_market=sc.spot_market)
res = rt.run(sc.requests, sc.availability,
             estimator=DemandEstimator(list(models), wls),
             controller=ReSolveController(),
             planner=TransitionPlanner(lib, CORE_REGIONS, rt.init_k))

print(f"{'ep':>2} {'$/h':>8} {'inst':>5} {'new':>4} {'drain':>5} "
      f"{'solve(s)':>8} {'trigger':>13}  goodput/model")
for e in res.epochs:
    gp = {m: round(v) for m, v in e.goodput.items()}
    print(f"{e.epoch:2d} {e.cost_per_hour:8.1f} {e.n_instances:5d} "
          f"{e.n_new:4d} {e.n_drained:5d} {e.solve_seconds:8.2f} "
          f"{e.trigger_reason:>13}  {gp}")
hot = sc.meta["hot_epochs"]
print(f"\nEpochs {hot} carry the {sc.meta['target']} flash crowd: the "
      f"estimator's trend term provisions into the ramp, the drift "
      f"trigger re-solves at the peak and again on the way down, and "
      f"{sc.n_epochs - res.n_resolves()} quiet epochs skip the solver "
      f"entirely (paper §5.1).")
