"""Quickstart: Coral's two-stage optimization in ~30 seconds.

1. Offline — generate the Serving Template Library for two models on a
   heterogeneous GPU pool (placement ILP per node combination).
2. Online — solve the allocation ILP against live availability/pricing.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.allocator import AllocProblem, Demand, allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.traces.workloads import workload_stats

models = [PAPER_MODELS["phi4-14b"], PAPER_MODELS["gpt-oss-20b"]]
configs = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2, 4))
wls = {m.name: workload_stats(m.trace) for m in models}

print("=== offline: Serving Template generation (paper §4.2) ===")
t0 = time.time()
lib = build_library(models, configs, wls, n_max=4, rho=8.0)
print(f"{lib.size} templates in {time.time() - t0:.1f}s")
for (m, phase), stats in lib.stats.items():
    print(f"  {m:14s} {phase:7s}: {stats['combos']:5d} combos -> "
          f"{stats['templates']:5d} templates ({stats['seconds']:.1f}s)")

print("\n=== online: allocation ILP (paper §4.3) ===")
avail = {(r.name, c.name): 10 for r in CORE_REGIONS for c in configs}
demands = []
for m in models:
    wl = wls[m.name]
    demands.append(Demand(m.name, "prefill", 5.0 * wl.avg_prompt))
    demands.append(Demand(m.name, "decode", 5.0 * wl.avg_output))
alloc = allocate(AllocProblem(CORE_REGIONS, configs, avail, demands, lib))
print(f"cost ${alloc.cost_per_hour:.1f}/h, {alloc.total_nodes} nodes, "
      f"solved in {alloc.solve_seconds:.2f}s "
      f"({alloc.n_vars} variables), unmet={alloc.unmet or 'none'}")
for (region, key), n in sorted(alloc.instances.items()):
    t = alloc.templates[key]
    print(f"  {region:22s} {key[0]:13s} {key[1]:7s} x{n}  "
          f"{dict(t.counts)}  T={t.throughput:.0f} tok/s  "
          f"stages={t.placement.n_stages} layers={t.placement.layer_counts}")
