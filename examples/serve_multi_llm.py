"""End-to-end driver (paper kind = serving): serve TWO small models with
batched requests through real JAX engines behind a Coral-style
weighted-round-robin router, and report per-model latency/throughput.

Run:  PYTHONPATH=src python examples/serve_multi_llm.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.models import api as mapi
from repro.obs.percentiles import percentiles
from repro.serving.engine import JaxEngine

ARCHS = ["qwen2-1.5b", "glm4-9b"]
N_REQ, RATE = 16, 4.0

engines = {}
for arch in ARCHS:
    cfg = get_smoke_config(arch)
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    engines[arch] = (cfg, JaxEngine(cfg, params, max_batch=4, max_len=128))
    print(f"[init] {arch}: {cfg.n_layers}L d={cfg.d_model} (reduced)")

rng = np.random.default_rng(0)
trace = []
t = 0.0
for i in range(N_REQ * len(ARCHS)):
    t += rng.exponential(1.0 / (RATE * len(ARCHS)))
    trace.append((t, ARCHS[i % len(ARCHS)], i))

t0 = time.time()
submitted, finished, sub_t = 0, {}, {}
while len(finished) < len(trace):
    now = time.time() - t0
    while submitted < len(trace) and trace[submitted][0] <= now:
        _, arch, rid = trace[submitted]
        cfg, eng = engines[arch]
        eng.submit(rid, rng.integers(0, cfg.vocab_size,
                                     size=(int(rng.integers(8, 48)),)),
                   int(rng.integers(8, 24)))
        sub_t[rid] = (arch, time.time())
        submitted += 1
    progressed = False
    for arch, (cfg, eng) in engines.items():
        if any(eng.slots) or eng.queue:
            reqs = {s.rid: s for s in eng.slots if s is not None}
            for rid, _tok, done in eng.step():
                if done:
                    finished[rid] = reqs[rid]
            progressed = True
    if not progressed:
        time.sleep(0.004)

wall = time.time() - t0
print(f"\nserved {len(finished)} requests across {len(ARCHS)} models "
      f"in {wall:.1f}s")
for arch in ARCHS:
    rids = [r for r, (a, _) in sub_t.items() if a == arch and r in finished]
    ttft = [finished[r].prefill_done - sub_t[r][1] for r in rids]
    toks = sum(len(finished[r].out_tokens) for r in rids)
    p50, p95 = percentiles(ttft, (0.50, 0.95))
    print(f"  {arch:12s} {len(rids):3d} reqs {toks:5d} tokens "
          f"TTFT p50={p50*1e3:.0f}ms p95={p95*1e3:.0f}ms")
