"""Coral's technique applied to the assigned architectures: generate
Serving Templates for the JAX model zoo itself (dense, MoE, SSM, hybrid)
and show the phase/architecture-dependent GPU affinity the paper builds
on (§2.1) — e.g. recurrent archs keep decode throughput at long context
while full-attention archs degrade.

Run:  PYTHONPATH=src python examples/templates_for_archs.py
"""
from repro.configs.registry import get_config
from repro.core.hardware import US_EAST_2, make_node_configs
from repro.core.modelspec import from_model_config
from repro.core.templates import generate_templates
from repro.traces.workloads import workload_stats

ARCHS = ["qwen2-1.5b", "glm4-9b", "granite-moe-3b-a800m", "zamba2-1.2b",
         "xlstm-350m"]
configs = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
wl = workload_stats("burstgpt")
by_name = {c.name: c for c in configs}

print(f"{'arch':22s} {'phase':8s} {'templates':>9s} "
      f"{'best tok/s/$':>12s}  best combo")
for arch in ARCHS:
    sm = from_model_config(get_config(arch), prefill_slo_ms=1200,
                           decode_slo_ms=60, trace="burstgpt")
    for phase in ("prefill", "decode"):
        temps, stats = generate_templates(sm, phase, configs, wl,
                                          n_max=3, rho=10.0)
        if not temps:
            print(f"{arch:22s} {phase:8s} {'0':>9s}")
            continue
        best = max(temps, key=lambda t: t.throughput
                   / t.cost(US_EAST_2, by_name))
        eff = best.throughput / best.cost(US_EAST_2, by_name)
        print(f"{arch:22s} {phase:8s} {len(temps):9d} {eff:12.0f}  "
              f"{dict(best.counts)} S={best.placement.n_stages}")
print("\nRecurrent archs (zamba2, xlstm) keep O(1) decode state: their "
      "decode templates are context-length-insensitive (§2.1 affinity).")
