"""Train a small LM for a few hundred steps with checkpoint/restart.

Uses the qwen2 family at a ~13M-parameter reduced width (CPU container
scale; pass --d-model 768 --layers 12 on a real accelerator for ~100M).

Run:  PYTHONPATH=src python examples/train_small.py
"""
import argparse

from repro.configs.registry import get_smoke_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config("qwen2-1.5b").with_(
        name="qwen2-small", d_model=args.d_model, n_layers=args.layers,
        n_heads=8, n_kv_heads=2, d_ff=4 * args.d_model, vocab_size=8192)
    print(f"[example] training {cfg.name}: ~{cfg.param_count()/1e6:.1f}M "
          f"params, {args.steps} steps")
    _, _, losses = train_loop(cfg, steps_total=args.steps,
                              batch_size=args.batch, seq_len=args.seq,
                              ckpt_dir=args.ckpt_dir, ckpt_every=50,
                              resume=args.resume)
    print(f"[example] loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
