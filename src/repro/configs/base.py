"""Model configuration dataclasses shared by the model zoo, the serving
cost model, and the launch/dry-run machinery.

Every assigned architecture gets one module ``src/repro/configs/<id>.py``
exposing ``config()`` (the exact assigned shape) and ``smoke_config()``
(a reduced same-family shape used by CPU smoke tests). ``registry.py``
maps ``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope: str = "rope"               # rope | mrope | none | sinusoidal
    rope_theta: float = 1e6
    sliding_window: int = 0          # 0 -> full attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25
    # "onehot": GShard-style dispatch via one-hot einsums (reference;
    #   O(T·E·cap) memory). "sorted": argsort/scatter dispatch, linear in
    #   tokens — the §Perf beyond-paper optimization (EXPERIMENTS.md).
    moe_impl: str = "onehot"

    # --- SSM / Mamba2 ---
    ssm_state: int = 0               # N (state size per head)
    ssm_head_dim: int = 64           # P (channels per SSM head)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv: int = 4
    attn_every: int = 0              # hybrid: shared attn block after every k SSM layers

    # --- xLSTM ---
    slstm_every: int = 0             # sLSTM block at layers where (i+1) % slstm_every == 0
    mlstm_expand: float = 2.0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0                 # encoder positions (whisper-base: 1500)

    # --- VLM ---
    vision_stub: bool = False        # frontend stubbed: input provides patch embeds
    n_vision_tokens: int = 0

    # --- numerics / training ---
    dtype: str = "bfloat16"          # compute/weight dtype for dry-run
    param_dtype: str = "float32"     # master weights for training
    remat: bool = True               # activation checkpointing in train_step
    weight_sharding: str = "tp"      # tp | fsdp  (fsdp => 2-D ("data","model"))
    # decode KV layout: shard the sequence dim over "model" when the kv
    # head count cannot use it (GQA kv < TP) — attention reductions over
    # the sharded seq become scalar psums (§Perf C3)
    kv_seq_shard: bool = False

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def mlstm_d_inner(self) -> int:
        return int(self.mlstm_expand * self.d_model)

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in context length (no full KV)."""
        return self.family in ("ssm", "hybrid")

    @property
    def supports_long_context(self) -> bool:
        """sub-quadratic decode => long_500k cell runs."""
        return self.is_recurrent

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs are (or contain) decoders

    # --- parameter counting (used by cost model + roofline MODEL_FLOPS) ---
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "vlm", "audio", "moe"):
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
            if self.family == "moe":
                n_e = self.top_k if active_only else self.n_experts
                ffn = n_e * 3 * d * self.d_ff + d * self.n_experts  # router
            else:
                ffn = 3 * d * self.d_ff
            per_layer = attn + ffn + 2 * d
            total = emb + L * per_layer
            if self.is_encoder_decoder:
                enc = self.n_enc_layers * (attn + 3 * d * self.d_ff + 2 * d)
                cross = L * attn          # cross-attention in decoder
                total += enc + cross
            return total
        if self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            H = self.ssm_nheads
            mamba = d * 2 * di + di * self.ssm_conv + di * 2 * N \
                + 2 * H + di + di * d + d * di  # in/out/gate projections approx
            shared_attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d + d * self.d_ff * 3
            n_attn = L // max(self.attn_every, 1) if self.attn_every else 0
            return emb + L * (mamba + 2 * d) + (shared_attn if n_attn else 0)
        if self.family == "ssm":  # xLSTM
            di = self.mlstm_d_inner
            mlstm = d * 2 * di + 3 * di * di // max(self.n_heads, 1) + di * d + 4 * di
            slstm = 4 * d * d + 4 * d
            n_s = L // self.slstm_every if self.slstm_every else 0
            return emb + (L - n_s) * mlstm + n_s * slstm + L * 2 * d
        raise ValueError(self.family)

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes appended per generated token (0 for recurrent)."""
        if self.is_recurrent:
            n_attn = (self.n_layers // max(self.attn_every, 1)
                      if self.attn_every else 0)
        else:
            n_attn = self.n_layers
        return n_attn * 2 * self.n_kv_heads * self.resolved_head_dim * bytes_per_el

    def decode_state_bytes(self, bytes_per_el: int = 2) -> int:
        """O(1) recurrent state bytes per sequence (SSM/xLSTM)."""
        if self.family == "hybrid":
            per_layer = self.ssm_nheads * self.ssm_head_dim * self.ssm_state \
                + self.d_inner * (self.ssm_conv - 1)
            return self.n_layers * per_layer * bytes_per_el
        if self.family == "ssm":
            dh = self.mlstm_d_inner // self.n_heads
            per_m = self.n_heads * dh * dh + self.n_heads * dh
            return self.n_layers * per_m * bytes_per_el
        return 0

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# ----------------------------------------------------------------------
# Input shapes assigned to the LM family (identical for all 10 archs).
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch — O(seq^2) attention and "
                       f"{shape.seq_len}-token KV are quadratic; see DESIGN.md §4")
    return True, ""
