"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
Largest assigned arch; uses FSDPxTP ("fsdp") 2-D weight sharding so the
production dry-run fits in v5e HBM, with EP over the model axis.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        n_experts=16, top_k=4, rope="rope",
        weight_sharding="fsdp", kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=512, n_experts=4, top_k=2, dtype="float32",
        weight_sharding="tp",
    )
