"""glm4-9b — dense, RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_ff=13696, vocab_size=151552, rope="rope", qkv_bias=True,
        kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="glm4-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, dtype="float32",
    )
