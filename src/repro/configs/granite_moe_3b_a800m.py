"""granite-moe-3b-a800m — fine-grained MoE [hf:ibm-granite].

Assigned spec: 32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert)
vocab=49155, MoE 40 experts top-8. NOTE: the source model card lists 32
experts; we implement the assigned shape (40e top-8) — see DESIGN.md §4.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab_size=49155,
        n_experts=40, top_k=8, rope="rope", kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=32, vocab_size=512, n_experts=4, top_k=2, dtype="float32",
    )
