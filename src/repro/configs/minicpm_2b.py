"""minicpm-2b — dense llama-like with WSD schedule [arXiv:2404.06395].

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753. The WSD
(warmup-stable-decay) learning-rate schedule is implemented in
``repro.train.optimizer`` and selected by this config.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_ff=5760, vocab_size=122753, rope="rope", tie_embeddings=True,
        kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="minicpm-smoke", n_layers=2, d_model=72, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, dtype="float32",
    )
