"""mistral-nemo-12b — dense, 128k context [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. head_dim=128
(explicit: the real model decouples head_dim from d_model/n_heads).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=131072, head_dim=128,
        rope="rope", rope_theta=1e6, weight_sharding="fsdp",
        kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="nemo-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, dtype="float32",
        weight_sharding="tp",
    )
