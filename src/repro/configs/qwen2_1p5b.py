"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", family="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True, rope="rope",
        tie_embeddings=True, kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, dtype="float32",
    )
