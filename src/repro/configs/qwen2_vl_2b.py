"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].

Backbone only per the assignment: the vision tower is a STUB —
``input_specs()`` supplies precomputed patch embeddings
(batch, n_vision_tokens, d_model) that are prepended to the token
embeddings, and 3-component M-RoPE position ids (temporal, h, w).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
        d_ff=8960, vocab_size=151936, qkv_bias=True, rope="mrope",
        vision_stub=True, n_vision_tokens=256, tie_embeddings=True,
        kv_seq_shard=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="qwen2vl-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, n_vision_tokens=8, dtype="float32",
    )
