"""--arch <id> registry over the 10 assigned architectures."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, SHAPES, SHAPE_BY_NAME, cell_is_runnable

_ARCH_MODULES: Dict[str, str] = {
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "whisper-base": "repro.configs.whisper_base",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "glm4-9b": "repro.configs.glm4_9b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "qwen2-1.5b": "repro.configs.qwen2_1p5b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
}

ARCH_IDS: List[str] = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).smoke_config()


def all_cells():
    """Yield (arch_id, shape, runnable, skip_reason) for all 40 cells."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_is_runnable(cfg, shape)
            yield arch, shape, ok, why


__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "all_cells",
           "SHAPES", "SHAPE_BY_NAME"]
