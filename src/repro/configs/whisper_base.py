"""whisper-base — encoder-decoder audio transformer [arXiv:2212.04356].

6 encoder + 6 decoder layers, d_model=512, 8 heads, d_ff=2048,
vocab=51865. The conv audio frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings
(batch, 1500, d_model). Decoder has causal self-attention + cross
attention into the encoder output; decode shapes lower ``serve_step``.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab_size=51865,
        is_encoder_decoder=True, n_enc_layers=6, enc_seq=1500, kv_seq_shard=True,
        rope="sinusoidal", qkv_bias=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="whisper-smoke", n_layers=2, n_enc_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512, enc_seq=32,
        dtype="float32",
    )
