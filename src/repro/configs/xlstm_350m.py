"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24 recurrent layers: mLSTM (matrix memory, up-projection 2x) with an sLSTM
block at every 6th position (4 sLSTM blocks total). d_ff=0 per the assigned
spec: blocks carry their own up-projections, there is no separate FFN.
Fully recurrent => O(1) decode state, long_500k runs.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=6, mlstm_expand=2.0, rope="none",
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="xlstm-smoke", n_layers=6, d_model=64, n_heads=2, n_kv_heads=2,
        vocab_size=512, slstm_every=3, dtype="float32",
    )
