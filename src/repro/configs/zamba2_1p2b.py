"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242].

38 Mamba2 layers; a single *shared* full-attention block (one weight set)
is applied after every 6th SSM layer (6 insertion points), following the
Zamba2 shared-block design. ssm_state=64.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
        attn_every=6, rope="rope", tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().with_(
        name="zamba2-smoke", n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
        attn_every=3, dtype="float32",
    )
