"""Adaptive control plane (paper §5): closes the loop between the
simulator's observables and the online allocator.

* ``estimator`` — online per-(model, phase) demand estimation from the
  observed arrival / queue / token streams (no oracle demands).
* ``controller`` — churn-aware re-solve policy: demand-drift and
  availability-delta triggers with hysteresis + cooldown over a fixed
  cadence fallback, plus a transition planner that feeds the allocator
  the cheapest-to-reach incumbent.
* ``scenarios`` — named, seeded scenario generators (diurnal demand,
  flash crowd, popularity shift, spot-preemption storms, region
  outage), each producing (requests, availability, truth-demand).
* ``faults`` — seeded fault injection (independent crashes, correlated
  per-(region, device-family) bursts, stragglers, flaky restarts,
  stale availability feeds) plus the hardened ``RestartPolicy`` and
  the time-to-recover / goodput-lost recovery metrics.
"""
from repro.control.controller import (ControllerConfig, ReSolveController,
                                      ResolveDecision, TransitionPlanner)
from repro.control.estimator import DemandEstimator, EstimatorConfig
from repro.control.faults import (FaultConfig, FaultEvent, FaultInjector,
                                  RestartPolicy, goodput_lost,
                                  time_to_recover)
from repro.control.scenarios import (FAULT_SCENARIO_NAMES, SCENARIO_NAMES,
                                     Scenario, make_scenario)

__all__ = [
    "ControllerConfig", "DemandEstimator", "EstimatorConfig",
    "FAULT_SCENARIO_NAMES", "FaultConfig", "FaultEvent", "FaultInjector",
    "ReSolveController", "ResolveDecision", "RestartPolicy",
    "SCENARIO_NAMES", "Scenario", "TransitionPlanner", "goodput_lost",
    "make_scenario", "time_to_recover",
]
