"""Adaptive control plane (paper §5): closes the loop between the
simulator's observables and the online allocator.

* ``estimator`` — online per-(model, phase) demand estimation from the
  observed arrival / queue / token streams (no oracle demands).
* ``controller`` — churn-aware re-solve policy: demand-drift and
  availability-delta triggers with hysteresis + cooldown over a fixed
  cadence fallback, plus a transition planner that feeds the allocator
  the cheapest-to-reach incumbent.
* ``scenarios`` — named, seeded scenario generators (diurnal demand,
  flash crowd, popularity shift, spot-preemption storms, region
  outage), each producing (requests, availability, truth-demand).
"""
from repro.control.controller import (ControllerConfig, ReSolveController,
                                      ResolveDecision, TransitionPlanner)
from repro.control.estimator import DemandEstimator, EstimatorConfig
from repro.control.scenarios import SCENARIO_NAMES, Scenario, make_scenario

__all__ = [
    "ControllerConfig", "DemandEstimator", "EstimatorConfig",
    "ReSolveController", "ResolveDecision", "SCENARIO_NAMES", "Scenario",
    "TransitionPlanner", "make_scenario",
]
