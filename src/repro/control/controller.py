"""Event-driven re-solve policy + churn-aware transition planning
(paper §5.1; ShuntServe motivates the churn case: spot preemptions make
reactive re-allocation pay exactly when re-solving is most disruptive).

``ReSolveController`` decides, once per epoch, whether the allocator
ILP should run at all:

* **demand-drift trigger** — the worst symmetric relative change of any
  (model, phase) demand against the demand at the last solve;
* **availability-delta trigger** — the max of the global L1 shift and
  the worst per-(region, config) relative change of the availability
  vector against the last solve;
* both triggers are *hysteretic* (Schmitt-style: fire above the ``_up``
  threshold, re-arm only after dropping below ``_down``) and share a
  post-solve **cooldown**, so a noisy-but-stationary signal hovering at
  the threshold cannot thrash the solver;
* a fixed **cadence** fallback (``max_interval_epochs``) guarantees the
  cluster is periodically re-optimized even with no trigger.

``TransitionPlanner`` scores candidate target allocations by *reconcile
churn* — the amortized INIT_DELAY cost of instances that would be
started plus a discounted drain cost for instances that would be torn
down — and feeds the cheapest-to-reach recent target to
``AllocatorState.set_incumbent`` as the warm start, so the solver's
incumbent bound reflects the cheapest transition, not just the last
solution.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import Allocation, Demand


@dataclass
class ControllerConfig:
    drift_up: float = 0.3           # demand trigger (symmetric rel. change)
    drift_down: float = 0.1         # demand re-arm level
    avail_up: float = 0.3           # availability trigger
    avail_down: float = 0.1         # availability re-arm level
    cooldown_epochs: int = 1        # min epochs between trigger solves
    max_interval_epochs: int = 4    # cadence fallback: always re-solve
    min_nodes: float = 4.0          # ignore per-key wiggle below this
    emergency_mult: float = 2.0     # a drift this many times the trigger
    #                                 threshold bypasses the cooldown
    #                                 (storm onset/recovery, demand cliffs)
    # -- mid-epoch (event-driven) evaluation, affordable now that a
    # re-solve is sub-second: ``decide_event`` fires once the capacity
    # lost to availability events (preemptions, failed restarts) since
    # the last solve reaches ``event_loss_frac`` of the held fleet, at
    # most ``max_mid_resolves`` times per epoch and never two solves
    # closer than ``min_event_gap_s`` of simulated time
    event_loss_frac: float = 0.1
    max_mid_resolves: int = 2
    min_event_gap_s: float = 30.0


@dataclass(frozen=True)
class ResolveDecision:
    resolve: bool
    reason: str                     # initial/demand_drift/avail_delta/
    #                                 preempted/failure/cadence/
    #                                 cooldown/steady/event


class ReSolveController:
    """Per-epoch re-solve gate.  Call ``decide`` once per epoch; call
    ``notify_solved`` after every *successful* solve so the reference
    demand/availability snapshots advance."""

    def __init__(self, cfg: Optional[ControllerConfig] = None):
        self.cfg = cfg or ControllerConfig()
        # observability: a repro.obs.TraceLog (and a sim-time clock
        # callable), wired by ClusterRuntime.run; every decide() then
        # emits a "trigger" record with its reason and drift readings
        self.trace = None
        self.clock = None
        self._ref_demand: Optional[Dict[Tuple[str, str], float]] = None
        self._ref_avail: Optional[Dict[Tuple[str, str], float]] = None
        self._since = 0
        self._armed_demand = True
        self._armed_avail = True
        # mid-epoch (event-driven) state
        self._event_losses = 0
        self._mid_this_epoch = 0
        self._last_mid_t = -float("inf")

    # ----------------------------------------------------------- drifts
    def demand_drift(self, demands: Sequence[Demand]) -> float:
        """Worst symmetric relative change vs the last-solved demand:
        |d - ref| / max(d, ref) — bounded in [0, 1], so doubling and
        halving both read 0.5."""
        if self._ref_demand is None:
            return 1.0
        worst = 0.0
        for d in demands:
            ref = self._ref_demand.get((d.model, d.phase), 0.0)
            base = max(d.tokens_per_s, ref, 1e-9)
            worst = max(worst, abs(d.tokens_per_s - ref) / base)
        return worst

    def avail_delta(self, availability: Dict[Tuple[str, str], int]) -> float:
        if self._ref_avail is None:
            return 1.0
        # sorted: the union's hash order would make the float l1
        # accumulation (and thus the trigger) PYTHONHASHSEED-dependent
        keys = sorted(set(availability) | set(self._ref_avail))
        total_ref = sum(self._ref_avail.values())
        l1 = 0.0
        worst_key = 0.0
        for k in keys:
            a = float(availability.get(k, 0))
            r = float(self._ref_avail.get(k, 0))
            l1 += abs(a - r)
            if max(a, r) >= self.cfg.min_nodes:
                worst_key = max(worst_key, abs(a - r) / max(a, r))
        return max(l1 / max(total_ref, 1.0), worst_key)

    # ----------------------------------------------------------- decide
    def decide(self, epoch: int, demands: Sequence[Demand],
               availability: Dict[Tuple[str, str], int],
               n_preempted: int = 0,
               n_failed: int = 0) -> ResolveDecision:
        dec, dd, da = self._decide(demands, availability,
                                   n_preempted, n_failed)
        if self.trace is not None:
            # drift readings are null on the emergency short-circuits
            # (initial/preempted/failure), which fire before drifts
            # are evaluated
            self.trace.emit(
                "trigger",
                self.clock() if self.clock is not None else 0.0,
                epoch, resolve=dec.resolve, reason=dec.reason,
                demand_drift=dd, avail_delta=da)
        return dec

    def _decide(self, demands: Sequence[Demand],
                availability: Dict[Tuple[str, str], int],
                n_preempted: int, n_failed: int
                ) -> Tuple[ResolveDecision, Optional[float],
                           Optional[float]]:
        cfg = self.cfg
        self._since += 1
        self._mid_this_epoch = 0        # fresh mid-epoch budget
        if self._ref_demand is None:
            return ResolveDecision(True, "initial"), None, None
        if n_preempted > 0:
            # lost held capacity is an emergency: reactive re-allocation
            # (ShuntServe's case for spot churn) overrides cooldown and
            # arming — the reconcile loop cannot replace nodes whose
            # supply is gone; only a re-solve can move the capacity
            return ResolveDecision(True, "preempted"), None, None
        if n_failed > 0:
            # detected node failures get the same emergency treatment:
            # the restart path may have been blocked (backoff, budget,
            # vanished availability), so re-place the lost capacity now
            return ResolveDecision(True, "failure"), None, None
        dd = self.demand_drift(demands)
        da = self.avail_delta(availability)
        # Schmitt re-arming: a trigger that fired stays disarmed until
        # its signal falls back below the low threshold
        if dd <= cfg.drift_down:
            self._armed_demand = True
        if da <= cfg.avail_down:
            self._armed_avail = True
        fire_d = self._armed_demand and dd >= cfg.drift_up
        fire_a = self._armed_avail and da >= cfg.avail_up
        if self._since <= cfg.cooldown_epochs:
            # an extreme excursion (supply storm hitting/recovering, a
            # demand cliff) is worth a back-to-back solve; ordinary
            # trigger-level drift waits the cooldown out
            if fire_a and da >= cfg.emergency_mult * cfg.avail_up:
                self._armed_avail = False
                return ResolveDecision(True, "avail_delta"), dd, da
            if fire_d and dd >= cfg.emergency_mult * cfg.drift_up:
                self._armed_demand = False
                return ResolveDecision(True, "demand_drift"), dd, da
            return ResolveDecision(False,
                                   "cooldown" if (fire_d or fire_a)
                                   else "steady"), dd, da
        if fire_d:
            self._armed_demand = False
            return ResolveDecision(True, "demand_drift"), dd, da
        if fire_a:
            self._armed_avail = False
            return ResolveDecision(True, "avail_delta"), dd, da
        if self._since >= cfg.max_interval_epochs:
            return ResolveDecision(True, "cadence"), dd, da
        return ResolveDecision(False, "steady"), dd, da

    def decide_event(self, now: float, n_lost: int,
                     n_held: int) -> ResolveDecision:
        """Sub-epoch evaluation hook, driven by availability events.

        The runtime calls this the moment capacity is lost *inside* an
        epoch (a detected node failure, a replacement blocked by
        vanished supply) instead of waiting for the epoch edge.  Losses
        accumulate across calls; a re-solve fires once they reach
        ``event_loss_frac`` of the held fleet — throttled by the
        per-epoch ``max_mid_resolves`` budget and the
        ``min_event_gap_s`` spacing so a storm of events cannot thrash
        the solver.  ``now`` is simulated time (seconds)."""
        cfg = self.cfg
        self._event_losses += max(int(n_lost), 0)
        if self._ref_avail is None:
            # no standing solve yet: the epoch loop's "initial" decision
            # owns the first solve
            return ResolveDecision(False, "steady")
        if self._mid_this_epoch >= cfg.max_mid_resolves:
            return ResolveDecision(False, "cooldown")
        if now - self._last_mid_t < cfg.min_event_gap_s:
            return ResolveDecision(False, "cooldown")
        need = max(1.0, cfg.event_loss_frac * max(n_held, 1))
        if self._event_losses < need:
            return ResolveDecision(False, "steady")
        self._mid_this_epoch += 1
        self._last_mid_t = now
        return ResolveDecision(True, "event")

    def notify_solved(self, demands: Sequence[Demand],
                      availability: Dict[Tuple[str, str], int]):
        self._ref_demand = {(d.model, d.phase): d.tokens_per_s
                            for d in demands}
        self._ref_avail = {k: float(v) for k, v in availability.items()}
        self._since = 0
        self._event_losses = 0          # the solve absorbed the losses
        # the drift references just moved: any future excursion is fresh
        # information, so re-arm both triggers.  The Schmitt disarm
        # therefore only throttles a trigger whose solve *failed* (the
        # reference could not advance) — exactly the repeat-fire case
        # hysteresis is for.
        self._armed_demand = True
        self._armed_avail = True


class TransitionPlanner:
    """Scores candidate allocations by reconcile churn and picks the
    cheapest-to-reach one as the allocator's incumbent warm start.

    Churn from ``current`` to ``target`` counts, per (region, template):
    ``(target - current)+ * price * init_k`` for instances that must be
    started (the INIT_DELAY cost the runtime will amortize) plus
    ``(current - target)+ * price * init_k * drain_weight`` for
    instances that must drain (lost warm capacity, discounted because a
    drain finishes its in-flight work).
    """

    def __init__(self, library, regions: Sequence, init_k: float,
                 drain_weight: float = 0.5, history: int = 4):
        self._cfg = library.config_by_name
        self._region_by_name = {r.name: r for r in regions}
        self._init_k = init_k
        self._drain_weight = drain_weight
        self._max_hist = history
        self._hist: List[Dict[Tuple[str, Tuple], int]] = []
        self._tmpl: Dict[Tuple, object] = {}

    def record(self, alloc: Allocation):
        """Remember a solved target as a future transition candidate."""
        self._tmpl.update(alloc.templates)
        counts = dict(alloc.instances)
        if counts in self._hist:
            self._hist.remove(counts)
        self._hist.append(counts)
        del self._hist[:-self._max_hist]

    def _price(self, region_name: str, tkey: Tuple) -> float:
        t = self._tmpl.get(tkey)
        region = self._region_by_name.get(region_name)
        if t is None or region is None:
            return 0.0
        return t.cost(region, self._cfg)

    def churn_cost(self, target: Dict[Tuple[str, Tuple], int],
                   current: Dict[Tuple[str, Tuple], int]) -> float:
        cost = 0.0
        # sorted: float accumulation order must not depend on hash seed
        for key in sorted(set(target) | set(current)):
            tgt = target.get(key, 0)
            cur = current.get(key, 0)
            if tgt == cur:
                continue
            price = self._price(key[0], key[1])
            if tgt > cur:
                cost += (tgt - cur) * price * self._init_k
            else:
                cost += (cur - tgt) * price * self._init_k \
                    * self._drain_weight
        return cost

    def choose_incumbent(self, current: Dict[Tuple[str, Tuple], int]
                         ) -> Optional[Dict[Tuple[str, Tuple], int]]:
        """Cheapest-to-reach recent target (ties: most recent)."""
        if not self._hist:
            return None
        return min(reversed(self._hist),
                   key=lambda t: self.churn_cost(t, current))
