"""Online demand estimation (paper §5, Mélange §3: the request rate and
size mix drive the cost-optimal GPU mix, so demand must be *measured*).

``DemandEstimator`` converts the simulator's observables — the windowed
request-arrival stream (count + prompt tokens; prompt lengths are
visible at arrival), the finished-request output lengths, and the pool
queue snapshots — into the per-(model, phase) ``Demand`` rows the
allocator consumes, replacing the oracle ``demands_per_epoch`` input of
``ClusterRuntime.run``.

Per model the estimator keeps:

* a sliding window of per-sub-window arrival *rates* (req/s), sampled
  ``window_s`` apart so the quantile headroom sees burst structure
  inside an epoch, not just epoch means;
* an EWMA *level* and an EWMA *trend* (req/s per second) over those
  samples — the point forecast is ``level + trend * horizon``;
* a configurable *quantile headroom*: the estimate never falls below
  the ``headroom_q`` quantile of the recent window rates, so goodput
  targets survive bursts (monotone in ``headroom_q``, tested);
* EWMA estimates of the request *shape* (prompt tokens from arrivals,
  output tokens from finished requests; priors come from the offline
  ``WorkloadStats``);
* queued-backlog correction: standing queue tokens are spread over
  ``backlog_drain_s`` and added to demand, so accumulated shortfall is
  drained instead of ignored.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.allocator import Demand
from repro.debug import invariants as _inv


@dataclass
class EstimatorConfig:
    window_s: float = 60.0          # sub-epoch sampling window
    n_windows: int = 6              # sliding rate-sample history (short:
    #                                 it must forget a spike within ~1.5
    #                                 epochs or the headroom quantile
    #                                 pins demand at the spike level)
    level_alpha: float = 0.35       # EWMA weight of a new rate sample
    trend_alpha: float = 0.2        # EWMA weight of the level delta
    shape_alpha: float = 0.15       # EWMA weight of prompt/output means
    headroom_q: float = 0.7         # burst-headroom quantile over history
    backlog_drain_s: float = 360.0  # horizon to drain standing queues
    prior_rate: float = 1.0         # req/s per model before any sample
    min_rate: float = 0.05          # floor: never estimate a dead model


class _ModelState:
    __slots__ = ("rates", "level", "trend", "prompt_mean", "out_mean",
                 "pre_backlog", "dec_backlog")

    def __init__(self, n_windows: int, prompt_mean: float, out_mean: float):
        self.rates: deque = deque(maxlen=n_windows)
        self.level: Optional[float] = None
        self.trend = 0.0
        self.prompt_mean = float(prompt_mean)
        self.out_mean = float(out_mean)
        self.pre_backlog = 0.0
        self.dec_backlog = 0.0


class DemandEstimator:
    """Online per-(model, phase) demand estimator.

    Drive it with ``observe(sim, t0, t1)`` after each simulated epoch
    and read ``estimate(horizon_s)`` before the next allocator solve.
    ``ingest_window`` is the low-level feed (used by ``observe`` and by
    tests).  The emitted ``Demand`` list has a stable (model, phase)
    order across calls, so a persistent ``AllocatorState`` never
    rebuilds its structure between epochs.
    """

    def __init__(self, models: Sequence[str], workloads: Dict,
                 cfg: Optional[EstimatorConfig] = None):
        self.cfg = cfg or EstimatorConfig()
        self._names = list(models)
        self._st: Dict[str, _ModelState] = {
            m: _ModelState(self.cfg.n_windows, workloads[m].avg_prompt,
                           workloads[m].avg_output)
            for m in self._names}
        self._fin_cursor = 0

    # ------------------------------------------------------------- feed
    def ingest_window(self, model: str, dt: float, n_req: int,
                      prompt_tokens: float = 0.0):
        """One observation window: ``n_req`` arrivals carrying
        ``prompt_tokens`` over ``dt`` seconds."""
        st = self._st[model]
        cfg = self.cfg
        rate = n_req / max(dt, 1e-9)
        st.rates.append(rate)
        if st.level is None:
            st.level = rate
        else:
            prev = st.level
            st.level = (1 - cfg.level_alpha) * st.level \
                + cfg.level_alpha * rate
            st.trend = (1 - cfg.trend_alpha) * st.trend \
                + cfg.trend_alpha * (st.level - prev) / max(dt, 1e-9)
        if n_req > 0:
            st.prompt_mean = (1 - cfg.shape_alpha) * st.prompt_mean \
                + cfg.shape_alpha * (prompt_tokens / n_req)

    def observe(self, sim, t0: float, t1: float):
        """Fold one epoch of simulator observables into the estimate:
        sub-window arrival rates, finished-request output lengths, and
        the standing queue backlogs at ``t1``."""
        cfg = self.cfg
        nw = max(1, int(round((t1 - t0) / cfg.window_s)))
        edges = np.linspace(t0, t1, nw + 1)
        for m in self._names:
            ob = sim.obs[m]
            st = self._st[m]
            for w0, w1 in zip(edges[:-1], edges[1:]):
                n, p, _o = ob.arrival.window(float(w0), float(w1))
                self.ingest_window(m, float(w1 - w0), n, p)
            nq_p, ptok = sim.pool_backlog(m, "prefill")
            nq_d, _ = sim.pool_backlog(m, "decode")
            st.pre_backlog = float(ptok)
            st.dec_backlog = nq_d * st.out_mean
        fin = sim.finished
        for r in fin[self._fin_cursor:]:
            st = self._st.get(r.model)
            if st is not None:
                st.out_mean = (1 - cfg.shape_alpha) * st.out_mean \
                    + cfg.shape_alpha * r.output_len
        self._fin_cursor = len(fin)

    # --------------------------------------------------------- estimate
    def rate(self, model: str, horizon_s: float = 0.0,
             q: Optional[float] = None) -> float:
        """Request-rate estimate ``horizon_s`` ahead: the max of the
        trend-extrapolated EWMA level and the ``q`` quantile of the
        recent window rates (burst headroom; monotone in ``q``)."""
        st = self._st[model]
        cfg = self.cfg
        if st.level is None:
            return max(cfg.prior_rate, cfg.min_rate)
        base = max(st.level + st.trend * horizon_s, 0.0)
        head = 0.0
        if st.rates:
            head = float(np.quantile(np.asarray(st.rates),
                                     cfg.headroom_q if q is None else q))
        return max(base, head, cfg.min_rate)

    def estimate(self, horizon_s: float = 0.0) -> List[Demand]:
        """Per-(model, phase) token demand for the next interval."""
        drain = max(self.cfg.backlog_drain_s, 1.0)
        out: List[Demand] = []
        for m in self._names:
            st = self._st[m]
            r = self.rate(m, horizon_s)
            out.append(Demand(m, "prefill",
                              r * st.prompt_mean + st.pre_backlog / drain))
            out.append(Demand(m, "decode",
                              r * st.out_mean + st.dec_backlog / drain))
        if _inv.sanitize_enabled():
            _inv.check_demands(out)
        return out
