"""Seeded, composable fault-injection subsystem (ROADMAP item 4;
ThunderServe/ShuntServe motivate the fault classes: mid-tier cloud GPUs
fail in correlated bursts, degrade into stragglers, and lie about
supply — they do not flip i.i.d. coins).

``FaultInjector`` turns a ``FaultConfig`` into a deterministic
per-epoch plan of fault events over the currently live instances:

* **independent crashes** — each live instance crashes this epoch with
  ``crash_rate``, at a uniform time within the epoch;
* **correlated bursts** — with ``burst_rate`` one (region,
  device-family) failure domain loses ``burst_frac`` of its instances
  at a single instant (family = the template's primary node config, a
  proxy for shared racks/host pools of one GPU SKU);
* **stragglers** — with ``straggler_rate`` an instance serves at
  ``1/straggler_factor`` of its speed for ``straggler_duration_s``
  (iteration *and* perceived latency inflate, so degraded nodes can
  fall out of SLO);
* **flaky restarts / crash loops** — each replacement the runtime
  starts re-crashes shortly after becoming ready with
  ``restart_flake_p`` (the crash-loop fuel that makes restart backoff
  and budgets pay);
* **stale availability feed** — the solver-visible availability lags
  the true supply by ``feed_lag_epochs`` and/or fails to refresh with
  ``feed_stale_p`` (the physical market — reclaim, reconcile caps —
  always uses the truth; only the control plane is lied to).

Three independent RNG streams (plan / feed / restart) keep each fault
class reproducible in isolation: adding restarts never perturbs which
instances the next epoch's burst hits.

``RestartPolicy`` is the runtime's hardened recovery half: exponential
backoff per (region, template) crash streak plus a per-epoch restart
budget, with an availability check so replacements are never conjured
past the supply the solver saw.  The naive baseline in
``benchmarks/fault_bench.py`` is this policy with everything switched
off (instant unconditional restarts).

``time_to_recover`` / ``goodput_lost`` are the recovery-observability
helpers the benchmark gates on.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass
class FaultConfig:
    """Knobs for one composed fault process (all default to off)."""

    seed: int = 0
    # independent crashes: per-instance, per-epoch crash probability
    crash_rate: float = 0.0
    # correlated bursts: per-epoch probability that one (region,
    # device-family) domain bursts, losing burst_frac of its instances
    burst_rate: float = 0.0
    burst_frac: float = 0.6
    # stragglers: per-instance, per-epoch degradation probability
    straggler_rate: float = 0.0
    straggler_factor: float = 3.0
    straggler_duration_s: float = 300.0
    # flaky restarts: probability a replacement crashes again shortly
    # after becoming ready (crash-loop fuel)
    restart_flake_p: float = 0.0
    flake_after_s: float = 30.0
    # stale availability feed: observed supply lags truth by this many
    # epochs, and/or fails to refresh with this probability
    feed_lag_epochs: int = 0
    feed_stale_p: float = 0.0
    # fault window: crash/straggler planning fires only in epochs
    # [start_epoch, stop_epoch) — a warmed-up cluster plus a post-fault
    # tail is what makes time-to-recover measurable
    start_epoch: int = 0
    stop_epoch: int = 1_000_000_000


@dataclass(frozen=True)
class FaultEvent:
    """One planned fault: a crash or a straggler degradation."""

    t: float
    kind: str                   # "crash" | "degrade"
    inst: object                # SimInstance
    factor: float = 1.0         # degrade only
    duration_s: Optional[float] = None


def _family(inst) -> str:
    """Failure-domain device family: the template's primary config —
    instances of one GPU SKU in one region share racks/host pools."""
    counts = inst.template.counts
    return counts[0][0] if counts else "?"


class FaultInjector:
    """Deterministic fault planner.  The runtime calls, per epoch and
    in this order: ``observed_availability`` (what the solver may see)
    and ``plan_epoch`` (which instances crash/degrade when); mid-epoch
    it calls ``restart_outcome`` once per replacement it starts."""

    def __init__(self, cfg: Optional[FaultConfig] = None):
        self.cfg = cfg or FaultConfig()
        seed = self.cfg.seed
        self._rng_plan = random.Random(seed)
        self._rng_feed = random.Random(seed ^ 0x5DEECE66D)
        self._rng_restart = random.Random(seed ^ 0x9E3779B9)
        self._feed_hist: List[Dict] = []
        self._last_obs: Optional[Dict] = None
        # observability: (t, kind, instance id) of every planned fault,
        # plus an optional repro.obs.TraceLog (wired by
        # ClusterRuntime.run) that receives a "fault_inject" record per
        # planned event — emitted at PLAN time, so its ``t`` is the
        # *future* injection instant
        self.events: List[Tuple[float, str, int]] = []
        self.trace = None
        self.first_fault_t: Optional[float] = None
        self._epoch = 0                 # advanced by plan_epoch

    # ------------------------------------------------------ stale feed
    def observed_availability(self, epoch: int, true_avail: Dict) -> Dict:
        """The availability map the control plane sees this epoch —
        possibly lagged or stuck.  ``true_avail`` is never mutated; the
        caller keeps using it for the physical market."""
        cfg = self.cfg
        self._feed_hist.append(dict(true_avail))
        if epoch < cfg.start_epoch \
                or (cfg.feed_lag_epochs <= 0 and cfg.feed_stale_p <= 0.0):
            self._last_obs = self._feed_hist[-1]
            return true_avail
        if cfg.feed_stale_p > 0.0 and self._last_obs is not None \
                and self._rng_feed.random() < cfg.feed_stale_p:
            obs = self._last_obs            # feed failed to refresh
        else:
            i = max(0, len(self._feed_hist) - 1 - cfg.feed_lag_epochs)
            obs = self._feed_hist[i]
        self._last_obs = obs
        return obs

    # ------------------------------------------------------- planning
    def plan_epoch(self, epoch: int, t0: float, epoch_s: float,
                   instances: Iterable) -> List[FaultEvent]:
        """This epoch's crash/degrade events over the live instances,
        sorted by time.  Crashing an already-failed instance is a no-op
        downstream, so overlapping processes compose safely."""
        cfg = self.cfg
        self._epoch = epoch     # restart_outcome gates on the window
        if not cfg.start_epoch <= epoch < cfg.stop_epoch:
            return []
        rng = self._rng_plan
        live = sorted((i for i in instances
                       if not i.dead and not i.draining and not i.failed),
                      key=lambda i: i.iid)
        out: List[FaultEvent] = []
        if cfg.crash_rate > 0.0:
            for inst in live:
                if rng.random() < cfg.crash_rate:
                    out.append(FaultEvent(t0 + rng.random() * epoch_s,
                                          "crash", inst))
        if cfg.burst_rate > 0.0 and live \
                and rng.random() < cfg.burst_rate:
            domains: Dict[Tuple[str, str], List] = {}
            for inst in live:
                domains.setdefault((inst.region, _family(inst)),
                                   []).append(inst)
            dom = sorted(domains)[rng.randrange(len(domains))]
            members = domains[dom]
            k = max(1, int(round(cfg.burst_frac * len(members))))
            t = t0 + rng.random() * epoch_s
            for inst in rng.sample(members, k):
                out.append(FaultEvent(t, "crash", inst))
        if cfg.straggler_rate > 0.0:
            for inst in live:
                if rng.random() < cfg.straggler_rate:
                    out.append(FaultEvent(
                        t0 + rng.random() * epoch_s, "degrade", inst,
                        factor=cfg.straggler_factor,
                        duration_s=cfg.straggler_duration_s))
        out.sort(key=lambda f: (f.t, f.inst.iid, f.kind))
        for f in out:
            self.events.append((f.t, f.kind, f.inst.iid))
            if self.trace is not None:
                self.trace.emit("fault_inject", f.t, epoch,
                                fault=f.kind, iid=f.inst.iid)
            if self.first_fault_t is None:
                self.first_fault_t = f.t
        return out

    # ------------------------------------------------------- restarts
    def restart_outcome(self) -> Optional[float]:
        """Flaky-restart draw for one replacement: ``None`` when it
        comes up healthy, else the post-ready delay after which it
        crashes again.  Gated on the fault window: flaky restarts model
        a correlated cause (bad image, failing rack) that clears when
        the fault process stops, so once the window closes the tail
        measures recovery discipline — not an unbounded crash loop
        that no discipline could ever win."""
        cfg = self.cfg
        if not cfg.start_epoch <= self._epoch < cfg.stop_epoch:
            return None
        if cfg.restart_flake_p > 0.0 \
                and self._rng_restart.random() < cfg.restart_flake_p:
            return cfg.flake_after_s * (0.5 + self._rng_restart.random())
        return None


class RestartPolicy:
    """Failure-domain-aware restart discipline for ``ClusterRuntime``.

    Each detected failure asks the policy for permission (per-epoch
    ``budget`` of restarts) and a delay (exponential backoff
    ``backoff_base_s * backoff_mult**streak`` capped at
    ``backoff_max_s``, streak counted per (region, template) and reset
    at any epoch edge where that domain suffered no failure).  With
    ``check_availability`` the replacement is also bounded by the
    availability the solver saw — capacity that is gone cannot be
    conjured back.  The defaults (no backoff, effectively unlimited
    budget, availability check on) reproduce the seed's immediate
    restart, minus its conjuring bug.
    """

    def __init__(self, backoff_base_s: float = 0.0,
                 backoff_mult: float = 2.0,
                 backoff_max_s: float = 600.0,
                 budget_per_epoch: int = 1_000_000,
                 check_availability: bool = True):
        self.backoff_base_s = backoff_base_s
        self.backoff_mult = backoff_mult
        self.backoff_max_s = backoff_max_s
        self.budget_per_epoch = budget_per_epoch
        self.check_availability = check_availability
        self._streak: Dict[Tuple, int] = {}
        self._used = 0

    def begin_epoch(self, failed_keys: Sequence[Tuple] = ()):
        """Epoch edge: refill the budget; domains with no failure last
        epoch forget their crash streak (they proved stable)."""
        self._used = 0
        failed = set(failed_keys)
        for k in [k for k in self._streak if k not in failed]:
            del self._streak[k]

    def allow(self) -> bool:
        """Consume one unit of this epoch's restart budget."""
        if self._used >= self.budget_per_epoch:
            return False
        self._used += 1
        return True

    def delay(self, key: Tuple) -> float:
        """Backoff before restarting ``key``'s next replacement."""
        if self.backoff_base_s <= 0.0:
            return 0.0
        s = self._streak.get(key, 0)
        return min(self.backoff_base_s * (self.backoff_mult ** s),
                   self.backoff_max_s)

    def note_restart(self, key: Tuple):
        self._streak[key] = self._streak.get(key, 0) + 1


# ------------------------------------------------------ recovery metrics
def time_to_recover(times: Sequence[float], values: Sequence[float],
                    t_fault: float, threshold: float,
                    sustain: int = 1) -> float:
    """Seconds from ``t_fault`` until *sustained* recovery.

    The outage opens at the first sample at/after ``t_fault`` below
    ``threshold`` (a fault's dip usually starts after the fault
    instant — detection lag, queues draining — so naive first-crossing
    semantics would declare recovery on a pre-dip sample).  Recovery is
    the start of the first run of ``sustain`` consecutive samples at or
    above ``threshold`` after the onset; a terminal all-good run
    shorter than ``sustain`` (the series ended while still recovered)
    also counts, so the metric composes with bounded runs.  Later
    isolated noise dips do not re-open the fault's outage — they are
    the service's ambient variance, not the fault.  ``0`` when coverage
    never dips; ``inf`` when the series ends still below threshold."""
    pts = [(t, v) for t, v in zip(times, values) if t >= t_fault]
    onset = next((i for i, (_t, v) in enumerate(pts) if v < threshold),
                 None)
    if onset is None:
        return 0.0
    run = 0
    for i in range(onset + 1, len(pts)):
        run = run + 1 if pts[i][1] >= threshold else 0
        if run == sustain or (run > 0 and i == len(pts) - 1):
            return pts[i - run + 1][0] - t_fault
    return float("inf")


def goodput_lost(times: Sequence[float], values: Sequence[float],
                 baseline: float, t_fault: float,
                 epoch_s: float) -> float:
    """Integrated shortfall below ``baseline`` (goodput tokens, i.e.
    coverage-points x seconds) over the epochs at or after the fault."""
    return sum((baseline - v) * epoch_s
               for t, v in zip(times, values)
               if t >= t_fault and v < baseline)
