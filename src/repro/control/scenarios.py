"""Named, seeded control-plane scenarios (paper §6.4 "shifting
throughput demand and resource availability"; ROADMAP "opens a new
workload").

Each generator produces a ``Scenario`` triple — the request trace, the
per-epoch availability series, and the *truth* per-epoch demands (what
an oracle controller would feed the allocator) — plus the underlying
per-model rate schedule for reference.  Estimator-driven runs ignore
the truth demands; oracle runs consume them; both replay the identical
seeded request/availability streams, so the benchmark's comparison is
apples-to-apples.

Availability semantics: demand-side scenarios (``diurnal``,
``flash_crowd``, ``popularity_shift``) use the default bounded
availability walk with the repo's usual "we keep what we hold" reading
(the series is *free market supply on top of held nodes*).  Supply-side
scenarios (``spot_preemption``, ``region_outage``) set
``spot_market=True``: the series is the *total* reclaimable supply per
(region, config), and ``ClusterRuntime`` preempts held instances that
no longer fit (ShuntServe's stress case).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.control.faults import FaultConfig
from repro.core.allocator import Demand
from repro.traces.workloads import (Request, default_base_availability,
                                    gen_availability, gen_requests_schedule)

SCENARIO_NAMES = ("diurnal", "flash_crowd", "popularity_shift",
                  "spot_preemption", "region_outage")

# fault-injection scenarios (ROADMAP item 4): flat demand, the fault
# process is the stressor.  Kept out of SCENARIO_NAMES — they need a
# FaultInjector wired into ClusterRuntime.run to mean anything, and
# their runs are judged on time-to-recover (benchmarks/fault_bench.py),
# not the estimated-vs-oracle parities of the control_loop suite.
FAULT_SCENARIO_NAMES = ("crash_storm", "straggler", "crash_loop",
                        "stale_feed")


@dataclass
class Scenario:
    name: str
    n_epochs: int
    epoch_s: float
    requests: List[Request]
    availability: List[Dict[Tuple[str, str], int]]
    truth_demands: List[List[Demand]]
    rates: Dict[str, List[float]]           # req/s per model per epoch
    spot_market: bool = False               # availability = total supply
    meta: Dict = field(default_factory=dict)
    # fault-process knobs for a FaultInjector (None = fault-free);
    # every run of the scenario should build its own injector from
    # this so hardened/naive comparisons replay identical faults
    faults: "FaultConfig" = None


# ------------------------------------------------------ rate schedules
def _rate_schedules(name: str, models: Sequence[str], n_epochs: int,
                    base_rate: float, rng: np.random.Generator
                    ) -> Tuple[Dict[str, List[float]], Dict]:
    names = sorted(models)
    rates = {m: [base_rate] * n_epochs for m in names}
    meta: Dict = {}
    if name == "diurnal":
        # one "day" per run, per-model phase offsets (peaks disagree)
        for i, m in enumerate(names):
            phase = i / max(len(names), 1)
            rates[m] = [base_rate * (0.55 + 0.45 * np.sin(
                2 * np.pi * (e / n_epochs + phase)))
                for e in range(n_epochs)]
    elif name == "flash_crowd":
        # one model's traffic ramps x4 over an epoch, holds, ramps back
        # (real flash crowds build over minutes — a step would be
        # unreactable at epoch granularity for *any* online controller)
        target = names[0]
        peak = 4.0
        s = max(n_epochs // 3, 1)
        hold = range(s + 1, min(s + 1 + max(n_epochs // 4, 2), n_epochs))
        mult = [1.0] * n_epochs
        if s < n_epochs:
            mult[s] = (1.0 + peak) / 2.0            # ramp up
        for e in hold:
            mult[e] = peak
        if hold and hold[-1] + 1 < n_epochs:
            mult[hold[-1] + 1] = (1.0 + peak) / 2.0  # ramp down
        rates[target] = [base_rate * m for m in mult]
        meta = {"target": target, "hot_epochs": [s] + list(hold)}
    elif name == "popularity_shift":
        # traffic migrates from the first model to the last over the run
        src, dst = names[0], names[-1]
        for e in range(n_epochs):
            w = min(max((e - n_epochs / 4) / (n_epochs / 2), 0.0), 1.0)
            rates[src][e] = base_rate * (1.6 - 1.2 * w)
            rates[dst][e] = base_rate * (0.4 + 1.2 * w)
        meta = {"src": src, "dst": dst}
    elif name in ("spot_preemption", "region_outage") \
            or name in FAULT_SCENARIO_NAMES:
        pass                # supply/fault-side: rates stay flat
    else:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"choose from {SCENARIO_NAMES + FAULT_SCENARIO_NAMES}")
    return rates, meta


# --------------------------------------------------- availability paths
def _flat_supply(regions, configs, base: Dict[str, int]
                 ) -> Dict[Tuple[str, str], int]:
    return {(r.name, c.name): base.get(c.name, 0)
            for r in regions for c in configs}


def _storm_availability(regions, configs, n_epochs: int,
                        base: Dict[str, int], rng: np.random.Generator,
                        p_storm: float = 0.15,
                        depth: Tuple[float, float] = (0.0, 0.05),
                        length: Tuple[int, int] = (1, 2)):
    """Spot-preemption storms: per (region, device family), supply of
    every config of that family collapses to ``depth`` of its base for
    ``length`` epochs, then recovers.  Preemptions correlate per
    instance family in real clouds (a capacity crunch on H100s hits
    1x/2x/4x/8x alike), and family-wide storms guarantee the scenario
    stresses whichever configs the allocator actually holds.  Every
    family is hit at least once mid-run (a quiet roll injects one), so
    the scenario never degenerates into a flat-supply run."""
    flat = _flat_supply(regions, configs, base)
    out = [dict(flat) for _ in range(n_epochs)]
    storms = []
    families = sorted({(r.name, c.device.name) for r in regions
                       for c in configs})
    cfg_of = {d: [c.name for c in configs if c.device.name == d]
              for d in sorted({c.device.name for c in configs})}

    def _apply(rname, dev, e):
        d = rng.uniform(*depth)
        ln = int(rng.integers(length[0], length[1] + 1))
        for j in range(e, min(e + ln, n_epochs)):
            for cname in cfg_of[dev]:
                k = (rname, cname)
                out[j][k] = int(round(flat[k] * d))
        storms.append({"region": rname, "device": dev, "epoch": e,
                       "len": ln, "depth": round(float(d), 3)})
        return ln

    for rname, dev in families:
        e = 0
        hit = False
        while e < n_epochs:
            if rng.random() < p_storm:
                e += _apply(rname, dev, e) + 1  # family storms don't
                hit = True                      # overlap themselves
            else:
                e += 1
        if not hit and n_epochs >= 3:
            lo, hi = n_epochs // 3, max(2 * n_epochs // 3, n_epochs // 3 + 1)
            _apply(rname, dev, int(rng.integers(lo, hi)))
    return out, storms


def _outage_availability(regions, configs, n_epochs: int,
                         base: Dict[str, int]):
    """The *primary* region (cheapest mean price multiplier — where the
    allocator concentrates capacity) loses all supply mid-run."""
    flat = _flat_supply(regions, configs, base)
    out = [dict(flat) for _ in range(n_epochs)]
    devices = sorted({c.device.name for c in configs})
    victim = min(sorted(regions, key=lambda r: r.name),
                 key=lambda r: sum(r.price_mult.get(d, 1.0)
                                   for d in devices)).name
    start = n_epochs // 2
    down = list(range(start, min(start + max(n_epochs // 4, 1), n_epochs)))
    for e in down:
        for c in configs:
            out[e][(victim, c.name)] = 0
    return out, {"region": victim, "down_epochs": down}


# ------------------------------------------------------ fault processes
def _fault_config(name: str, n_epochs: int, epoch_s: float,
                  seed: int) -> FaultConfig:
    """The fault process behind each FAULT_SCENARIO_NAMES entry.  The
    fault window opens after a warm-up third of the run and (except
    for the stale feed, which lies for the whole run) closes again so
    the tail measures recovery, not steady-state attrition."""
    start = max(n_epochs // 3, 1)
    # stable full-name hash: len(name) collided for same-length
    # scenario names (crash_loop/stale_feed), giving them identical
    # fault-plan RNG streams; the "fault:" prefix keeps this stream
    # distinct from make_scenario's workload rng for the same name
    fseed = seed * 7919 + zlib.crc32(f"fault:{name}".encode())
    if name == "crash_storm":
        # one correlated (region, device-family) burst, plus light
        # independent attrition while the window is open
        return FaultConfig(seed=fseed, burst_rate=1.0, burst_frac=0.7,
                           crash_rate=0.05, start_epoch=start,
                           stop_epoch=start + 1)
    if name == "straggler":
        return FaultConfig(seed=fseed, straggler_rate=0.3,
                           straggler_factor=4.0,
                           straggler_duration_s=2.0 * epoch_s,
                           start_epoch=start, stop_epoch=start + 2)
    if name == "crash_loop":
        # heavy crashes whose replacements usually die again — restart
        # discipline fuel (a light rate never dents coverage on a
        # provisioned-with-headroom cluster, so the TTR gate would
        # measure nothing)
        return FaultConfig(seed=fseed, crash_rate=0.5,
                           restart_flake_p=0.7, flake_after_s=20.0,
                           start_epoch=start, stop_epoch=start + 3)
    if name == "stale_feed":
        return FaultConfig(seed=fseed, feed_lag_epochs=2,
                           feed_stale_p=0.3, start_epoch=1)
    raise ValueError(f"unknown fault scenario {name!r}; "
                     f"choose from {FAULT_SCENARIO_NAMES}")


# -------------------------------------------------------------- builder
def make_scenario(name: str, models: Dict, regions: Sequence,
                  configs: Sequence, workloads: Dict, *,
                  n_epochs: int = 12, epoch_s: float = 240.0,
                  base_rate: float = 2.0, abundance: float = 24.0,
                  seed: int = 0) -> Scenario:
    """Build one named scenario over the given (models, regions,
    configs) universe.  Deterministic in ``seed``."""
    # stable full-name hash: the old len(name) term seeded same-length
    # scenario names (flash_crowd/crash_storm) with identical streams
    rng = np.random.default_rng(seed * 7919 + zlib.crc32(name.encode()))
    rates, meta = _rate_schedules(name, list(models), n_epochs,
                                  base_rate, rng)
    base = default_base_availability(configs, abundance=abundance)
    # the stale-feed scenario only bites when supply actually moves
    # under the lying feed: run it on the spot-preemption storm market
    spot = name in ("spot_preemption", "region_outage", "stale_feed")
    faults = None
    if name in FAULT_SCENARIO_NAMES:
        faults = _fault_config(name, n_epochs, epoch_s, seed)
        meta = {"faults": name, "start_epoch": faults.start_epoch,
                "stop_epoch": faults.stop_epoch}
    if name in ("spot_preemption", "stale_feed"):
        avail, storms = _storm_availability(regions, configs, n_epochs,
                                            base, rng)
        meta["storms"] = storms
    elif name == "region_outage":
        avail, meta = _outage_availability(regions, configs, n_epochs, base)
    else:
        avail = gen_availability(regions, configs, n_epochs, base,
                                 seed=seed * 13 + 1)

    reqs: List[Request] = []
    for i, m in enumerate(sorted(models)):
        reqs += gen_requests_schedule(
            m, models[m].trace, rates[m], epoch_s,
            seed=seed * 101 + i * 17 + 3, rid0=i * 100_000_000)
    reqs.sort(key=lambda r: r.arrival)

    truth = []
    for e in range(n_epochs):
        row = []
        for m in sorted(models):
            wl = workloads[m]
            r = rates[m][e]
            row.append(Demand(m, "prefill", r * wl.avg_prompt))
            row.append(Demand(m, "decode", r * wl.avg_output))
        truth.append(row)
    return Scenario(name, n_epochs, epoch_s, reqs, avail, truth, rates,
                    spot_market=spot, meta=meta, faults=faults)
