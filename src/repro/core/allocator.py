"""Online resource allocation ILP (paper §4.3).

Decision vars: integer v_r(tau) = #Serving Instances of template tau in
region r; continuous I_r(tau) >= (v - v')·p_r(tau)·K models the
initialization penalty charged only on newly added instances.
Constraints: per-(region, config) availability; per-(model, phase)
throughput demand. Objective: provisioning cost + init penalty
(+ big-M shortfall slack so scarce-availability instances always return
a best-effort allocation instead of INFEASIBLE — mirroring §6.4 where
methods are compared by how much demand they actually satisfy).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig, Region
from repro.core.templates import ServingTemplate, TemplateLibrary
from repro.solver.milp import MilpModel


@dataclass(frozen=True)
class Demand:
    model: str
    phase: str
    tokens_per_s: float


@dataclass
class AllocProblem:
    regions: Sequence[Region]
    configs: Sequence[NodeConfig]
    availability: Dict[Tuple[str, str], int]      # (region, config) -> nodes
    demands: Sequence[Demand]
    library: TemplateLibrary
    current: Dict[Tuple[str, Tuple], int] = field(default_factory=dict)
    init_penalty_k: float = 0.1                    # K (init time / interval)
    time_limit: float = 60.0
    max_templates_per_demand: int = 1200           # solver-scaling knob


@dataclass
class Allocation:
    instances: Dict[Tuple[str, Tuple], int]        # (region, template.key) -> n
    templates: Dict[Tuple, ServingTemplate]        # template.key -> template
    cost_per_hour: float
    init_penalty: float
    unmet: Dict[Tuple[str, str], float]            # (model, phase) -> tok/s
    solve_seconds: float
    n_vars: int
    ok: bool

    @property
    def total_nodes(self) -> int:
        return sum(self.templates[k].n_nodes * n
                   for (_, k), n in self.instances.items())

    def served(self, model: str, phase: str) -> float:
        return sum(self.templates[k].throughput * n
                   for (_, k), n in self.instances.items()
                   if k[0] == model and k[1] == phase)


def allocate(p: AllocProblem) -> Allocation:
    t0 = time.time()
    cfg_by_name = p.library.config_by_name
    mdl = MilpModel()

    v_vars: Dict[Tuple[str, Tuple], int] = {}
    i_vars: Dict[Tuple[str, Tuple], int] = {}
    tmpl_by_key: Dict[Tuple, ServingTemplate] = {}
    avail_rows: Dict[Tuple[str, str], Dict[int, float]] = {}
    demand_rows: Dict[Tuple[str, str], Dict[int, float]] = {}
    shortfall_pen: Dict[Tuple[str, str], float] = {}

    for dem in p.demands:
        temps = p.library.get(dem.model, dem.phase)
        if not temps:
            continue
        # var-count cap: keep the 2-D (cost, throughput) Pareto frontier
        # first — the solver needs cheap low-throughput templates to match
        # demand tightly, not just the best $/tok/s — then fill by
        # cost-efficiency.
        if len(temps) > p.max_templates_per_demand:
            # hoist per-template min-region cost into one usage x price
            # matmul instead of a per-sort-key loop over regions
            cnames = sorted({c for t in temps for c, _ in t.counts})
            cidx = {c: i for i, c in enumerate(cnames)}
            usage = np.zeros((len(temps), len(cnames)))
            for i, t in enumerate(temps):
                for c, n in t.counts:
                    usage[i, cidx[c]] = n
            price = np.array([[r.node_usd_per_hour(cfg_by_name[c])
                               for c in cnames] for r in p.regions])
            mc = (usage @ price.T).min(axis=1)
            mincost = {t.key: mc[i] for i, t in enumerate(temps)}
            by_cost = sorted(temps, key=lambda t: (mincost[t.key],
                                                   -t.throughput))
            frontier, best_t = [], -1.0
            for t in by_cost:
                if t.throughput > best_t:
                    frontier.append(t)
                    best_t = t.throughput
            chosen = dict.fromkeys(frontier[:p.max_templates_per_demand])
            if len(chosen) < p.max_templates_per_demand:
                def eff(t):
                    return mincost[t.key] / max(t.throughput, 1e-9)
                for t in sorted(temps, key=eff):
                    if len(chosen) >= p.max_templates_per_demand:
                        break
                    chosen.setdefault(t)
            temps = list(chosen)
        dkey = (dem.model, dem.phase)
        demand_rows[dkey] = {}
        # shortfall penalty: ~100x the worst $/tok/s so meeting demand wins
        worst = max(t.cost(r, cfg_by_name) / max(t.throughput, 1e-9)
                    for t in temps for r in p.regions)
        shortfall_pen[dkey] = 100.0 * worst

        for region in p.regions:
            for t in temps:
                usage = t.usage()
                ub = min((p.availability.get((region.name, c), 0) // n
                          for c, n in usage.items() if n > 0), default=0)
                ub = min(ub, int(np.ceil(dem.tokens_per_s
                                         / max(t.throughput, 1e-9))) + 1)
                if ub <= 0:
                    continue
                price = t.cost(region, cfg_by_name)
                key = (region.name, t.key)
                v = mdl.add_var(obj=price, ub=ub, integer=True)
                v_vars[key] = v
                tmpl_by_key[t.key] = t
                # init penalty: I >= (v - v_cur) * price * K
                cur = p.current.get(key, 0)
                iv = mdl.add_var(obj=1.0, lb=0.0)
                i_vars[key] = iv
                mdl.add_constr({v: price * p.init_penalty_k, iv: -1.0},
                               ub=price * p.init_penalty_k * cur)
                for c, n in usage.items():
                    avail_rows.setdefault((region.name, c), {})[v] = float(n)
                demand_rows[dkey][v] = demand_rows[dkey].get(v, 0.0) \
                    + float(t.throughput)

    # availability constraints
    for (rname, cname), coeffs in avail_rows.items():
        mdl.add_constr(coeffs, ub=float(p.availability.get((rname, cname), 0)))
    # demand constraints with a *coupled per-model* shortfall fraction
    # s_m in [0,1] (the paper has a single T_m per model, §3: a request
    # not prefilled is never decoded, so phase shortfalls move together)
    model_slack = {}
    for dem in p.demands:
        m = dem.model
        if m not in model_slack:
            pen = sum(shortfall_pen.get((d.model, d.phase), 1e5)
                      * d.tokens_per_s for d in p.demands if d.model == m)
            model_slack[m] = mdl.add_var(obj=pen, lb=0.0, ub=1.0)
        coeffs = dict(demand_rows.get((m, dem.phase), {}))
        coeffs[model_slack[m]] = dem.tokens_per_s
        mdl.add_constr(coeffs, lb=dem.tokens_per_s)

    res = mdl.solve(time_limit=p.time_limit, gap=1e-4)
    if not res.ok:
        return Allocation({}, {}, np.inf, 0.0,
                          {(d.model, d.phase): d.tokens_per_s
                           for d in p.demands},
                          time.time() - t0, mdl.n, False)

    instances = {}
    cost = init_pen = 0.0
    for key, v in v_vars.items():
        n = int(round(res.x[v]))
        if n > 0:
            instances[key] = n
            t = tmpl_by_key[key[1]]
            region = next(r for r in p.regions if r.name == key[0])
            cost += n * t.cost(region, cfg_by_name)
            init_pen += res.x[i_vars[key]]
    unmet = {}
    for dem in p.demands:
        s = res.x[model_slack[dem.model]]
        if s > 1e-6:
            unmet[(dem.model, dem.phase)] = float(s * dem.tokens_per_s)
    return Allocation(instances, tmpl_by_key, cost, init_pen, unmet,
                      time.time() - t0, mdl.n, True)
