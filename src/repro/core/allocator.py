"""Online resource allocation ILP (paper §4.3) — columnar pipeline.

Decision vars: integer v_r(tau) = #Serving Instances of template tau in
region r; continuous I_r(tau) >= (v - v')·p_r(tau)·K models the
initialization penalty charged only on newly added instances.
Constraints: per-(region, config) availability; per-(model, phase)
throughput demand. Objective: provisioning cost + init penalty
(+ big-M shortfall slack so scarce-availability instances always return
a best-effort allocation instead of INFEASIBLE — mirroring §6.4 where
methods are compared by how much demand they actually satisfy).

Two assembly paths build the same model:

* ``allocate_reference`` — the seed per-var path (one ``add_var`` /
  ``add_constr`` Python call per (region, template) pair).  Kept as the
  equivalence oracle; at 20-config/6-model scale its *build* time
  dominates the HiGHS solve.
* ``AllocatorState`` (and the ``allocate`` convenience wrapper) — the
  columnar path.  Template sets are consumed as ``LibraryColumns``
  arrays (usage matrix, throughput vector, per-region cost from one
  ``usage @ price.T`` matmul); the Pareto/var-cap selection, shortfall
  penalties and per-var bounds are vectorized; and the whole constraint
  matrix is assembled once as COO triplets fed straight into
  ``scipy.sparse``/HiGHS via ``MilpModel.add_vars`` /
  ``add_constrs_coo``.

``AllocatorState`` persists *across epochs*: the assembled structure
(variable layout, COO pattern, selection) is reused, and each re-solve
only rewrites availability bounds, demand right-hand sides and
``current`` counts.  The previous epoch's solution — clamped to the new
availability and greedily repaired to feasibility — seeds the solve as
an *incumbent*: its objective value is a valid upper bound, so
``v <= floor(z_inc / price)`` prunes dominated variables and
``s <= z_inc / penalty`` tightens the shortfall big-M before HiGHS
runs; if the solver fails or times out, the incumbent is returned as a
best-effort fallback (``Allocation.fallback``) instead of draining the
cluster.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig, Region
from repro.debug import invariants as _inv
from repro.core.templates import (LibraryColumns, ServingTemplate,
                                  TemplateLibrary)
from repro.solver import decompose as _dec
from repro.solver.milp import MilpModel

MIP_GAP = 1e-4
# acceptance gap of the fast tiers (decomposed / rounded-LP): a tier's
# solution is only returned when its objective provably sits within
# this relative gap of a valid lower bound on the monolithic optimum —
# otherwise the solve escalates (the "lossless escape hatch")
ACCEPT_GAP = 5e-4


@dataclass(frozen=True)
class Demand:
    model: str
    phase: str
    tokens_per_s: float


@dataclass
class AllocProblem:
    regions: Sequence[Region]
    configs: Sequence[NodeConfig]
    availability: Dict[Tuple[str, str], int]      # (region, config) -> nodes
    demands: Sequence[Demand]
    library: TemplateLibrary
    current: Dict[Tuple[str, Tuple], int] = field(default_factory=dict)
    init_penalty_k: float = 0.1                    # K (init time / interval)
    time_limit: float = 60.0
    max_templates_per_demand: int = 1200           # solver-scaling knob
    # solve-path selector: "auto" runs the three-tier ladder
    # (decomposed -> rounded LP -> monolithic MIP, escalating only when
    # a tier cannot certify its objective within ACCEPT_GAP); the other
    # values force a single tier (benchmarks, tests, A/B comparisons)
    solve_mode: str = "auto"       # auto|decomposed|rounded_lp|monolithic


@dataclass
class Allocation:
    instances: Dict[Tuple[str, Tuple], int]        # (region, template.key) -> n
    templates: Dict[Tuple, ServingTemplate]        # template.key -> template
    cost_per_hour: float
    init_penalty: float
    unmet: Dict[Tuple[str, str], float]            # (model, phase) -> tok/s
    solve_seconds: float
    n_vars: int
    ok: bool
    objective: float = np.nan                      # full MILP objective
    build_seconds: float = 0.0                     # model assembly (excl. solve)
    fallback: bool = False                         # incumbent returned on failure
    solve_path: str = "monolithic"                 # tier that produced it
    solver_seconds: float = 0.0                    # pure solver time
    extract_seconds: float = 0.0                   # solution extraction

    @property
    def total_nodes(self) -> int:
        return sum(self.templates[k].n_nodes * n
                   for (_, k), n in self.instances.items())

    def served(self, model: str, phase: str) -> float:
        return sum(self.templates[k].throughput * n
                   for (_, k), n in self.instances.items()
                   if k[0] == model and k[1] == phase)


# --------------------------------------------------------------- selection
def select_template_indices(cost: np.ndarray, thr: np.ndarray,
                            cap: int) -> np.ndarray:
    """Vectorized var-count cap: 2-D (cost, throughput) Pareto frontier
    first — the solver needs cheap low-throughput templates to match
    demand tightly, not just the best $/tok/s — then fill by
    cost-efficiency.  ``cost`` is the (T, R) per-region cost matrix,
    ``thr`` the (T,) throughput vector; returns kept indices."""
    n = len(thr)
    if n <= cap:
        return np.arange(n)
    mincost = cost.min(axis=1)
    # stable sort by (mincost, -throughput), then a running-max scan:
    # a template is on the frontier iff it is strictly faster than
    # every cheaper-or-equal template before it
    order = np.lexsort((-thr, mincost))
    thr_sorted = thr[order]
    prev_max = np.concatenate(([-np.inf],
                               np.maximum.accumulate(thr_sorted)[:-1]))
    frontier = order[thr_sorted > prev_max]
    chosen = frontier[:cap]
    if len(chosen) < cap:
        picked = np.zeros(n, dtype=bool)
        picked[chosen] = True
        eff_order = np.argsort(mincost / np.maximum(thr, 1e-9),
                               kind="stable")
        fill = eff_order[~picked[eff_order]][:cap - len(chosen)]
        chosen = np.concatenate([chosen, fill])
    return chosen


def availability_caps(avail_mat: np.ndarray,
                      usage: np.ndarray) -> np.ndarray:
    """(R, n) max instances per region: min over used configs of
    floor(available nodes / nodes per instance).  Shared by the Coral
    columnar allocator and the Cauchy baseline."""
    with np.errstate(divide="ignore", invalid="ignore"):
        per_cfg = np.where(usage > 0,
                           np.floor(avail_mat[:, None, :] / usage),
                           np.inf)                          # (R, n, C)
    return per_cfg.min(axis=2)


def availability_row_index(usage_blocks: Sequence[np.ndarray],
                           n_regions: int, n_cfg: int):
    """Row layout of the per-(region, used-config) availability
    constraints: a (R, C) row-id matrix (-1 for unused configs) plus
    the region/config index arrays of each row, in row order.  Shared
    by the Coral columnar allocator and the Cauchy baseline."""
    used = np.zeros(n_cfg, dtype=bool)
    for u in usage_blocks:
        used |= (u > 0).any(axis=0)
    used_idx = np.nonzero(used)[0]
    row_of = -np.ones((n_regions, n_cfg), dtype=np.int64)
    rix, cix = [], []
    for r in range(n_regions):
        for c in used_idx:
            row_of[r, c] = len(rix)
            rix.append(r)
            cix.append(int(c))
    return row_of, np.array(rix, dtype=np.int64), \
        np.array(cix, dtype=np.int64)


def availability_row_coo(usage: np.ndarray, base: int, n_regions: int,
                         row_of: np.ndarray):
    """COO triplet segments tying one pair block's region-major vars
    into the per-(region, config) availability rows."""
    nz_t, nz_c = np.nonzero(usage)
    vals = usage[nz_t, nz_c]
    n = usage.shape[0]
    d, r, c = [], [], []
    for reg in range(n_regions):
        d.append(vals)
        r.append(row_of[reg, nz_c])
        c.append(base + reg * n + nz_t)
    return d, r, c


@dataclass
class _PairBlock:
    """Static per-(model, phase) slice of the assembled structure."""
    model: str
    phase: str
    cols: LibraryColumns           # identity-checked for staleness
    sel: np.ndarray                # indices into cols arrays
    base: int                      # first v-var index of this pair
    thr: np.ndarray                # (n,) selected throughput
    cost: np.ndarray               # (n, R) selected per-region cost
    usage: np.ndarray              # (n, C) selected usage
    templates: List[ServingTemplate]
    keys: List[Tuple]              # template keys, selection order
    key_local: Dict[Tuple, int]    # template key -> local index

    @property
    def n(self) -> int:
        return len(self.sel)


class AllocatorState:
    """Persistent cross-epoch columnar allocator (callable AllocatorFn).

    The first call assembles the full structure from ``LibraryColumns``;
    later calls with the same shape (regions, demand keys, library,
    caps) reuse it and only rewrite bounds/RHS — plus the incumbent
    warm-start described in the module docstring.  Any shape change
    triggers a transparent rebuild.
    """

    def __init__(self, max_templates_per_demand: Optional[int] = None):
        self._cap_override = max_templates_per_demand
        self._sig = None
        self._prev_x: Optional[np.ndarray] = None
        self._pending_inc: Optional[Dict[Tuple[str, Tuple], int]] = None

    # -------------------------------------------------------- incumbent
    def set_incumbent(self, counts: Optional[Dict[Tuple[str, Tuple], int]]):
        """Seed the *next* solve's warm start from an external target —
        e.g. the churn-scored cheapest-to-reach allocation picked by
        ``repro.control.controller.TransitionPlanner`` — instead of the
        previous solution.  ``counts`` maps (region name, template key)
        to instance counts; entries whose template is not in the
        current selection are ignored.  Pass ``None`` to clear."""
        self._pending_inc = dict(counts) if counts else None

    def _counts_to_x(self, counts: Dict[Tuple[str, Tuple], int]
                     ) -> np.ndarray:
        x = np.zeros(self._V, dtype=np.int64)
        for (rname, tkey), n in counts.items():
            pb = self._pair_by_mp.get((tkey[0], tkey[1]))
            r = self._region_idx.get(rname)
            loc = pb.key_local.get(tkey) if pb is not None else None
            if r is not None and loc is not None:
                x[pb.base + r * pb.n + loc] = n
        return x

    # ------------------------------------------------------------- build
    def _signature(self, p: AllocProblem):
        return (
            tuple((r.name, tuple(sorted(r.price_mult.items())))
                  for r in p.regions),
            tuple((d.model, d.phase) for d in p.demands),
            self._cap_override or p.max_templates_per_demand,
            p.init_penalty_k,
            id(p.library),
        )

    def _stale(self, p: AllocProblem) -> bool:
        if self._sig != self._signature(p):
            return True
        # library content may have changed in place: columns() returns a
        # cached object per (model, phase), so identity is a freshness
        # check; pairs that were empty at build time must re-check too
        # (lib.add may have filled them since)
        for pb, dem in zip(self._pairs, p.demands):
            cols = p.library.columns(dem.model, dem.phase)
            if (cols is not pb.cols) if pb is not None else (cols.n > 0):
                return True
        return False

    def _build(self, p: AllocProblem) -> None:
        cap = self._cap_override or p.max_templates_per_demand
        regions = list(p.regions)
        R = len(regions)
        self._regions = regions
        self._pairs: List[Optional[_PairBlock]] = []
        self._pen: Dict[Tuple[str, str], float] = {}
        V = 0
        for dem in p.demands:
            cols = p.library.columns(dem.model, dem.phase)
            if cols.n == 0:
                self._pairs.append(None)
                continue
            cost_all = cols.region_cost(regions)
            sel = select_template_indices(cost_all, cols.throughput, cap)
            thr = cols.throughput[sel]
            cost = cost_all[sel]
            keys = [cols.keys[i] for i in sel]
            pb = _PairBlock(dem.model, dem.phase, cols, sel, V, thr, cost,
                            cols.usage[sel],
                            [cols.templates[i] for i in sel], keys,
                            {k: i for i, k in enumerate(keys)})
            # shortfall penalty: ~100x the worst $/tok/s so meeting
            # demand wins
            self._pen[(dem.model, dem.phase)] = \
                100.0 * float((cost / np.maximum(thr, 1e-9)[:, None]).max())
            self._pairs.append(pb)
            V += pb.n * R
        self._V = V
        self._cap = cap
        self._k = p.init_penalty_k
        if V == 0:                       # no templates for any demand
            self._sig = self._signature(p)
            self._prev_x = None
            return

        # variable metadata (region-major inside each pair block); var
        # index = pb.base + r * pb.n + local — no per-var Python objects
        v_obj = np.empty(V)
        tmpl_by_key: Dict[Tuple, ServingTemplate] = {}
        for pb in self._pairs:
            if pb is None:
                continue
            tmpl_by_key.update(zip(pb.keys, pb.templates))
            # region-major ravel: [r0 templates..., r1 templates..., ...]
            v_obj[pb.base:pb.base + pb.n * R] = pb.cost.T.ravel()
        self._v_obj = v_obj
        self._tmpl_by_key = tmpl_by_key
        self._pair_by_mp = {(pb.model, pb.phase): pb
                            for pb in self._pairs if pb is not None}
        self._pair_list = [pb for pb in self._pairs if pb is not None]
        self._pair_bases = np.array([pb.base for pb in self._pair_list])
        self._region_idx = {r.name: i for i, r in enumerate(regions)}

        # slack vars: one shortfall fraction per model (first-occurrence
        # order), shared across phases (§3: a request not prefilled is
        # never decoded, so phase shortfalls move together)
        self._slack_models: List[str] = []
        for dem in p.demands:
            if dem.model not in self._slack_models:
                self._slack_models.append(dem.model)
        self._slack_of = {m: 2 * V + i
                          for i, m in enumerate(self._slack_models)}
        self._M = len(self._slack_models)

        # config column universe (library-wide, sorted)
        some = next(pb for pb in self._pairs if pb is not None)
        cnames = some.cols.config_names
        self._cnames = cnames
        self._cfg_idx = {c: i for i, c in enumerate(cnames)}
        self._n_cfg = len(cnames)

        # availability rows: one per (region, config) used by any
        # selected template; the integer index arrays make the RHS a
        # single fancy-index per epoch
        row_of, self._avail_rix, self._avail_cix = availability_row_index(
            [pb.usage for pb in self._pairs if pb is not None],
            R, self._n_cfg)
        n_avail = len(self._avail_rix)

        n_dem = len(p.demands)
        self._n_dem = n_dem
        # row layout: [init (V)] [avail (n_avail)] [demand (n_dem)]
        self._n_rows = V + n_avail + n_dem

        # ---- static COO segments -------------------------------------
        seg_d, seg_r, seg_c = [], [], []
        # init penalty rows: price*K*v - I <= price*K*cur
        ar = np.arange(V)
        seg_d += [v_obj * p.init_penalty_k, -np.ones(V)]
        seg_r += [ar, ar]
        seg_c += [ar, ar + V]
        # availability rows (also kept separately, 0-based, for the
        # incumbent-repair CSR)
        av_d, av_r, av_c = [], [], []
        for pb in self._pairs:
            if pb is None:
                continue
            d, r_, c_ = availability_row_coo(pb.usage, pb.base, R, row_of)
            av_d += d
            av_r += r_
            av_c += c_
        seg_d += av_d
        seg_r += [a + V for a in av_r]
        seg_c += av_c
        # demand rows (var entries)
        for di, pb in enumerate(self._pairs):
            if pb is None:
                continue
            seg_d.append(np.tile(pb.thr, R))
            seg_r.append(np.full(pb.n * R, V + n_avail + di,
                                 dtype=np.int64))
            seg_c.append(pb.base + np.arange(pb.n * R))
        # demand rows (slack entries, rewritten each epoch) — LAST so
        # they occupy the data array's tail
        slack_cols = np.array(
            [self._slack_of[d.model] for d in p.demands], dtype=np.int64)
        seg_d.append(np.zeros(n_dem))
        seg_r.append(V + n_avail + np.arange(n_dem))
        seg_c.append(slack_cols)

        self._coo_data = np.concatenate(seg_d)
        self._coo_rows = np.concatenate(seg_r)
        self._coo_cols = np.concatenate(seg_c)

        # 0-based availability COO, reused by the decomposed tier
        # (DecomposeProblem) and the rounding tier's slack accounting
        self._av_coo = (
            np.concatenate(av_d) if av_d else np.zeros(0),
            np.concatenate(av_r) if av_r else np.zeros(0, dtype=np.int64),
            np.concatenate(av_c) if av_c else np.zeros(0, dtype=np.int64))
        # column-major layout (data, rows, indptr over vars) so the
        # greedy rounding fill can query one var's availability rows
        c_ord = np.argsort(self._av_coo[2], kind="stable")
        self._av_csc = (
            self._av_coo[0][c_ord], self._av_coo[1][c_ord],
            np.searchsorted(self._av_coo[2][c_ord], np.arange(V + 1)))

        # sparse availability matrix for incumbent repair
        try:
            from scipy import sparse
            self._A_avail = sparse.csr_matrix(
                (self._av_coo[0], (self._av_coo[1], self._av_coo[2])),
                shape=(n_avail, V))
        except Exception:                              # pragma: no cover
            self._A_avail = None

        self._sig = self._signature(p)
        self._prev_x = None

    # ------------------------------------------------------- epoch solve
    def _epoch_arrays(self, p: AllocProblem):
        """Availability / demand / current-dependent arrays."""
        R = len(self._regions)
        avail = np.zeros((R, self._n_cfg))
        for (rname, cname), nodes in p.availability.items():
            r = self._region_idx.get(rname)
            c = self._cfg_idx.get(cname)
            if r is not None and c is not None:
                avail[r, c] = nodes
        v_ub = np.empty(self._V)
        tokens = np.array([d.tokens_per_s for d in p.demands])
        for di, pb in enumerate(self._pairs):
            if pb is None:
                continue
            dem_cap = np.ceil(tokens[di] / np.maximum(pb.thr, 1e-9)) + 1
            ub = np.minimum(availability_caps(avail, pb.usage), dem_cap)
            v_ub[pb.base:pb.base + pb.n * R] = ub.ravel()
        v_ub = np.maximum(v_ub, 0.0)

        cur = self._counts_to_x(p.current).astype(float)

        # per-model slack penalty: sum over the model's demands of
        # pen(dkey) * tokens (missing pairs default to 1e5, as seed)
        pen_vec = np.zeros(self._M)
        for di, d in enumerate(p.demands):
            m = self._slack_of[d.model] - 2 * self._V
            pen_vec[m] += self._pen.get((d.model, d.phase), 1e5) \
                * d.tokens_per_s
        return avail, v_ub, cur, tokens, pen_vec

    def _incumbent(self, v_ub: np.ndarray, cur: np.ndarray,
                   tokens: np.ndarray, pen_vec: np.ndarray,
                   avail_rhs: np.ndarray):
        """Clamp the previous solution to the new bounds, repair
        availability feasibility greedily, and return (x, z_inc).

        Requires the repair matrix: per-var clamping alone cannot fix a
        *joint* (region, config) availability violation, and an
        infeasible incumbent would make z_inc an invalid bound.
        """
        x = np.minimum(self._prev_x.astype(float), v_ub)
        A = self._A_avail
        usage = A @ x
        for i in np.nonzero(usage > avail_rhs + 1e-9)[0]:
            lo, hi = A.indptr[i], A.indptr[i + 1]
            idx = A.indices[lo:hi]
            coef = A.data[lo:hi]
            s = float(usage[i])
            # drop the most expensive instances first
            order = np.argsort(-self._v_obj[idx], kind="stable")
            for k in order:
                if s <= avail_rhs[i] + 1e-9:
                    break
                v = idx[k]
                if x[v] <= 0:
                    continue
                dec = min(x[v], np.ceil((s - avail_rhs[i]) / coef[k]))
                x[v] -= dec
                s -= dec * coef[k]
            usage = A @ x
        cost = float(self._v_obj @ x)
        init_pen = float(np.maximum(0.0, x - cur) @ self._v_obj) * self._k
        z = cost + init_pen
        s_inc = np.zeros(self._M)
        for di, pb in enumerate(self._pairs):
            if pb is None:
                served = 0.0
            else:
                R = len(self._regions)
                served = float(np.tile(pb.thr, R)
                               @ x[pb.base:pb.base + pb.n * R])
            if tokens[di] > 1e-12:
                frac = max(0.0, 1.0 - served / tokens[di])
                m = self._dem_model_idx[di]
                s_inc[m] = max(s_inc[m], frac)
        z += float(pen_vec @ s_inc)
        return x, s_inc, z

    # --------------------------------------------------- decomposed tier
    def _decompose_problem(self, v_ub: np.ndarray, cur: np.ndarray,
                           tokens: np.ndarray, pen_vec: np.ndarray,
                           avail_rhs: np.ndarray) -> "_dec.DecomposeProblem":
        """Mirror this epoch's arrays as a ``DecomposeProblem``: one
        ``RowSpec`` per (model, phase) demand (empty for pairs with no
        templates — their forced full shortfall must still be priced),
        grouped into per-model ``ModelSpec``s by slack index."""
        R = len(self._regions)
        rows_by_m: List[List[_dec.RowSpec]] = [[] for _ in range(self._M)]
        e = np.zeros(0)
        for di, pb in enumerate(self._pairs):
            m = self._dem_model_idx[di]
            if pb is None:
                rows_by_m[m].append(_dec.RowSpec(
                    np.zeros(0, dtype=np.int64), e, e, e, e,
                    float(tokens[di])))
                continue
            lo, hi = pb.base, pb.base + pb.n * R
            rows_by_m[m].append(_dec.RowSpec(
                np.arange(lo, hi), self._v_obj[lo:hi], np.tile(pb.thr, R),
                v_ub[lo:hi], cur[lo:hi], float(tokens[di])))
        models = [_dec.ModelSpec(i, rows, float(pen_vec[i]))
                  for i, rows in enumerate(rows_by_m)]
        d, r, c = self._av_coo
        return _dec.DecomposeProblem(self._V, models, self._k, d, r, c,
                                     avail_rhs.astype(float))

    def _round_lp(self, xv_lp: np.ndarray, v_ub: np.ndarray,
                  dp: "_dec.DecomposeProblem", avail_rhs: np.ndarray,
                  tokens: np.ndarray) -> np.ndarray:
        """Round the LP relaxation down (always availability-feasible),
        then re-fill each demand's deficit in two greedy phases.

        Phase 1 bulk-fills in marginal cost-efficiency (cost/token)
        order but never overshoots the target: an LP vertex routinely
        serves a small demand with a tiny *fraction* of one huge
        template, and "one whole instance of the most efficient
        column" can over-provision such a pair by orders of magnitude.
        Phase 2 closes the sub-instance residual by the cheapest
        *total* addition — min over columns of ceil(residual/thr)*cost
        — which picks the small cheap instance the MIP would.  Both
        phases cap takes by the remaining availability slack of every
        config row the candidate column touches."""
        R = len(self._regions)
        v = np.clip(np.floor(xv_lp + 1e-6), 0.0, v_ub)
        slack = (avail_rhs - dp.usage(v)).astype(float)
        dcs, rcs, indptr = self._av_csc

        def room_of(j):
            r = v_ub[j] - v[j]
            a, b_ = indptr[j], indptr[j + 1]
            if b_ > a:
                r = min(r, float(np.min(slack[rcs[a:b_]] / dcs[a:b_])))
            return float(np.floor(r + 1e-9))

        def apply(j, take, thr_j):
            v[j] += take
            a, b_ = indptr[j], indptr[j + 1]
            if b_ > a:
                slack[rcs[a:b_]] -= take * dcs[a:b_]
            return take * thr_j

        for di, pb in enumerate(self._pairs):
            if pb is None:
                continue
            lo, hi = pb.base, pb.base + pb.n * R
            thr = np.tile(pb.thr, R)
            deficit = tokens[di] - float(thr @ v[lo:hi])
            if deficit <= 1e-9:
                continue
            cost = self._v_obj[lo:hi]
            eff = np.argsort(cost / np.maximum(thr, 1e-12), kind="stable")
            # phase 1: bulk fill, rounding the take *down* (no overshoot)
            for jl in eff:
                if deficit <= 1e-9:
                    break
                if thr[jl] <= 1e-12:
                    continue
                j = lo + int(jl)
                take = min(room_of(j), np.floor(deficit / thr[jl] + 1e-9))
                if take >= 1.0:
                    deficit -= apply(j, take, thr[jl])
            # phase 2: close the residual at minimum total cost
            while deficit > 1e-9:
                best_jl, best_tot, best_take = -1, np.inf, 0.0
                part_jl, part_take = -1, 0.0
                for jl in eff:
                    if thr[jl] <= 1e-12:
                        continue
                    room = room_of(lo + int(jl))
                    if room < 1.0:
                        continue
                    need = np.ceil(deficit / thr[jl])
                    if room >= need:
                        tot = need * cost[jl]
                        if tot < best_tot - 1e-12:
                            best_jl, best_tot, best_take = int(jl), tot, need
                    elif part_jl < 0:
                        # most efficient partial cover as a last resort
                        part_jl, part_take = int(jl), room
                if best_jl >= 0:
                    jl, take = best_jl, best_take
                elif part_jl >= 0:
                    jl, take = part_jl, part_take
                else:
                    break               # supply exhausted: leave shortfall
                deficit -= apply(lo + jl, take, thr[jl])
        return v

    def _finish(self, xv, xi, xs, objective, tokens, cur, p, t0,
                n_vars, solver_s, path, fallback=False) -> Allocation:
        """Common epilogue of every successful tier: extract, stamp the
        solve-path/time breakdown, advance the warm start, sanitize."""
        # corallint: disable=D1 - build/extract-seconds telemetry only
        build_s = time.time() - t0 - solver_s
        te = time.time()    # corallint: disable=D1 - telemetry only
        alloc = self._extract(xv, xi, xs, tokens, cur, p, t0, n_vars,
                              build_s)
        # corallint: disable=D1 - telemetry only
        alloc.extract_seconds = time.time() - te
        alloc.solver_seconds = solver_s
        alloc.solve_path = path
        alloc.objective = objective
        alloc.fallback = fallback
        self._prev_x = np.rint(np.asarray(xv)).astype(np.int64)
        if not fallback and _inv.sanitize_enabled():
            # CORAL_SANITIZE=1: a successful solve must honor the
            # availability constraint it was handed — on *every* tier
            _inv.check_allocation(alloc, p.availability)
        return alloc

    def solve(self, p: AllocProblem) -> Allocation:
        # corallint: disable=D1 - build/solve-seconds telemetry only
        t0 = time.time()
        if self._sig is None or self._stale(p):
            self._build(p)
        V = self._V
        if V == 0:
            # an external incumbent has no meaning for an empty model —
            # drop it rather than let it leak into a later solve
            self._pending_inc = None
            unmet = {(d.model, d.phase): d.tokens_per_s for d in p.demands}
            # corallint: disable=D1 - solve-seconds telemetry only
            return Allocation({}, {}, 0.0, 0.0, unmet, time.time() - t0,
                              0, True, objective=0.0)
        M = self._M
        self._dem_model_idx = [self._slack_of[d.model] - 2 * V
                               for d in p.demands]
        if self._pending_inc is not None:
            # externally chosen (churn-scored) warm start overrides the
            # previous solution; it is clamped/repaired like any other
            # incumbent before its bound is trusted
            self._prev_x = self._counts_to_x(self._pending_inc)
            self._pending_inc = None
        avail, v_ub, cur, tokens, pen_vec = self._epoch_arrays(p)
        avail_rhs = self._avail_rhs(avail)

        # epoch rewrites into the static COO structure
        n_dem = self._n_dem
        self._coo_data[-n_dem:] = tokens
        row_lb = np.full(self._n_rows, -np.inf)
        row_ub = np.full(self._n_rows, np.inf)
        row_ub[:V] = self._v_obj * self._k * cur
        row_ub[V:V + len(avail_rhs)] = avail_rhs
        row_lb[V + len(avail_rhs):] = tokens

        # incumbent warm-start: prune + tighten with the previous
        # epoch's (clamped, repaired) solution
        s_ub = np.ones(M)
        inc = None
        if self._prev_x is not None and self._A_avail is not None:
            x_inc, s_inc, z_inc = self._incumbent(
                v_ub, cur, tokens, pen_vec, avail_rhs)
            inc = (x_inc, s_inc, z_inc)
            margin = z_inc * (1.0 + 1e-9) + 1e-9
            v_ub = np.minimum(
                v_ub, np.floor(margin / np.maximum(self._v_obj, 1e-12)))
            s_ub = np.minimum(s_ub,
                              margin / np.maximum(pen_vec, 1e-12))

        mode = p.solve_mode
        deadline = t0 + max(p.time_limit, 0.0)
        solver_s = 0.0
        n_vars_full = 2 * V + M
        # best feasible candidate so far: (v, s, honest objective) —
        # seeds warm starts downward and is the fallback of last resort
        best = inc

        # ---- tier 1: per-model price-coordinated decomposition -------
        dp = None
        if mode in ("auto", "decomposed"):
            dp = self._decompose_problem(v_ub, cur, tokens, pen_vec,
                                         avail_rhs)
            prev = self._prev_x.astype(float) \
                if self._prev_x is not None else None
            # corallint: disable=D1 - tier time budget only
            rem = max(deadline - time.time(), min(p.time_limit, 1.0))
            try:
                dres = _dec.solve_decomposed(dp, prev_v=prev,
                                             accept_gap=ACCEPT_GAP,
                                             time_limit=rem)
            except Exception:
                # same degradation discipline as the solvers below: a
                # crashing tier escalates, it never raises upward
                dres = _dec.DecomposeResult(False, False, None, None)
            solver_s += dres.seconds
            if dres.ok and dres.objective < (best[2] if best else np.inf):
                best = (dres.v, dres.s, dres.objective)
            if dres.ok and (dres.certified or mode == "decomposed"):
                return self._finish(dres.v, None, dres.s, dres.objective,
                                    tokens, cur, p, t0, n_vars_full,
                                    solver_s, "decomposed")

        if mode != "decomposed":
            if best is not None and best is not inc:
                # a fast-tier candidate cheaper than the incumbent
                # re-tightens the bound pruning before assembly
                margin = best[2] * (1.0 + 1e-9) + 1e-9
                v_ub = np.minimum(v_ub, np.floor(
                    margin / np.maximum(self._v_obj, 1e-12)))
                s_ub = np.minimum(s_ub,
                                  margin / np.maximum(pen_vec, 1e-12))
            mdl = MilpModel()
            mdl.add_vars(self._v_obj, 0.0, v_ub, True)          # v
            mdl.add_vars(np.ones(V), 0.0, np.inf, False)        # I
            mdl.add_vars(pen_vec, 0.0, s_ub, False)             # s_m
            mdl.add_constrs_coo(self._coo_data, self._coo_rows,
                                self._coo_cols, lb=row_lb, ub=row_ub)

            # ---- tier 2: LP relaxation + greedy rounding -------------
            if mode in ("auto", "rounded_lp"):
                if dp is None:
                    dp = self._decompose_problem(v_ub, cur, tokens,
                                                 pen_vec, avail_rhs)
                # corallint: disable=D1 - tier time budget only
                rem = max(deadline - time.time(), min(p.time_limit, 1.0))
                try:
                    lp = mdl.solve(time_limit=rem, gap=MIP_GAP,
                                   relax=True)
                except Exception:
                    lp = None
                if lp is not None:
                    # failed solves still burn solver time (HiGHS
                    # presolve is not interruptible): always count it,
                    # or it leaks into the assembly metric
                    solver_s += lp.seconds
                if lp is not None and lp.ok:
                    v_r = self._round_lp(lp.x[:V], v_ub, dp, avail_rhs,
                                         tokens)
                    z_r, s_r = _dec._honest(dp, v_r)
                    if z_r < (best[2] if best else np.inf):
                        best = (v_r, s_r, z_r)
                    # the LP optimum is a valid lower bound on the MIP:
                    # certify only when rounding provably lost < gap
                    z_lp = lp.dual_bound if lp.dual_bound is not None \
                        else lp.obj
                    if (z_r - z_lp) <= ACCEPT_GAP * max(abs(z_lp), 1e-9) \
                            or mode == "rounded_lp":
                        return self._finish(v_r, None, s_r, z_r, tokens,
                                            cur, p, t0, n_vars_full,
                                            solver_s, "rounded_lp")

            # ---- tier 3: monolithic MIP, warm-started ----------------
            if mode in ("auto", "monolithic"):
                x0 = None
                if best is not None:
                    bv = np.rint(best[0])
                    x0 = np.concatenate([
                        bv,
                        self._k * self._v_obj * np.maximum(0.0, bv - cur),
                        best[1]])
                # corallint: disable=D1 - tier time budget only
                rem = max(deadline - time.time(), min(p.time_limit, 1.0))
                try:
                    res = mdl.solve(time_limit=rem, gap=MIP_GAP,
                                    incumbent=x0)
                except Exception:
                    res = None
                if res is not None:
                    solver_s += res.seconds     # count failures too
                if res is not None and res.ok:
                    return self._finish(res.x[:V], res.x[V:2 * V],
                                        res.x[2 * V:], res.obj, tokens,
                                        cur, p, t0, n_vars_full,
                                        solver_s, "monolithic")

        # ---- degradation ladder: every tier failed or timed out ------
        if best is not None:
            return self._finish(best[0], None, best[1], best[2], tokens,
                                cur, p, t0, n_vars_full, solver_s,
                                "fallback", fallback=True)
        t_now = time.time()     # corallint: disable=D1 - telemetry only
        return Allocation({}, {}, np.inf, 0.0,
                          {(d.model, d.phase): d.tokens_per_s
                           for d in p.demands},
                          t_now - t0, n_vars_full, False,
                          build_seconds=t_now - t0 - solver_s,
                          solve_path="fallback", solver_seconds=solver_s)

    def _avail_rhs(self, avail: np.ndarray) -> np.ndarray:
        return avail[self._avail_rix, self._avail_cix]

    def _extract(self, xv, xi, xs, tokens, cur, p, t0, n_vars,
                 build_s) -> Allocation:
        counts = np.rint(xv).astype(np.int64)
        nz = np.nonzero(counts > 0)[0]
        instances = {}
        for i in nz:
            pb = self._pair_list[
                int(np.searchsorted(self._pair_bases, i, side="right")) - 1]
            r, loc = divmod(int(i) - pb.base, pb.n)
            instances[(self._regions[r].name, pb.keys[loc])] = int(counts[i])
        cost = float(self._v_obj[nz] @ counts[nz])
        if xi is not None:
            init_pen = float(np.sum(xi[nz]))
        else:
            init_pen = float(np.maximum(0.0, counts - cur)[nz]
                             @ self._v_obj[nz]) * self._k
        unmet = {}
        for di, d in enumerate(p.demands):
            s = float(xs[self._dem_model_idx[di]])
            if s > 1e-6:
                unmet[(d.model, d.phase)] = s * tokens[di]
        return Allocation(instances, dict(self._tmpl_by_key), cost,
                          # corallint: disable=D1 - telemetry only
                          init_pen, unmet, time.time() - t0, n_vars, True,
                          build_seconds=build_s)

    __call__ = solve


def allocate(p: AllocProblem) -> Allocation:
    """One-shot columnar allocation (fresh ``AllocatorState``).

    Epoch loops should hold an ``AllocatorState`` instead, to reuse the
    assembled structure and the incumbent warm-start across re-solves.
    """
    return AllocatorState()(p)


# ------------------------------------------------------- reference path
def allocate_reference(p: AllocProblem) -> Allocation:
    """Seed per-var assembly — the equivalence oracle for the columnar
    path (same model, one Python call per variable/row)."""
    # corallint: disable=D1 - build/solve-seconds telemetry only
    t0 = time.time()
    cfg_by_name = p.library.config_by_name
    mdl = MilpModel()

    v_vars: Dict[Tuple[str, Tuple], int] = {}
    i_vars: Dict[Tuple[str, Tuple], int] = {}
    tmpl_by_key: Dict[Tuple, ServingTemplate] = {}
    avail_rows: Dict[Tuple[str, str], Dict[int, float]] = {}
    demand_rows: Dict[Tuple[str, str], Dict[int, float]] = {}
    shortfall_pen: Dict[Tuple[str, str], float] = {}

    for dem in p.demands:
        temps = p.library.get(dem.model, dem.phase)
        if not temps:
            continue
        # var-count cap: keep the 2-D (cost, throughput) Pareto frontier
        # first — the solver needs cheap low-throughput templates to match
        # demand tightly, not just the best $/tok/s — then fill by
        # cost-efficiency.
        if len(temps) > p.max_templates_per_demand:
            # hoist per-template min-region cost into one usage x price
            # matmul instead of a per-sort-key loop over regions
            cnames = sorted({c for t in temps for c, _ in t.counts})
            cidx = {c: i for i, c in enumerate(cnames)}
            usage = np.zeros((len(temps), len(cnames)))
            for i, t in enumerate(temps):
                for c, n in t.counts:
                    usage[i, cidx[c]] = n
            price = np.array([[r.node_usd_per_hour(cfg_by_name[c])
                               for c in cnames] for r in p.regions])
            mc = (usage @ price.T).min(axis=1)
            mincost = {t.key: mc[i] for i, t in enumerate(temps)}
            by_cost = sorted(temps, key=lambda t: (mincost[t.key],
                                                   -t.throughput))
            frontier, best_t = [], -1.0
            for t in by_cost:
                if t.throughput > best_t:
                    frontier.append(t)
                    best_t = t.throughput
            chosen = dict.fromkeys(frontier[:p.max_templates_per_demand])
            if len(chosen) < p.max_templates_per_demand:
                def eff(t):
                    return mincost[t.key] / max(t.throughput, 1e-9)
                for t in sorted(temps, key=eff):
                    if len(chosen) >= p.max_templates_per_demand:
                        break
                    chosen.setdefault(t)
            temps = list(chosen)
        dkey = (dem.model, dem.phase)
        demand_rows[dkey] = {}
        # shortfall penalty: ~100x the worst $/tok/s so meeting demand wins
        worst = max(t.cost(r, cfg_by_name) / max(t.throughput, 1e-9)
                    for t in temps for r in p.regions)
        shortfall_pen[dkey] = 100.0 * worst

        for region in p.regions:
            for t in temps:
                usage = t.usage()
                ub = min((p.availability.get((region.name, c), 0) // n
                          for c, n in usage.items() if n > 0), default=0)
                ub = min(ub, int(np.ceil(dem.tokens_per_s
                                         / max(t.throughput, 1e-9))) + 1)
                if ub <= 0:
                    continue
                price = t.cost(region, cfg_by_name)
                key = (region.name, t.key)
                # corallint: disable=S1 - sanctioned per-var oracle
                v = mdl.add_var(obj=price, ub=ub, integer=True)
                v_vars[key] = v
                tmpl_by_key[t.key] = t
                # init penalty: I >= (v - v_cur) * price * K
                cur = p.current.get(key, 0)
                # corallint: disable=S1 - sanctioned per-var oracle
                iv = mdl.add_var(obj=1.0, lb=0.0)
                i_vars[key] = iv
                # corallint: disable=S1 - sanctioned per-var oracle
                mdl.add_constr({v: price * p.init_penalty_k, iv: -1.0},
                               ub=price * p.init_penalty_k * cur)
                for c, n in usage.items():
                    avail_rows.setdefault((region.name, c), {})[v] = float(n)
                demand_rows[dkey][v] = demand_rows[dkey].get(v, 0.0) \
                    + float(t.throughput)

    # availability constraints (insertion-ordered build dict; the
    # per-var oracle path is sanctioned, see allocate_reference doc)
    # corallint: disable=D1,S1 - sanctioned per-var oracle
    for (rname, cname), coeffs in avail_rows.items():
        # corallint: disable=S1 - sanctioned per-var oracle
        mdl.add_constr(coeffs, ub=float(p.availability.get((rname, cname), 0)))
    # demand constraints with a *coupled per-model* shortfall fraction
    # s_m in [0,1] (the paper has a single T_m per model, §3: a request
    # not prefilled is never decoded, so phase shortfalls move together)
    model_slack = {}
    for dem in p.demands:
        m = dem.model
        if m not in model_slack:
            pen = sum(shortfall_pen.get((d.model, d.phase), 1e5)
                      * d.tokens_per_s for d in p.demands if d.model == m)
            # corallint: disable=S1 - sanctioned per-var oracle
            model_slack[m] = mdl.add_var(obj=pen, lb=0.0, ub=1.0)
        coeffs = dict(demand_rows.get((m, dem.phase), {}))
        coeffs[model_slack[m]] = dem.tokens_per_s
        # corallint: disable=S1 - sanctioned per-var oracle
        mdl.add_constr(coeffs, lb=dem.tokens_per_s)

    # corallint: disable=D1 - build-seconds telemetry only
    build_s = time.time() - t0
    res = mdl.solve(time_limit=p.time_limit, gap=MIP_GAP)
    if not res.ok:
        return Allocation({}, {}, np.inf, 0.0,
                          {(d.model, d.phase): d.tokens_per_s
                           for d in p.demands},
                          # corallint: disable=D1 - telemetry only
                          time.time() - t0, mdl.n, False,
                          build_seconds=build_s)

    region_by_name = {r.name: r for r in p.regions}
    instances = {}
    cost = init_pen = 0.0
    for key, v in v_vars.items():
        n = int(round(res.x[v]))
        if n > 0:
            instances[key] = n
            t = tmpl_by_key[key[1]]
            region = region_by_name[key[0]]
            cost += n * t.cost(region, cfg_by_name)
            init_pen += res.x[i_vars[key]]
    unmet = {}
    for dem in p.demands:
        s = res.x[model_slack[dem.model]]
        if s > 1e-6:
            unmet[(dem.model, dem.phase)] = float(s * dem.tokens_per_s)
    return Allocation(instances, tmpl_by_key, cost, init_pen, unmet,
                      # corallint: disable=D1 - telemetry only
                      time.time() - t0, mdl.n, True, objective=res.obj,
                      build_seconds=build_s)
