"""Baselines from the paper's evaluation (§6.1, §6.6).

* Homo — SkyServe/SageServe-style: each replica on homogeneous hardware
  (heterogeneity only across replicas); greedily instantiates the most
  cost-efficient (throughput per USD) homogeneous template per model, in
  isolation, consuming availability in sequence.
* Cauchy — per-model ILP over homogeneous-per-replica templates with
  phase-specific GPU combos (prefill and decode pools may differ; a
  prefill replica may feed multiple decode replicas), cost-efficiency in
  the objective, still no cross-model coordination.
* Helix-style — single-model monolithic placement over a *fixed* node
  pool: all nodes in one PP x DP pipeline, stages grouped by device type
  (an approximation of Helix's max-flow placement; DESIGN.md §7).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.allocator import (MIP_GAP, Allocation, AllocProblem, Demand,
                                  availability_caps, availability_row_coo,
                                  availability_row_index)
from repro.core.hardware import NodeConfig, Region
from repro.core.modelspec import ServedModel
from repro.core.placement import (Placement, PlacementCache,
                                  optimal_placement_exact)
from repro.core.profiles import ProfileTable, WorkloadStats
from repro.core.templates import (ServingTemplate, TemplateLibrary,
                                  generate_templates)
from repro.solver.milp import MilpModel


def homo_library(models: Sequence[ServedModel], configs: Sequence[NodeConfig],
                 workloads: Dict[str, WorkloadStats], n_max: int = 6,
                 rho: float = 12.0) -> TemplateLibrary:
    """Template library restricted to single-config-type combinations.

    Goes through the same fast placement path as ``build_library``: one
    ``PlacementCache`` per (model, phase) is shared across the per-config
    sub-universes, so the homogeneous stage groups (k identical nodes
    under a given S) are solved once each.
    """
    lib = TemplateLibrary(config_by_name={c.name: c for c in configs})
    by_name = {c.name: c for c in configs}
    for m in models:
        wl = workloads[m.name]
        for phase in ("prefill", "decode"):
            slo = m.prefill_slo_ms if phase == "prefill" else m.decode_slo_ms
            pt = ProfileTable(m, phase, slo, wl)
            cache = PlacementCache(
                lambda n, S, _pt=pt: _pt.table(by_name[n], S), m.n_layers)
            temps: List[ServingTemplate] = []
            for c in configs:
                t, _ = generate_templates(m, phase, [c], wl, n_max=n_max,
                                          rho=rho, prune=True, cache=cache)
                temps.extend(t)
            lib.add((m.name, phase), temps, {"homo": True})
    return lib


def _consume(avail: Dict, region: str, t: ServingTemplate, n: int):
    for c, k in t.counts:
        avail[(region, c)] -= k * n


def _max_instances(avail: Dict, region: str, t: ServingTemplate) -> int:
    return min((avail.get((region, c), 0) // k for c, k in t.counts
                if k > 0), default=0)


def homo_allocate(p: AllocProblem, lib: TemplateLibrary) -> Allocation:
    """Greedy per-model best cost-efficiency homogeneous allocation."""
    avail = dict(p.availability)
    cfg = lib.config_by_name
    instances: Dict[Tuple[str, Tuple], int] = {}
    tmpl: Dict[Tuple, ServingTemplate] = {}
    cost = 0.0
    unmet: Dict[Tuple[str, str], float] = {}
    for dem in p.demands:
        left = dem.tokens_per_s
        cands = []
        for t in lib.get(dem.model, dem.phase):
            for r in p.regions:
                price = t.cost(r, cfg)
                cands.append((price / max(t.throughput, 1e-9), r, t))
        cands.sort(key=lambda x: x[0])
        for _, r, t in cands:
            if left <= 1e-9:
                break
            n = min(_max_instances(avail, r.name, t),
                    int(np.ceil(left / t.throughput)))
            if n <= 0:
                continue
            _consume(avail, r.name, t, n)
            instances[(r.name, t.key)] = instances.get((r.name, t.key), 0) + n
            tmpl[t.key] = t
            cost += n * t.cost(r, cfg)
            left -= n * t.throughput
        if left > 1e-6:
            unmet[(dem.model, dem.phase)] = left
    return Allocation(instances, tmpl, cost, 0.0, unmet, 0.0, 0, True)


def cauchy_allocate(p: AllocProblem, lib: TemplateLibrary) -> Allocation:
    """Per-model ILP over homogeneous templates (phases jointly, models
    sequentially — cost efficiency in the objective, no cross-model
    coordination).

    Assembled columnar: each (model, phase) block comes straight from
    ``lib.columns()`` arrays (per-region cost via one ``usage @
    price.T`` matmul, vectorized availability/demand caps) and is
    appended to the MILP via the batched ``add_vars`` /
    ``add_constrs_coo`` APIs — no per-variable Python loop.
    """
    regions = list(p.regions)
    R = len(regions)
    avail = dict(p.availability)
    instances: Dict[Tuple[str, Tuple], int] = {}
    tmpl: Dict[Tuple, ServingTemplate] = {}
    total_cost = 0.0
    unmet: Dict[Tuple[str, str], float] = {}
    models = sorted({d.model for d in p.demands})
    for mname in models:
        dems = [d for d in p.demands if d.model == mname]
        mdl = MilpModel()
        blocks = []                 # (dem, cols, cost (T,R), base)
        V = 0
        for dem in dems:
            cols = lib.columns(dem.model, dem.phase)
            if cols.n == 0:
                blocks.append((dem, None, None, V))
                continue
            cost = cols.region_cost(regions)
            blocks.append((dem, cols, cost, V))
            V += cols.n * R
        if V == 0:
            for dem in dems:
                unmet[(dem.model, dem.phase)] = dem.tokens_per_s
            continue
        cnames = next(c for _, c, _, _ in blocks
                      if c is not None).config_names
        C = len(cnames)
        avail_mat = np.zeros((R, C))
        for r in range(R):
            for ci, cn in enumerate(cnames):
                avail_mat[r, ci] = avail.get((regions[r].name, cn), 0)

        v_obj = np.empty(V)
        v_ub = np.empty(V)
        v_keys: List[Tuple[str, Tuple]] = [None] * V
        coo_d, coo_r, coo_c = [], [], []
        for dem, cols, cost, base in blocks:
            if cols is None:
                continue
            n = cols.n
            dem_cap = np.ceil(dem.tokens_per_s
                              / np.maximum(cols.throughput, 1e-9)) + 1
            caps = np.maximum(np.minimum(
                availability_caps(avail_mat, cols.usage), dem_cap), 0)
            for t in cols.templates:
                tmpl[t.key] = t
            for r in range(R):
                lo = base + r * n
                v_obj[lo:lo + n] = cost[:, r]
                v_ub[lo:lo + n] = caps[r]
                rname = regions[r].name
                for i, t in enumerate(cols.templates):
                    v_keys[lo + i] = (rname, t.key)

        # availability rows, one per (region, used config)
        row_of, a_rix, a_cix = availability_row_index(
            [cols.usage for _, cols, _, _ in blocks if cols is not None],
            R, C)
        avail_rhs = avail_mat[a_rix, a_cix]
        for dem, cols, cost, base in blocks:
            if cols is None:
                continue
            d, r_, c_ = availability_row_coo(cols.usage, base, R, row_of)
            coo_d += d
            coo_r += r_
            coo_c += c_
        n_avail = len(avail_rhs)

        # demand rows: served + s >= tokens, shortfall penalized at
        # ~100x the worst $/tok/s of the model's own template pool
        s_obj, s_ub, dem_rhs = [], [], []
        for di, (dem, cols, cost, base) in enumerate(blocks):
            if cols is not None:
                worst = float((cost / np.maximum(
                    cols.throughput, 1e-9)[:, None]).max())
                s_obj.append(100.0 * worst)
                coo_d.append(np.tile(cols.throughput, R))
                coo_r.append(np.full(cols.n * R, n_avail + di,
                                     dtype=np.int64))
                coo_c.append(base + np.arange(cols.n * R))
            else:
                s_obj.append(1e5)
            s_ub.append(dem.tokens_per_s)
            dem_rhs.append(dem.tokens_per_s)
            coo_d.append(np.ones(1))
            coo_r.append(np.array([n_avail + di]))
            coo_c.append(np.array([V + di]))

        mdl.add_vars(v_obj, 0.0, v_ub, True)
        mdl.add_vars(np.array(s_obj), 0.0, np.array(s_ub), False)
        row_lb = np.concatenate([np.full(n_avail, -np.inf),
                                 np.array(dem_rhs)])
        row_ub = np.concatenate([np.array(avail_rhs),
                                 np.full(len(dems), np.inf)])
        mdl.add_constrs_coo(np.concatenate(coo_d), np.concatenate(coo_r),
                            np.concatenate(coo_c), lb=row_lb, ub=row_ub)
        res = mdl.solve(time_limit=p.time_limit, gap=MIP_GAP)
        if not res.ok:
            for dem in dems:
                unmet[(dem.model, dem.phase)] = dem.tokens_per_s
            continue
        counts = np.rint(res.x[:V]).astype(np.int64)
        for i in np.nonzero(counts > 0)[0]:
            rname, tkey = v_keys[i]
            t = tmpl[tkey]
            n = int(counts[i])
            _consume(avail, rname, t, n)
            instances[(rname, tkey)] = instances.get((rname, tkey), 0) + n
            total_cost += n * float(v_obj[i])
        for di, dem in enumerate(dems):
            s = res.x[V + di]
            if s > 1e-6:
                unmet[(dem.model, dem.phase)] = float(s)
    return Allocation(instances, tmpl, total_cost, 0.0, unmet, 0.0, 0, True)


# ------------------------------------------------------------- Helix-style
def helix_placement(model: ServedModel, phase: str, wl: WorkloadStats,
                    nodes: List[NodeConfig], slo_ms: Optional[float] = None
                    ) -> Optional[Placement]:
    """Monolithic pipeline over the full pool, nodes grouped by type.

    Enumerates ordered merges of the type groups into stages (devices of
    one type stay together) and optimizes the layer split with the same
    bottleneck search as the exact solver.
    """
    slo = slo_ms if slo_ms is not None else (
        model.prefill_slo_ms if phase == "prefill" else model.decode_slo_ms)
    pt = ProfileTable(model, phase, slo, wl, max_stages=32)
    by_name = {}
    for n in nodes:
        by_name[n.name] = n
    names = [n.name for n in nodes]
    types: Dict[str, List[str]] = {}
    for n in names:
        types.setdefault(n, []).append(n)
    groups = list(types.values())
    G = len(groups)
    best = None

    def split_variants(i, cur):
        """Each type group may split into 1..4 near-equal sub-stages
        (Helix's max-flow lets same-type nodes hold different layer
        ranges; strict type-grouped stages can be infeasible when no
        single node class can hold L/G layers)."""
        if i == G:
            yield [list(st) for st in cur]
            return
        g = groups[i]
        for s in range(1, min(4, len(g)) + 1):
            size = len(g) // s
            subs, off = [], 0
            for k in range(s):
                extra = 1 if k < len(g) % s else 0
                subs.append(g[off:off + size + extra])
                off += size + extra
            cur.extend(subs)
            yield from split_variants(i + 1, cur)
            del cur[-len(subs):]

    for stages in split_variants(0, []):
        S = len(stages)
        if S > model.n_layers:
            continue
        tables = lambda nm, S_: pt.table(by_name[nm], S_)
        arrs = [sum(tables(nm, S) for nm in st) for st in stages]
        cand = np.unique(np.concatenate([a[a > 0] for a in arrs])) \
            if any((a > 0).any() for a in arrs) else None
        if cand is None or not len(cand):
            continue

        def feasible(T):
            js = []
            for a in arrs:
                jmax = int(np.searchsorted(-a, -T, side="right"))
                if jmax == 0:
                    return None
                js.append(jmax)
            return js if sum(js) >= model.n_layers else None

        lo, hi = 0, len(cand) - 1
        if feasible(cand[0]) is None:
            continue
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if feasible(cand[mid]) is not None:
                lo = mid
            else:
                hi = mid - 1
        T = float(cand[lo])
        js = feasible(T)
        counts = [1] * S
        rest = model.n_layers - S
        for i in range(S):
            add = min(rest, js[i] - 1)
            counts[i] += add
            rest -= add
        if rest > 0:
            continue
        pl = Placement(S, tuple(counts),
                       tuple(tuple(sorted(st)) for st in stages), T)
        if best is None or pl.throughput > best.throughput:
            best = pl
    return best
