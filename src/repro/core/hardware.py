"""Hardware profiles: Table 1 GPU types + TPU v5e adaptation, node
configurations (type x GPUs-per-node), and cloud pricing.

The Coral optimizer is hardware-agnostic: every device type is just a
``DeviceType(cost, mem, bw, flops, ...)`` record, so the same template
generator and allocator run over GPU nodes (paper-faithful evaluation)
or TPU slices (this repo's deployment target). See DESIGN.md §3.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class DeviceType:
    name: str
    mem_gb: float            # HBM per device
    bw_tbps: float           # HBM bandwidth, TB/s
    tflops: float            # dense (bf16/fp16) TFLOP/s per device
    rel_cost: float          # hourly price relative to L4 (Table 1)
    intra_node_gbps: float   # per-device interconnect inside a node (NVLink/ICI)
    has_fast_interconnect: bool = True


# --- Table 1 (paper) -------------------------------------------------------
H100 = DeviceType("H100", 80, 3.35, 989, 7.6, 450)
A100 = DeviceType("A100", 80, 2.04, 312, 3.5, 300)
L40S = DeviceType("L40S", 48, 0.86, 362, 2.2, 32, has_fast_interconnect=False)
L4 = DeviceType("L4", 24, 0.30, 121, 1.0, 16, has_fast_interconnect=False)
A10G = DeviceType("A10G", 24, 0.60, 70, 1.2, 16, has_fast_interconnect=False)
# Helix §6.6 comparison pool additionally uses:
A100_40G = DeviceType("A100-40G", 40, 1.56, 312, 2.8, 300)
V100 = DeviceType("V100-16G", 16, 0.90, 112, 1.45, 150)
T4 = DeviceType("T4", 16, 0.32, 65, 0.55, 16, has_fast_interconnect=False)
# --- TPU adaptation (deployment target of this repo) ----------------------
V5E = DeviceType("TPUv5e", 16, 0.819, 197, 1.15, 100)

DEVICE_TYPES: Dict[str, DeviceType] = {
    d.name: d for d in (H100, A100, A100_40G, L40S, L4, A10G, V100, T4, V5E)
}

# Hourly price of a 1xL4 node in USD (anchor for rel_cost).
L4_NODE_USD_PER_HOUR = 0.81


@dataclass(frozen=True)
class NodeConfig:
    """A provisionable cloud node: k devices of one type (TP/EP inside)."""
    device: DeviceType
    n_devices: int

    @property
    def name(self) -> str:
        return f"{self.n_devices}x{self.device.name}"

    @property
    def mem_gb(self) -> float:
        return self.device.mem_gb * self.n_devices

    @property
    def bw_tbps(self) -> float:
        return self.device.bw_tbps * self.n_devices

    @property
    def tflops(self) -> float:
        return self.device.tflops * self.n_devices

    def tp_efficiency(self) -> float:
        """Fraction of linear scaling retained by intra-node TP."""
        if self.n_devices == 1:
            return 1.0
        base = 0.92 if self.device.has_fast_interconnect else 0.80
        # mild degradation with TP degree
        return base ** (self.n_devices.bit_length() - 1)

    @property
    def rel_cost(self) -> float:
        # multi-GPU nodes carry a small premium (bigger host, NVSwitch)
        premium = 1.0 + 0.05 * (self.n_devices.bit_length() - 1)
        return self.device.rel_cost * self.n_devices * premium

    @property
    def usd_per_hour(self) -> float:
        return self.rel_cost * L4_NODE_USD_PER_HOUR


def make_node_configs(device_names: List[str],
                      sizes: Tuple[int, ...] = (1, 2, 4, 8)) -> List[NodeConfig]:
    return [NodeConfig(DEVICE_TYPES[d], k) for d in device_names for k in sizes]


# Paper §6.1 pools.
CORE_DEVICES = ["L40S", "L4", "A10G"]                       # 12 configs
EXT_DEVICES = CORE_DEVICES + ["H100", "A100"]               # 20 configs
CORE_CONFIGS = make_node_configs(CORE_DEVICES)
EXT_CONFIGS = make_node_configs(EXT_DEVICES)
TPU_CONFIGS = make_node_configs(["TPUv5e"], sizes=(1, 4, 8))

# Inter-node (PP / data-plane) network, GB/s per node — cloud ethernet/EFA.
INTER_NODE_GBPS = 12.5          # 100 Gbit/s
INTER_NODE_LATENCY_S = 25e-6    # per hop
# Inter-region links are prohibitive for PP (paper §4.2): templates never
# span regions; only the allocator crosses regions.


@dataclass(frozen=True)
class Region:
    name: str
    # price multiplier per device type (regional price differences)
    price_mult: Dict[str, float] = field(default_factory=dict)

    def node_usd_per_hour(self, cfg: NodeConfig) -> float:
        return cfg.usd_per_hour * self.price_mult.get(cfg.device.name, 1.0)


# Paper §6.1: AWS US-East-2 + AP-Northeast-2 (core), + GCP US-Central-1 (ext).
US_EAST_2 = Region("aws-us-east-2", {})
AP_NE_2 = Region("aws-ap-northeast-2", {"L40S": 1.18, "L4": 1.12, "A10G": 1.10,
                                        "H100": 1.15, "A100": 1.20})
US_CENTRAL_1 = Region("gcp-us-central-1", {"L40S": 0.95, "L4": 1.05, "A10G": 1.30,
                                           "H100": 0.92, "A100": 1.05})
CORE_REGIONS = [US_EAST_2, AP_NE_2]
EXT_REGIONS = [US_EAST_2, AP_NE_2, US_CENTRAL_1]

# TPU v5e roofline constants used by the §Roofline analysis (per chip).
TPU_V5E_PEAK_FLOPS = 197e12      # bf16 FLOP/s
TPU_V5E_HBM_BW = 819e9           # bytes/s
TPU_V5E_ICI_BW = 50e9            # bytes/s per link
