"""Analytic descriptors of the LLMs being served (the cost-model view).

The Coral optimizer needs only per-layer compute / weight / KV figures,
not executable models. ``ServedModel`` provides them for the paper's six
evaluation models (Table 3) and, via ``from_model_config``, for every
assigned architecture in ``repro.configs`` — so the same template
generator runs over both.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class ServedModel:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    n_experts: int = 0
    top_k: int = 0
    hybrid_attn: bool = False      # half the layers use sliding-window attn
    sliding_window: int = 4096
    recurrent: bool = False        # SSM-style O(1) decode state
    dtype_bytes: int = 2
    # serving metrics (paper Table 3)
    prefill_slo_ms: float = 1500.0
    decode_slo_ms: float = 80.0
    trace: str = "burstgpt"

    # ---------------- derived quantities ----------------
    @property
    def attn_params_layer(self) -> int:
        d, hd = self.d_model, self.head_dim
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d

    @property
    def ffn_params_layer_total(self) -> int:
        if self.n_experts:
            return self.n_experts * 3 * self.d_model * self.d_ff \
                + self.d_model * self.n_experts
        return 3 * self.d_model * self.d_ff

    @property
    def ffn_params_layer_active(self) -> int:
        if self.n_experts:
            return self.top_k * 3 * self.d_model * self.d_ff
        return 3 * self.d_model * self.d_ff

    @property
    def params_layer_total(self) -> int:
        return self.attn_params_layer + self.ffn_params_layer_total \
            + 2 * self.d_model

    @property
    def params_layer_active(self) -> int:
        return self.attn_params_layer + self.ffn_params_layer_active \
            + 2 * self.d_model

    @property
    def embed_params(self) -> int:
        return 2 * self.vocab * self.d_model

    @property
    def params_total(self) -> int:
        return self.embed_params + self.n_layers * self.params_layer_total

    @property
    def params_active(self) -> int:
        return self.embed_params + self.n_layers * self.params_layer_active

    @property
    def bytes_total(self) -> int:
        return self.params_total * self.dtype_bytes

    def bytes_for_layers(self, j: int) -> int:
        """Weight bytes a stage holding j layers must store (embedding
        amortized uniformly across layers)."""
        per = self.params_layer_total + self.embed_params / self.n_layers
        return int(j * per * self.dtype_bytes)

    def flops_per_token_layer(self, ctx: float, phase: str) -> float:
        """Forward FLOPs per token per layer at average context ``ctx``."""
        base = 2.0 * self.params_layer_active
        ctx_eff = self._ctx_eff(ctx)
        attn = 4.0 * self.n_heads * self.head_dim * ctx_eff
        if phase == "prefill":
            attn *= 0.5        # causal: average over positions
        return base + attn

    def _ctx_eff(self, ctx: float) -> float:
        if self.recurrent:
            return float(self.sliding_window) * 0.1
        if self.hybrid_attn:
            return (ctx + min(ctx, self.sliding_window)) / 2.0
        return ctx

    def kv_bytes_per_token_layer(self) -> float:
        """Bytes appended to the KV cache per token per layer (average
        across layers for hybrid-attention models)."""
        full = 2 * self.n_kv_heads * self.head_dim * self.dtype_bytes
        return full

    def kv_read_bytes_layer(self, ctx: float) -> float:
        """Bytes of KV streamed per generated token per layer."""
        return self.kv_bytes_per_token_layer() * self._ctx_eff(ctx)

    def kv_bytes_per_seq(self, j: int, ctx: float) -> float:
        """Resident KV bytes per sequence for a stage with j layers."""
        return j * self.kv_bytes_per_token_layer() * self._ctx_eff(ctx)

    def decode_read_bytes(self, j: int, batch: float, ctx: float) -> float:
        """Weight+KV bytes streamed per decode iteration (B tokens).

        MoE models with small batches only touch the activated experts.
        """
        w = self.bytes_for_layers(j)
        if self.n_experts:
            shared = (self.attn_params_layer + 2 * self.d_model
                      + self.embed_params / self.n_layers) * self.dtype_bytes
            expert_all = self.ffn_params_layer_total * self.dtype_bytes
            frac = min(1.0, batch * self.top_k / self.n_experts)
            w = j * (shared + frac * expert_all)
        kv = batch * j * self.kv_read_bytes_layer(ctx)
        return w + kv


# ---------------------------------------------------------------- paper set
# Table 3 of the paper; architecture constants from the public model cards.
PAPER_MODELS: Dict[str, ServedModel] = {m.name: m for m in [
    ServedModel("phi4-14b", 40, 5120, 40, 10, 128, 17920, 100352,
                prefill_slo_ms=1200, decode_slo_ms=60, trace="azure_conv"),
    ServedModel("gpt-oss-20b", 24, 2880, 64, 8, 64, 2880, 201088,
                n_experts=32, top_k=4, hybrid_attn=True, sliding_window=128,
                prefill_slo_ms=900, decode_slo_ms=30, trace="azure_code"),
    ServedModel("qwen3-32b", 64, 5120, 64, 8, 128, 25600, 151936,
                prefill_slo_ms=1600, decode_slo_ms=100, trace="burstgpt"),
    ServedModel("llama3-70b", 80, 8192, 64, 8, 128, 28672, 128256,
                prefill_slo_ms=1500, decode_slo_ms=80, trace="burstgpt"),
    ServedModel("gpt-oss-120b", 36, 2880, 64, 8, 64, 2880, 201088,
                n_experts=128, top_k=4, hybrid_attn=True, sliding_window=128,
                prefill_slo_ms=1000, decode_slo_ms=40, trace="azure_conv"),
    ServedModel("qwen3-235b", 94, 4096, 64, 4, 128, 1536, 151936,
                n_experts=128, top_k=8,
                prefill_slo_ms=1800, decode_slo_ms=120, trace="azure_code"),
]}

CORE_MODELS = ["qwen3-32b", "gpt-oss-20b", "phi4-14b"]
EXT_MODELS = CORE_MODELS + ["qwen3-235b", "gpt-oss-120b", "llama3-70b"]


def from_model_config(cfg: ModelConfig, *, prefill_slo_ms=1200.0,
                      decode_slo_ms=60.0, trace="burstgpt") -> ServedModel:
    """Bridge an assigned-architecture config into the serving cost model."""
    return ServedModel(
        name=cfg.name, n_layers=cfg.n_layers, d_model=cfg.d_model,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        d_ff=cfg.d_ff if cfg.d_ff else 2 * cfg.d_model,
        vocab=cfg.vocab_size, n_experts=cfg.n_experts, top_k=cfg.top_k,
        recurrent=cfg.is_recurrent,
        prefill_slo_ms=prefill_slo_ms, decode_slo_ms=decode_slo_ms,
        trace=trace)
