"""Throughput-optimal model placement on a node combination (paper §4.2).

Two solvers, property-tested to agree:

1. ``optimal_placement_ilp`` — the paper's exact formulation: binaries
   x_sj (stage s holds j layers), y_sk (node k in stage s), linearized
   z_sjk, maximize T with per-stage constraints
   T <= sum_jk z_sjk * T̂_j(g_k); optimum over S in [1, |G'|].
   Solved with HiGHS via repro.solver.milp.

2. ``optimal_placement_exact`` — an equivalent combinatorial algorithm
   exploiting two structures the ILP ignores: (a) stages are symmetric,
   so node->stage assignments reduce to *multiset partitions* of G'
   (e.g. 6 identical nodes have 11 partitions, not 6^6 assignments);
   (b) T̂_j is non-increasing in j, so for a fixed partition the optimal
   layer split is found by binary-searching the bottleneck throughput:
   partition {G_s} achieves T iff sum_s max{j : sum_{g in G_s} T̂_j(g) >= T} >= L.
   ~10^2-10^3x faster than the ILP; this is what makes full-library
   generation tractable on one core (beyond-paper contribution,
   DESIGN.md §6).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig
from repro.core.profiles import ProfileTable
from repro.solver.milp import MilpModel


@dataclass(frozen=True)
class Placement:
    n_stages: int
    layer_counts: Tuple[int, ...]           # per stage, sums to L
    stage_nodes: Tuple[Tuple[str, ...], ...]  # node-config names per stage
    throughput: float                        # tokens/s of the pipeline


# ------------------------------------------------------------ exact solver
def _multiset_partitions(items: Tuple[str, ...]):
    """All partitions of a multiset into unordered non-empty groups."""
    items = tuple(sorted(items))

    def rec(remaining: Tuple[str, ...], groups: Tuple[Tuple[str, ...], ...]):
        if not remaining:
            yield groups
            return
        x, rest = remaining[0], remaining[1:]
        seen = set()
        for i, g in enumerate(groups):
            key = g
            if key in seen:
                continue
            seen.add(key)
            yield from rec(rest, tuple(sorted(
                groups[:i] + (tuple(sorted(g + (x,))),) + groups[i + 1:])))
        yield from rec(rest, tuple(sorted(groups + ((x,),))))

    out = set()
    for p in rec(items, ()):
        out.add(p)
    return out


def optimal_placement_exact(node_names: Sequence[str],
                            tables: Callable[[str, int], np.ndarray],
                            L: int,
                            max_stages: Optional[int] = None) -> Optional[Placement]:
    """node_names: node-config names of G'. tables(name, S) -> length-L
    non-increasing array of T̂_j (j = 1..L) under per-stage budget slo/S."""
    names = tuple(sorted(node_names))
    K = len(names)
    max_stages = min(max_stages or K, K)
    best: Optional[Placement] = None

    for groups in _multiset_partitions(names):
        S = len(groups)
        if S > max_stages or S > L:
            continue
        # per-stage throughput arrays under the S-stage budget
        arrs = [sum(tables(n, S) for n in g) for g in groups]
        # candidate bottleneck values: all distinct positive stage values
        cand = np.unique(np.concatenate([a[a > 0] for a in arrs])
                         ) if any((a > 0).any() for a in arrs) else None
        if cand is None or len(cand) == 0:
            continue

        def feasible(T: float) -> Optional[List[int]]:
            js = []
            for a in arrs:
                # largest j (1-indexed) with a[j-1] >= T; a non-increasing
                jmax = int(np.searchsorted(-a, -T, side="right"))
                if jmax == 0:
                    return None
                js.append(jmax)
            return js if sum(js) >= L else None

        lo, hi = 0, len(cand) - 1
        if feasible(cand[0]) is None:
            continue
        while lo < hi:                       # largest feasible candidate
            mid = (lo + hi + 1) // 2
            if feasible(cand[mid]) is not None:
                lo = mid
            else:
                hi = mid - 1
        T = float(cand[lo])
        js = feasible(T)
        if js is None:
            continue
        # distribute the L layers: start from 1 each, fill up to jmax
        counts = [1] * S
        rest = L - S
        for i in range(S):
            add = min(rest, js[i] - 1)
            counts[i] += add
            rest -= add
        if rest > 0:
            continue
        if best is None or T > best.throughput:
            best = Placement(S, tuple(counts), groups, T)
    return best


# -------------------------------------------------------------- paper ILP
def optimal_placement_ilp(node_names: Sequence[str],
                          tables: Callable[[str, int], np.ndarray],
                          L: int,
                          max_stages: Optional[int] = None,
                          time_limit: float = 30.0) -> Optional[Placement]:
    """The paper's ILP, solved per S and maximized over S in [1, |G'|]."""
    names = list(node_names)
    K = len(names)
    max_stages = min(max_stages or K, K)
    best: Optional[Placement] = None

    for S in range(1, max_stages + 1):
        that = np.stack([tables(n, S) for n in names])   # (K, L)
        tmax = float(that.sum(0).max())
        if tmax <= 0:
            continue
        mdl = MilpModel()
        T = mdl.add_var(obj=-1.0, lb=0.0, ub=tmax * K)
        x = [[mdl.add_var(integer=True, ub=1) for _ in range(L)]
             for _ in range(S)]
        y = [[mdl.add_var(integer=True, ub=1) for _ in range(K)]
             for _ in range(S)]
        z = {}
        for s in range(S):
            for j in range(L):
                for k in range(K):
                    if that[k, j] <= 0:
                        continue
                    v = mdl.add_var(integer=True, ub=1)
                    z[s, j, k] = v
                    mdl.add_constr({v: 1, x[s][j]: -1}, ub=0)
                    mdl.add_constr({v: 1, y[s][k]: -1}, ub=0)
                    mdl.add_constr({v: 1, x[s][j]: -1, y[s][k]: -1}, lb=-1)
        for s in range(S):
            mdl.add_constr({x[s][j]: 1 for j in range(L)}, lb=1, ub=1)
            coeffs = {T: 1.0}
            for (s2, j, k), v in z.items():
                if s2 == s:
                    coeffs[v] = coeffs.get(v, 0.0) - float(that[k, j])
            mdl.add_constr(coeffs, ub=0)
        for k in range(K):
            mdl.add_constr({y[s][k]: 1 for s in range(S)}, lb=1, ub=1)
        mdl.add_constr({x[s][j]: j + 1 for s in range(S) for j in range(L)},
                       lb=L, ub=L)
        res = mdl.solve(time_limit=time_limit)
        if not res.ok:
            continue
        tput = -res.obj
        if tput <= 0:
            continue
        counts, stage_nodes = [], []
        for s in range(S):
            j = int(np.argmax([res.x[x[s][j]] for j in range(L)])) + 1
            counts.append(j)
            stage_nodes.append(tuple(sorted(
                names[k] for k in range(K) if res.x[y[s][k]] > 0.5)))
        cand = Placement(S, tuple(counts), tuple(stage_nodes), float(tput))
        if best is None or cand.throughput > best.throughput + 1e-9:
            best = cand
    return best
