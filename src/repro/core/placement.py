"""Throughput-optimal model placement on a node combination (paper §4.2).

Three solvers, property-tested to agree:

1. ``optimal_placement_ilp`` — the paper's exact formulation: binaries
   x_sj (stage s holds j layers), y_sk (node k in stage s), linearized
   z_sjk, maximize T with per-stage constraints
   T <= sum_jk z_sjk * T̂_j(g_k); optimum over S in [1, |G'|].
   Solved with HiGHS via repro.solver.milp.

2. ``optimal_placement_exact`` — an equivalent combinatorial algorithm
   exploiting two structures the ILP ignores: (a) stages are symmetric,
   so node->stage assignments reduce to *multiset partitions* of G'
   (e.g. 6 identical nodes have 11 partitions, not 6^6 assignments);
   (b) T̂_j is non-increasing in j, so for a fixed partition the optimal
   layer split is found by binary-searching the bottleneck throughput:
   partition {G_s} achieves T iff sum_s max{j : sum_{g in G_s} T̂_j(g) >= T} >= L.
   ~10^2-10^3x faster than the ILP; this is what makes full-library
   generation tractable on one core (beyond-paper contribution,
   DESIGN.md §6). Kept as the reference oracle for the fast path.

3. ``PlacementCache`` / ``optimal_placement_fast`` — the production path
   used by library generation. Same optimum as (2), computed without the
   per-partition binary search. For a partition with stacked per-stage
   rows A (S x L, each non-increasing), feasibility of a bottleneck T is
   "every stage fits >= 1 layer at T" and "total layers at T >= L", i.e.
   #{(s,j): A[s,j] >= T} >= L and min_s A[s,0] >= T. Both counts are
   monotone step functions that change only at entries of A, so the
   optimum collapses to the closed form

       T* = min( L-th largest positive entry of A, min_s A[s,0] )

   (infeasible iff A has < L positive entries or some row is all zero).
   That turns the search into two vectorized reductions over a (P, S, L)
   gather, batched over all P partitions of a combo at once. On top of
   that, the cache memoizes across the whole enumeration:

   * partition *structures* per multiset shape (count signature) — 29
     shapes cover every combo at n_max = 6, vs. re-deriving ~10^2
     partitions per combo;
   * summed group rows per (stage-group, S) — combos drawn from a small
     config universe share almost all their sub-multisets, so each
     group's T̂ row is built once and gathered thereafter.

   ``solve_batch`` further amortizes the per-combo numpy dispatch by
   processing all combos of one shape as a stacked (combos, partitions)
   grid, visiting S levels best-bound-first so the incumbent prunes
   the L-th-largest selections. Callers can seed the incumbent per
   combo (``incumbents=``): only partitions whose bound — min of the
   per-partition 1-layer cap and a per-(combo, S) aggregate bound
   R_S[ceil(L/S)-1] — exceeds it are evaluated, and a ``None`` result
   certifies the optimum equals the incumbent (the dominated-combo
   prune behind ``generate_templates``' level-wise frontier). Stage
   groups are interned by packed integer code so batch lookups stay
   in array land (``_solve_batch_legacy`` keeps the tuple-keyed path
   for inputs that overflow the packing).

   Measured on this container (qwen3-32b decode, core 12-config setup,
   n_max=6, rho=12: 12,990 combos): 212s seed -> ~6s batch solver
   (~35x, PR 1) -> ~2s with packed-code interning + frontier
   incumbents (PR 4), with a bit-identical post-prune template set —
   throughputs equal to the last ulp because group rows accumulate in
   the same order as the reference (see tests/test_placement_fast.py,
   tests/test_template_prune.py and benchmarks/template_gen.py).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig
from repro.core.profiles import ProfileTable
from repro.solver.milp import MilpModel


@dataclass(frozen=True)
class Placement:
    n_stages: int
    layer_counts: Tuple[int, ...]           # per stage, sums to L
    stage_nodes: Tuple[Tuple[str, ...], ...]  # node-config names per stage
    throughput: float                        # tokens/s of the pipeline


# ------------------------------------------------------------ exact solver
def _multiset_partitions(items: Tuple[str, ...]):
    """All partitions of a multiset into unordered non-empty groups."""
    items = tuple(sorted(items))

    def rec(remaining: Tuple[str, ...], groups: Tuple[Tuple[str, ...], ...]):
        if not remaining:
            yield groups
            return
        x, rest = remaining[0], remaining[1:]
        seen = set()
        for i, g in enumerate(groups):
            key = g
            if key in seen:
                continue
            seen.add(key)
            yield from rec(rest, tuple(sorted(
                groups[:i] + (tuple(sorted(g + (x,))),) + groups[i + 1:])))
        yield from rec(rest, tuple(sorted(groups + ((x,),))))

    out = set()
    for p in rec(items, ()):
        out.add(p)
    return out


def optimal_placement_exact(node_names: Sequence[str],
                            tables: Callable[[str, int], np.ndarray],
                            L: int,
                            max_stages: Optional[int] = None) -> Optional[Placement]:
    """node_names: node-config names of G'. tables(name, S) -> length-L
    non-increasing array of T̂_j (j = 1..L) under per-stage budget slo/S."""
    names = tuple(sorted(node_names))
    K = len(names)
    max_stages = min(max_stages or K, K)
    best: Optional[Placement] = None

    for groups in _multiset_partitions(names):
        S = len(groups)
        if S > max_stages or S > L:
            continue
        # per-stage throughput arrays under the S-stage budget
        arrs = [sum(tables(n, S) for n in g) for g in groups]
        # candidate bottleneck values: all distinct positive stage values
        cand = np.unique(np.concatenate([a[a > 0] for a in arrs])
                         ) if any((a > 0).any() for a in arrs) else None
        if cand is None or len(cand) == 0:
            continue

        def feasible(T: float) -> Optional[List[int]]:
            js = []
            for a in arrs:
                # largest j (1-indexed) with a[j-1] >= T; a non-increasing
                jmax = int(np.searchsorted(-a, -T, side="right"))
                if jmax == 0:
                    return None
                js.append(jmax)
            return js if sum(js) >= L else None

        lo, hi = 0, len(cand) - 1
        if feasible(cand[0]) is None:
            continue
        while lo < hi:                       # largest feasible candidate
            mid = (lo + hi + 1) // 2
            if feasible(cand[mid]) is not None:
                lo = mid
            else:
                hi = mid - 1
        T = float(cand[lo])
        js = feasible(T)
        if js is None:
            continue
        # distribute the L layers: start from 1 each, fill up to jmax
        counts = [1] * S
        rest = L - S
        for i in range(S):
            add = min(rest, js[i] - 1)
            counts[i] += add
            rest -= add
        if rest > 0:
            continue
        if best is None or T > best.throughput:
            best = Placement(S, tuple(counts), groups, T)
    return best


# ------------------------------------------------------- fast cached solver
@lru_cache(maxsize=None)
def _partitions_by_shape(shape: Tuple[int, ...]):
    """Partition structures for any multiset with count signature ``shape``
    (counts sorted descending, e.g. (A,A,A,B,C,C) -> (3,2,1)).

    Structurally identical combos share their partition set up to a
    relabeling, so this is computed once per shape. Returns
    ``(cgroups, by_S)`` where ``cgroups`` is the list of distinct
    canonical groups — each a tuple of (label, count) pairs, labels being
    indices into ``shape`` — and ``by_S[S] = (used, local_idx)``:
    ``used`` the int array of cgroup indices appearing in S-part
    partitions, ``local_idx`` an int32 array (P_S, S) indexing into
    ``used``, one row per partition into S groups.
    """
    items = tuple(lbl for lbl, n in enumerate(shape) for _ in range(n))
    cg_index: Dict[Tuple[Tuple[int, int], ...], int] = {}
    cgroups: List[Tuple[Tuple[int, int], ...]] = []
    rows_by_S: Dict[int, List[List[int]]] = {}
    for part in _multiset_partitions(items):
        row = []
        for g in part:
            key = tuple(sorted((lbl, g.count(lbl))
                               for lbl in sorted(set(g))))
            gid = cg_index.get(key)
            if gid is None:
                gid = cg_index[key] = len(cgroups)
                cgroups.append(key)
            row.append(gid)
        rows_by_S.setdefault(len(part), []).append(sorted(row))
    by_S = {}
    for S, rows in rows_by_S.items():
        idx = np.array(rows, dtype=np.int32)
        used, local = np.unique(idx, return_inverse=True)
        by_S[S] = (used, local.reshape(idx.shape).astype(np.int32))
    return cgroups, by_S


CODE_BITS = 3                 # packed stage-group codes: 3 bits per config
CODE_MASK = (1 << CODE_BITS) - 1
# multiplicative slack covering the fp error of the vectorized R_S bound
# (a <= 21-term matvec; worst-case relative error ~2e-15) so the bound
# stays a true upper bound on the sequentially-accumulated stage rows
_UB_INFLATE = 1.0 + 1e-12


class PlacementCache:
    """Shared-subproblem store for ``optimal_placement_fast`` across a
    whole enumeration (one instance per (model, phase, SLO, workload);
    threaded through ``generate_templates`` from ``build_library``).

    Per stage count S it keeps a growing (G, L) matrix of summed T̂ rows,
    one row per distinct stage group (sub-multiset of configs) seen so
    far, plus the per-config base tables. ``solve`` gathers the rows of
    every partition of a combo and applies the closed-form bottleneck
    optimum (module docstring, solver 3) in one batched pass per S.

    ``solve_batch``/``solve_batch_counts`` accept per-combo *incumbent*
    throughputs: a combo's search starts from its incumbent and only
    partitions whose upper bound exceeds it are evaluated; the result is
    ``None`` when nothing strictly beats the incumbent. With the
    incumbent set to the best throughput of any enumerated sub-combo
    (see ``generate_templates``' frontier), a ``None`` is a lossless
    dominated-combo prune: throughput is monotone non-decreasing under
    adding nodes (every row is >= 0, so extending any stage of the
    sub-combo's optimal partition preserves feasibility), hence
    ``T(combo) == incumbent`` exactly and the combo's template would be
    usage-dominated. Two bounds do the partition-level pruning:

    * ``tcap`` — min over stages of the 1-layer row value (exact cap);
    * the aggregate bound ``R_S[ceil(L/S)-1]`` where ``R_S`` is the
      pointwise sum of *all* member rows at budget S: every stage row is
      <= R_S, so a feasible T needs ceil(L/S) entries of R_S above it.
      Computed for a whole batch as one matvec per S (rows are
      non-increasing, so the k-th largest is a column pick) and inflated
      by ``_UB_INFLATE`` to stay sound under fp summation differences.

    Stage groups are interned by packed integer code (``CODE_BITS`` bits
    per config) so batch lookups are array ops; combos whose counts or
    config universe overflow the packing fall back to the original
    tuple-keyed path (``_solve_batch_legacy``), which shares the same
    row store.
    """

    def __init__(self, tables: Callable[[str, int], np.ndarray], L: int):
        self.tables = tables
        self.L = L
        self._base: Dict[int, Dict[str, np.ndarray]] = {}   # S -> name -> row
        self._gid: Dict[int, Dict[Tuple, int]] = {}         # S -> group -> gid
        self._codegid: Dict[int, Dict[int, int]] = {}       # S -> code -> gid
        self._key: Dict[int, List[Tuple]] = {}              # S -> gid -> group
        self._rows: Dict[int, np.ndarray] = {}              # S -> (cap, L)
        self._n: Dict[int, int] = {}                        # S -> used rows
        self._cfg_idx: Dict[str, int] = {}                  # name -> code slot
        self._cfg_names: List[str] = []

    # ------------------------------------------------------ group registry
    def _base_row(self, name: str, S: int) -> np.ndarray:
        per = self._base.setdefault(S, {})
        row = per.get(name)
        if row is None:
            row = per[name] = np.asarray(self.tables(name, S), dtype=float)
        return row

    def _register_cfgs(self, names: Sequence[str]) -> np.ndarray:
        """Packed-code slots for ``names``, assigning new slots on first
        appearance. Slot order is first-appearance order; only identity
        matters (codes are internal to this cache instance)."""
        idx = self._cfg_idx
        for nm in names:
            if nm not in idx:
                idx[nm] = len(self._cfg_names)
                self._cfg_names.append(nm)
        return np.array([idx[nm] for nm in names], dtype=np.int64)

    def _register_key(self, S: int, key: Tuple[Tuple[str, int], ...]) -> int:
        """gid for group ``key`` ((name, count) tuples, name-sorted),
        registering and summing its row if unseen."""
        gid = self._gid.setdefault(S, {})
        g = gid.get(key)
        if g is not None:
            return g
        rows = self._rows.get(S)
        if rows is None:
            rows = self._rows[S] = np.zeros((64, self.L))
            self._n[S] = 0
        g = gid[key] = self._n[S]
        self._key.setdefault(S, []).append(key)
        self._n[S] += 1
        if g >= rows.shape[0]:
            rows = np.concatenate([rows, np.zeros_like(rows)])
            self._rows[S] = rows
        # accumulate members one by one in sorted-name order —
        # bit-identical to the reference solver's sum(tables(...))
        acc = rows[g]
        for name, cnt in key:
            base = self._base_row(name, S)
            for _ in range(cnt):
                acc += base
        return g

    def _group_rows(self, S: int, keys: List[Tuple[Tuple[str, int], ...]]
                    ) -> np.ndarray:
        """gids for group ``keys``, registering unseen groups."""
        out = np.empty(len(keys), dtype=np.int32)
        for i, key in enumerate(keys):
            out[i] = self._register_key(S, key)
        return out

    def _map_codes(self, S: int, codes: np.ndarray) -> np.ndarray:
        """gids for an array of packed group codes, registering unseen
        codes (decoded into name-sorted keys, so rows are accumulated
        exactly as in the tuple-keyed path)."""
        cg = self._codegid.setdefault(S, {})
        uniq, inv = np.unique(codes.ravel(), return_inverse=True)
        gid_u = np.empty(len(uniq), dtype=np.int32)
        for j, c in enumerate(uniq.tolist()):
            g = cg.get(c)
            if g is None:
                items = []
                for k, nm in enumerate(self._cfg_names):
                    cnt = (c >> (CODE_BITS * k)) & CODE_MASK
                    if cnt:
                        items.append((nm, cnt))
                g = cg[c] = self._register_key(S, tuple(sorted(items)))
            gid_u[j] = g
        return gid_u[inv].reshape(codes.shape)

    # -------------------------------------------------------------- solve
    def solve(self, node_names: Sequence[str],
              max_stages: Optional[int] = None) -> Optional[Placement]:
        return self.solve_batch([node_names], max_stages=max_stages)[0]

    def solve_batch(self, combos: Sequence[Sequence[str]],
                    max_stages: Optional[int] = None,
                    incumbents: Optional[np.ndarray] = None
                    ) -> List[Optional[Placement]]:
        """``solve`` over many combos at once, batched by shape.

        Combos with the same count signature share their partition
        structure, so their per-S group-id lookup vectors stack into a
        (combos, groups) matrix and the whole (combo, partition) grid
        evaluates with a handful of chunked numpy ops — instead of ~10
        small numpy calls per (combo, S). Same optima as per-combo
        ``solve``. ``incumbents`` (optional, per combo): only return a
        placement when its throughput strictly beats the incumbent (see
        class docstring); this is what ``generate_templates`` drives.
        """
        combos = [list(names) for names in combos]
        uni = sorted({n for names in combos for n in names})
        counts = np.zeros((len(combos), len(uni)), dtype=np.int64)
        ix = {n: i for i, n in enumerate(uni)}
        for ci, names in enumerate(combos):
            for n in names:
                counts[ci, ix[n]] += 1
        return self.solve_batch_counts(counts, uni, max_stages=max_stages,
                                       incumbents=incumbents)

    def solve_batch_counts(self, counts, names: Sequence[str],
                           max_stages: Optional[int] = None,
                           incumbents: Optional[np.ndarray] = None
                           ) -> List[Optional[Placement]]:
        """Array-native ``solve_batch``: ``counts`` is an (N, len(names))
        matrix of node counts per combo. Avoids re-deriving multiset
        shapes from name lists — the path the level-wise frontier in
        ``generate_templates`` uses."""
        counts = np.asarray(counts, dtype=np.int64)
        N, K = counts.shape
        if N == 0:
            return []
        names = list(names)
        slots = self._register_cfgs(names)
        if (counts.max(initial=0) > CODE_MASK
                or len(self._cfg_names) * CODE_BITS > 62):
            name_lists = [[names[i] for i in range(K)
                           for _ in range(int(row[i]))] for row in counts]
            return self._solve_batch_legacy(name_lists, max_stages,
                                            incumbents)
        L = self.L
        results: List[Optional[Placement]] = [None] * N
        bestT = (np.zeros(N) if incumbents is None
                 else np.asarray(incumbents, dtype=float).copy())
        bestSP: List[Optional[Tuple[int, int]]] = [None] * N
        # canonical per-row label order: count desc, then name asc
        order = np.argsort(np.array(names))
        rank = np.empty(K, dtype=np.int64)
        rank[order] = np.arange(K)
        perm = np.lexsort((np.broadcast_to(rank, counts.shape), -counts),
                          axis=-1)
        csort = np.take_along_axis(counts, perm, axis=1)
        shapes, sinv = np.unique(csort, axis=0, return_inverse=True)
        sinv = sinv.ravel()
        pow_slot = np.int64(1) << (CODE_BITS * slots)
        counts_f = counts.astype(float)
        ub_cols: Dict[int, np.ndarray] = {}       # S -> per-name R_S[kidx]
        for si in range(len(shapes)):
            srow = shapes[si]
            m = int(np.count_nonzero(srow))
            if m == 0:
                continue
            members = np.nonzero(sinv == si)[0]
            shape = tuple(int(x) for x in srow[:m])
            Kn = int(srow.sum())
            smax = min(max_stages or Kn, Kn, L)
            cgroups, by_S = _partitions_by_shape(shape)
            CG = np.zeros((len(cgroups), m), dtype=np.int64)
            for u, key in enumerate(cgroups):
                for lbl, cnt in key:
                    CG[u, lbl] = cnt
            lab_pow = pow_slot[perm[members][:, :m]]       # (C, m)
            codes_all = lab_pow @ CG.T                     # (C, cgroups)
            # pass 1: per-S aggregate bound, group registration and the
            # per-partition cap for combos still above their incumbent
            passes = []
            for S in sorted(by_S):
                if S > smax:
                    continue
                col = ub_cols.get(S)
                if col is None:
                    kidx = (L + S - 1) // S - 1
                    col = ub_cols[S] = np.array(
                        [self._base_row(nm, S)[kidx] for nm in names])
                ub = (counts_f[members] @ col) * _UB_INFLATE
                aidx = np.nonzero(ub > bestT[members])[0]
                if not len(aidx):
                    continue
                used, local_idx = by_S[S]
                gids = self._map_codes(S, codes_all[np.ix_(aidx, used)])
                rows = self._rows[S][:self._n[S]]
                grid = gids[:, local_idx]                  # (A, P, S)
                bound = rows[:, 0][grid].min(axis=2)       # (A, P)
                np.minimum(bound, ub[aidx, None], out=bound)
                passes.append((S, members[aidx], grid, bound, rows))
            # pass 2: visit S levels best-bound-first so the strongest
            # incumbent forms early; bound <= bestT prunes the rest,
            # leaving the expensive L-th-largest selection to few pairs
            passes.sort(key=lambda p: -p[3].max(initial=0.0))
            for S, gidx, grid, bound, rows in passes:
                A, P = bound.shape
                kth = S * L - L
                chunk = max(1, 4_000_000 // max(P * S * L, 1))
                for c0 in range(0, A, chunk):
                    gi = gidx[c0:c0 + chunk]
                    bc = bound[c0:c0 + chunk]
                    live = bc > bestT[gi, None]
                    if not live.any():
                        continue
                    idx = np.nonzero(live)
                    g = grid[c0:c0 + chunk]
                    vals = rows[g[idx]].reshape(len(idx[0]), S * L)
                    vL = np.partition(vals, kth, axis=1)[:, kth]
                    T = np.minimum(vL, bc[idx])
                    T[vL <= 0] = 0.0
                    Tf = np.zeros(bc.shape)
                    Tf[idx] = T
                    pbest = np.argmax(Tf, axis=1)
                    tbest = Tf[np.arange(len(pbest)), pbest]
                    for j in np.nonzero(tbest > bestT[gi])[0]:
                        bestT[gi[j]] = tbest[j]
                        bestSP[gi[j]] = (S, g[j, pbest[j]])
        for ci in range(N):
            if bestSP[ci] is not None:
                results[ci] = self._reconstruct(
                    bestSP[ci][0], bestSP[ci][1], float(bestT[ci]))
        return results

    def _solve_batch_legacy(self, combos: Sequence[Sequence[str]],
                            max_stages: Optional[int] = None,
                            incumbents: Optional[np.ndarray] = None
                            ) -> List[Optional[Placement]]:
        """Tuple-keyed fallback for combos whose counts or config
        universe overflow the packed codes. Same optima (and the same
        row store) as ``solve_batch_counts``; no aggregate R_S bound."""
        results: List[Optional[Placement]] = [None] * len(combos)
        by_shape: Dict[Tuple[int, ...], List[Tuple[int, List[str]]]] = {}
        for ci, names in enumerate(combos):
            counts: Dict[str, int] = {}
            for n in names:
                counts[n] = counts.get(n, 0) + 1
            by_count = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
            shape = tuple(n for _, n in by_count)
            labels = [name for name, _ in by_count]
            by_shape.setdefault(shape, []).append((ci, labels))

        L = self.L
        inc_all = (None if incumbents is None
                   else np.asarray(incumbents, dtype=float))
        for shape, members in by_shape.items():
            cgroups, by_S = _partitions_by_shape(shape)
            K = sum(shape)
            smax = min(max_stages or K, K, L)
            C = len(members)
            bestT = (np.zeros(C) if inc_all is None
                     else inc_all[[ci for ci, _ in members]].copy())
            bestSP: List[Optional[Tuple[int, np.ndarray]]] = [None] * C
            keys_per = [[None] * len(cgroups) for _ in range(C)]
            # pass 1: register groups and compute the cheap tcap bound
            # (min over stages of the 1-layer value) for every S
            passes = []
            for S in sorted(by_S):
                if S > smax:
                    continue
                used, local_idx = by_S[S]
                lookups = np.empty((C, len(used)), dtype=np.int32)
                for i, (ci, labels) in enumerate(members):
                    keys = keys_per[i]
                    for u in used:
                        if keys[u] is None:
                            keys[u] = tuple(sorted(
                                (labels[lbl], cnt)
                                for lbl, cnt in cgroups[u]))
                    lookups[i] = self._group_rows(S, [keys[u] for u in used])
                rows = self._rows[S][:self._n[S]]
                gids = lookups[:, local_idx]                 # (C, P, S)
                tcap = rows[:, 0][gids].min(axis=2)          # (C, P)
                passes.append((S, rows, gids, tcap))
            # pass 2: visit S levels best-bound-first so the strongest
            # incumbent forms early; T* <= tcap prunes the rest, leaving
            # the expensive L-th-largest selection to few candidates
            passes.sort(key=lambda p: -p[3].max(initial=0.0))
            for S, rows, gids, tcap in passes:
                P = tcap.shape[1]
                kth = S * L - L
                chunk = max(1, 4_000_000 // max(P * S * L, 1))
                for c0 in range(0, C, chunk):
                    tc = tcap[c0:c0 + chunk]
                    live = tc > bestT[c0:c0 + chunk, None]
                    if not live.any():
                        continue
                    idx = np.nonzero(live)
                    g = gids[c0:c0 + chunk]
                    vals = rows[g[idx]].reshape(len(idx[0]), S * L)
                    vL = np.partition(vals, kth, axis=1)[:, kth]
                    T = np.minimum(vL, tc[idx])
                    T[vL <= 0] = 0.0
                    Tf = np.zeros(tc.shape)
                    Tf[idx] = T
                    pbest = np.argmax(Tf, axis=1)
                    tbest = Tf[np.arange(len(pbest)), pbest]
                    for j in np.nonzero(tbest > bestT[c0:c0 + chunk])[0]:
                        bestT[c0 + j] = tbest[j]
                        bestSP[c0 + j] = (S, g[j, pbest[j]])
            for i, (ci, _) in enumerate(members):
                if bestSP[i] is not None:
                    results[ci] = self._reconstruct(
                        bestSP[i][0], bestSP[i][1], float(bestT[i]))
        return results

    def _reconstruct(self, S: int, gids: np.ndarray,
                     best_T: float) -> Placement:
        L = self.L
        key_of = self._key[S]
        named = sorted(
            (tuple(sorted(n for name, cnt in key_of[int(g)]
                          for n in [name] * cnt)), int(g)) for g in gids)
        groups = tuple(g for g, _ in named)
        rows = self._rows[S][[g for _, g in named]]
        # layer split: same distribution rule as the reference solver
        js = (rows >= best_T).sum(axis=1)
        layer_counts = [1] * S
        rest = L - S
        for i in range(S):
            add = min(rest, int(js[i]) - 1)
            layer_counts[i] += add
            rest -= add
        return Placement(S, tuple(layer_counts), groups, best_T)


def optimal_placement_fast(node_names: Sequence[str],
                           tables: Callable[[str, int], np.ndarray],
                           L: int,
                           max_stages: Optional[int] = None,
                           cache: Optional[PlacementCache] = None
                           ) -> Optional[Placement]:
    """Drop-in equivalent of ``optimal_placement_exact`` (same optimum;
    stage grouping may differ only between equal-throughput ties). Pass a
    shared ``cache`` when solving many combos over one config universe."""
    if cache is None:
        cache = PlacementCache(tables, L)
    return cache.solve(node_names, max_stages=max_stages)


# -------------------------------------------------------------- paper ILP
def optimal_placement_ilp(node_names: Sequence[str],
                          tables: Callable[[str, int], np.ndarray],
                          L: int,
                          max_stages: Optional[int] = None,
                          time_limit: float = 30.0) -> Optional[Placement]:
    """The paper's ILP, solved per S and maximized over S in [1, |G'|]."""
    names = list(node_names)
    K = len(names)
    max_stages = min(max_stages or K, K)
    best: Optional[Placement] = None

    for S in range(1, max_stages + 1):
        that = np.stack([tables(n, S) for n in names])   # (K, L)
        tmax = float(that.sum(0).max())
        if tmax <= 0:
            continue
        mdl = MilpModel()
        T = mdl.add_var(obj=-1.0, lb=0.0, ub=tmax * K)
        x = [[mdl.add_var(integer=True, ub=1) for _ in range(L)]
             for _ in range(S)]
        y = [[mdl.add_var(integer=True, ub=1) for _ in range(K)]
             for _ in range(S)]
        z = {}
        for s in range(S):
            for j in range(L):
                for k in range(K):
                    if that[k, j] <= 0:
                        continue
                    v = mdl.add_var(integer=True, ub=1)
                    z[s, j, k] = v
                    mdl.add_constr({v: 1, x[s][j]: -1}, ub=0)
                    mdl.add_constr({v: 1, y[s][k]: -1}, ub=0)
                    mdl.add_constr({v: 1, x[s][j]: -1, y[s][k]: -1}, lb=-1)
        for s in range(S):
            mdl.add_constr({x[s][j]: 1 for j in range(L)}, lb=1, ub=1)
            coeffs = {T: 1.0}
            for (s2, j, k), v in z.items():
                if s2 == s:
                    coeffs[v] = coeffs.get(v, 0.0) - float(that[k, j])
            mdl.add_constr(coeffs, ub=0)
        for k in range(K):
            mdl.add_constr({y[s][k]: 1 for s in range(S)}, lb=1, ub=1)
        mdl.add_constr({x[s][j]: j + 1 for s in range(S) for j in range(L)},
                       lb=L, ub=L)
        res = mdl.solve(time_limit=time_limit)
        if not res.ok:
            continue
        tput = -res.obj
        if tput <= 0:
            continue
        counts, stage_nodes = [], []
        for s in range(S):
            j = int(np.argmax([res.x[x[s][j]] for j in range(L)])) + 1
            counts.append(j)
            stage_nodes.append(tuple(sorted(
                names[k] for k in range(K) if res.x[y[s][k]] > 0.5)))
        cand = Placement(S, tuple(counts), tuple(stage_nodes), float(tput))
        if best is None or cand.throughput > best.throughput + 1e-9:
            best = cand
    return best
