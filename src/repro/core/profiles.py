"""T̂_j(g): max throughput of node g holding j consecutive layers under a
per-stage latency budget (paper §4.2, "obtained from a one-time offline
profiling run").

On real hardware this table comes from profiling; here it is an
analytical roofline cost model over the node profiles of
``repro.core.hardware`` (compute term, HBM term, capacity limit,
pipeline-network term, iteration overhead). The interface — a table of
T̂_j(g) per (model, phase, per-stage budget) — is identical, so measured
tables can be dropped in. The event simulator (repro.simulator) uses the
*same* cost model, which is what makes the Fig-6-style fidelity check an
apples-to-apples comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import lru_cache
from typing import Dict

import numpy as np

from repro.core.hardware import (INTER_NODE_GBPS, INTER_NODE_LATENCY_S,
                                 NodeConfig)
from repro.core.modelspec import ServedModel

# calibration constants (the "profiling fit")
MFU_PREFILL = 0.55          # achievable fraction of peak FLOPs in prefill
MFU_DECODE = 0.35           # gemm efficiency at small batch
BW_EFF = 0.80               # achievable fraction of HBM bandwidth
ALPHA_PREFILL = 3e-3        # per-iteration overhead (s)
ALPHA_DECODE = 1.2e-3
MEM_HEADROOM = 0.90         # fraction of HBM usable for weights+KV
MAX_PREFILL_CHUNK = 16384   # engine cap on tokens per prefill iteration


@dataclass(frozen=True)
class WorkloadStats:
    """Average request shape (from the trace class; repro.traces)."""
    avg_prompt: float
    avg_output: float

    @property
    def avg_ctx_decode(self) -> float:
        return self.avg_prompt + self.avg_output / 2.0

    @property
    def max_ctx(self) -> float:
        return self.avg_prompt * 2.0 + self.avg_output * 2.0


def prefill_throughput(model: ServedModel, node: NodeConfig, j: int,
                       budget_s: float, wl: WorkloadStats) -> float:
    """Tokens/s of prefill for a stage of j layers on ``node``."""
    w_bytes = model.bytes_for_layers(j)
    mem = node.mem_gb * 1e9 * MEM_HEADROOM
    if w_bytes > mem:
        return 0.0
    eff_flops = node.tflops * 1e12 * node.tp_efficiency() * MFU_PREFILL
    eff_bw = node.bw_tbps * 1e12 * BW_EFF
    f_tok = model.flops_per_token_layer(wl.avg_prompt / 2, "prefill") * j
    net_tok = model.d_model * model.dtype_bytes / (INTER_NODE_GBPS * 1e9)
    fixed = ALPHA_PREFILL + w_bytes / eff_bw + INTER_NODE_LATENCY_S
    per_tok = f_tok / eff_flops + net_tok
    # the average prompt must fit one iteration within the stage budget
    if fixed + wl.avg_prompt * per_tok > budget_s:
        return 0.0
    chunk = min((budget_s - fixed) / per_tok, MAX_PREFILL_CHUNK)
    t = fixed + chunk * per_tok
    return chunk / t


def decode_throughput(model: ServedModel, node: NodeConfig, j: int,
                      budget_s: float, wl: WorkloadStats) -> float:
    """Tokens/s of decode for a stage of j layers on ``node``."""
    w_bytes = model.bytes_for_layers(j)
    mem = node.mem_gb * 1e9 * MEM_HEADROOM
    if w_bytes > mem:
        return 0.0
    eff_flops = node.tflops * 1e12 * node.tp_efficiency() * MFU_DECODE
    eff_bw = node.bw_tbps * 1e12 * BW_EFF
    ctx = wl.avg_ctx_decode
    if model.recurrent:
        kv_seq = j * 64 * model.d_model * 4     # SSM state, ctx-independent
    else:
        kv_seq = model.kv_bytes_per_seq(j, wl.max_ctx)
    b_mem = (mem - w_bytes) / max(kv_seq, 1.0)
    if b_mem < 1:
        return 0.0

    f_tok = model.flops_per_token_layer(ctx, "decode") * j
    net_tok = model.d_model * model.dtype_bytes / (INTER_NODE_GBPS * 1e9)

    def iter_time(b: float) -> float:
        return (ALPHA_DECODE + INTER_NODE_LATENCY_S
                + model.decode_read_bytes(j, b, ctx) / eff_bw
                + b * f_tok / eff_flops + b * net_tok)

    if iter_time(1.0) > budget_s:
        return 0.0
    # largest batch meeting the budget (iter_time is affine+monotone in b)
    lo, hi = 1.0, float(b_mem)
    if iter_time(hi) <= budget_s:
        b = hi
    else:
        for _ in range(40):
            mid = 0.5 * (lo + hi)
            if iter_time(mid) <= budget_s:
                lo = mid
            else:
                hi = mid
        b = lo
    return b / iter_time(b)


def decode_throughput_row(model: ServedModel, node: NodeConfig,
                          budget_s: float, wl: WorkloadStats) -> np.ndarray:
    """Vectorized ``decode_throughput`` over j = 1..n_layers.

    One array sweep replaces n_layers scalar calls (each with a 40-step
    batch bisection), which is what keeps ``LibraryColumns`` / template
    generation off scalar profile sweeps on cold caches.  Every
    operation mirrors the scalar function in the same order, so rows
    are bit-identical to the scalar sweep (tested in
    tests/test_profiles.py).
    """
    L = model.n_layers
    j = np.arange(1, L + 1, dtype=float)
    per = model.params_layer_total + model.embed_params / model.n_layers
    w_bytes = np.floor(j * per * model.dtype_bytes)    # int() truncation
    mem = node.mem_gb * 1e9 * MEM_HEADROOM
    eff_flops = node.tflops * 1e12 * node.tp_efficiency() * MFU_DECODE
    eff_bw = node.bw_tbps * 1e12 * BW_EFF
    ctx = wl.avg_ctx_decode
    if model.recurrent:
        kv_seq = j * 64 * model.d_model * 4
    else:
        kv_seq = j * model.kv_bytes_per_token_layer() \
            * model._ctx_eff(wl.max_ctx)
    b_mem = (mem - w_bytes) / np.maximum(kv_seq, 1.0)
    f_tok = model.flops_per_token_layer(ctx, "decode") * j
    net_tok = model.d_model * model.dtype_bytes / (INTER_NODE_GBPS * 1e9)
    kv_read = model.kv_read_bytes_layer(ctx)
    if model.n_experts:
        shared = (model.attn_params_layer + 2 * model.d_model
                  + model.embed_params / model.n_layers) * model.dtype_bytes
        expert_all = model.ffn_params_layer_total * model.dtype_bytes

        def read_bytes(b):
            frac = np.minimum(1.0, b * model.top_k / model.n_experts)
            return j * (shared + frac * expert_all) + b * j * kv_read
    else:
        def read_bytes(b):
            return w_bytes + b * j * kv_read

    base = ALPHA_DECODE + INTER_NODE_LATENCY_S

    def iter_time(b):
        return base + read_bytes(b) / eff_bw \
            + b * f_tok / eff_flops + b * net_tok

    with np.errstate(all="ignore"):
        feasible = (w_bytes <= mem) & (b_mem >= 1.0) \
            & (iter_time(np.ones(L)) <= budget_s)
        hi = np.where(b_mem >= 1.0, b_mem, 1.0)
        full = iter_time(hi) <= budget_s
        lo, hw = np.ones(L), hi.copy()
        for _ in range(40):
            mid = 0.5 * (lo + hw)
            ok = iter_time(mid) <= budget_s
            lo = np.where(ok, mid, lo)
            hw = np.where(ok, hw, mid)
        b = np.where(full, hi, lo)
        thr = b / iter_time(b)
    return np.where(feasible, thr, 0.0)


def throughput(model: ServedModel, node: NodeConfig, j: int, phase: str,
               budget_s: float, wl: WorkloadStats) -> float:
    fn = prefill_throughput if phase == "prefill" else decode_throughput
    return fn(model, node, j, budget_s, wl)


class ProfileTable:
    """Monotone T̂_j(g) tables per (model, phase, n_stages).

    ``table(node, S)[j-1]`` = T̂_j(node) under per-stage budget slo/S,
    made non-increasing in j (required by the exact placement solver;
    physically, more layers on the same node is never faster).

    Rows are memoized process-wide, keyed by the frozen value objects
    (model, phase, SLO, workload, node, S): every table instance over
    the same inputs — repeated ``generate_templates`` calls, homo vs.
    Coral libraries, benchmark sweeps over n_max — shares one computed
    row. Each row costs an L-point sweep of the analytic cost model
    (with a 40-step bisection per decode entry), so sharing them keeps
    the offline pipeline's profile cost a true one-time expense.
    Callers must treat returned arrays as read-only.
    """

    _shared: Dict = {}

    def __init__(self, model: ServedModel, phase: str, slo_ms: float,
                 wl: WorkloadStats, max_stages: int = 8):
        self.model = model
        self.phase = phase
        self.slo_s = slo_ms / 1e3
        self.wl = wl
        self.max_stages = max_stages

    def table(self, node: NodeConfig, n_stages: int) -> np.ndarray:
        key = (self.model, self.phase, self.slo_s, self.wl, node, n_stages)
        row = self._shared.get(key)
        if row is None:
            budget = self.slo_s / n_stages
            L = self.model.n_layers
            if self.phase == "decode":
                # one vectorized sweep over all j (batch bisection incl.)
                vals = decode_throughput_row(self.model, node, budget,
                                             self.wl)
            else:
                vals = np.array([throughput(self.model, node, j, self.phase,
                                            budget, self.wl)
                                 for j in range(1, L + 1)])
            row = np.minimum.accumulate(vals)
            row.setflags(write=False)       # shared across callers
            self._shared[key] = row
        return row
