"""Serving Template generation (paper §4.2).

Offline: for each (model, phase, SLO), enumerate node combinations with
at most ``n_max`` nodes and total memory within [fit, rho x model size],
and compute the throughput-optimal placement on each — yielding the
Serving Template Library the online allocator consumes.

Beyond the paper (DESIGN.md §6): usage-dominance Pareto pruning — a
template is dropped if another template of the same (model, phase) has
>= throughput and <= node usage of *every* config. Dominance in usage
implies dominance in cost (any price vector) and in every availability
constraint, so pruning is lossless for the online ILP.
"""
from __future__ import annotations

import itertools
import json
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig
from repro.core.modelspec import ServedModel
from repro.core.placement import (Placement, optimal_placement_exact,
                                  optimal_placement_ilp)
from repro.core.profiles import ProfileTable, WorkloadStats


@dataclass(frozen=True)
class ServingTemplate:
    model: str
    phase: str                              # prefill | decode
    slo_ms: float
    counts: Tuple[Tuple[str, int], ...]     # sorted (config_name, n)
    placement: Placement
    throughput: float

    @property
    def key(self) -> Tuple:
        return (self.model, self.phase, self.counts)

    @property
    def n_nodes(self) -> int:
        return sum(n for _, n in self.counts)

    def usage(self) -> Dict[str, int]:
        return dict(self.counts)

    def cost(self, region, config_by_name: Dict[str, NodeConfig]) -> float:
        return sum(region.node_usd_per_hour(config_by_name[c]) * n
                   for c, n in self.counts)


def enumerate_combos(configs: Sequence[NodeConfig], n_max: int,
                     mem_lo_gb: float, mem_hi_gb: float
                     ) -> Iterable[Tuple[NodeConfig, ...]]:
    """Multisets of <= n_max nodes with total memory in [lo, hi]."""
    cfgs = sorted(configs, key=lambda c: c.mem_gb)
    min_mem = cfgs[0].mem_gb

    def rec(start: int, left: int, mem: float, acc):
        if mem >= mem_lo_gb:
            yield tuple(acc)
        if left == 0:
            return
        for i in range(start, len(cfgs)):
            m = cfgs[i].mem_gb
            if mem + m > mem_hi_gb:
                continue
            acc.append(cfgs[i])
            yield from rec(i, left - 1, mem + m, acc)
            acc.pop()

    yield from rec(0, n_max, 0.0, [])


@dataclass
class TemplateLibrary:
    templates: Dict[Tuple[str, str], List[ServingTemplate]] = field(
        default_factory=dict)
    config_by_name: Dict[str, NodeConfig] = field(default_factory=dict)
    stats: Dict[Tuple[str, str], Dict] = field(default_factory=dict)

    def get(self, model: str, phase: str) -> List[ServingTemplate]:
        return self.templates.get((model, phase), [])

    def add(self, key, temps: List[ServingTemplate], stats: Dict):
        self.templates[key] = temps
        self.stats[key] = stats

    @property
    def size(self) -> int:
        return sum(len(v) for v in self.templates.values())


def pareto_prune(temps: List[ServingTemplate],
                 config_names: Sequence[str]) -> List[ServingTemplate]:
    """Drop usage-dominated templates (lossless, see module docstring)."""
    if not temps:
        return temps
    order = sorted(temps, key=lambda t: -t.throughput)
    n = len(order)
    usage = np.array([[t.usage().get(c, 0) for c in config_names]
                      for t in order])
    tput = np.array([t.throughput for t in order])
    kept_idx: List[int] = []
    kept_usage = np.empty((n, len(config_names)), usage.dtype)
    kept_tput = np.empty((n,), tput.dtype)
    k = 0
    for i in range(n):
        if k:
            ku = kept_usage[:k]
            kt = kept_tput[:k]
            dom = (ku <= usage[i]).all(axis=1) & (kt >= tput[i] - 1e-12)
            # strict domination only (keep equals once)
            strict = dom & ((ku < usage[i]).any(axis=1)
                            | (kt > tput[i] + 1e-12))
            if strict.any() or (dom & ~strict).any():
                continue
        kept_idx.append(i)
        kept_usage[k] = usage[i]
        kept_tput[k] = tput[i]
        k += 1
    return [order[i] for i in kept_idx]


def generate_templates(model: ServedModel, phase: str,
                       configs: Sequence[NodeConfig], wl: WorkloadStats,
                       n_max: int = 6, rho: float = 12.0,
                       solver: str = "exact", prune: bool = True,
                       max_stages: Optional[int] = None,
                       ) -> Tuple[List[ServingTemplate], Dict]:
    """The Serving Template generator for one (model, SLO, phase)."""
    t0 = time.time()
    slo_ms = model.prefill_slo_ms if phase == "prefill" else model.decode_slo_ms
    pt = ProfileTable(model, phase, slo_ms, wl)
    by_name = {c.name: c for c in configs}
    tables = lambda name, S: pt.table(by_name[name], S)

    model_gb = model.bytes_total / 1e9
    lo = model_gb * (0.9 if phase == "prefill" else 1.0)
    # tiny models: rho x model_size can undershoot even one node's HBM;
    # a single smallest node must always be admissible
    hi = max(model_gb * rho, min(c.mem_gb for c in configs) + 1e-9)
    out: List[ServingTemplate] = []
    n_combos = 0
    solve = optimal_placement_exact if solver == "exact" \
        else optimal_placement_ilp
    for combo in enumerate_combos(configs, n_max, lo, hi):
        n_combos += 1
        names = [c.name for c in combo]
        pl = solve(names, tables, model.n_layers, max_stages=max_stages)
        if pl is None or pl.throughput <= 0:
            continue
        counts: Dict[str, int] = {}
        for n in names:
            counts[n] = counts.get(n, 0) + 1
        out.append(ServingTemplate(
            model.name, phase, slo_ms,
            tuple(sorted(counts.items())), pl, pl.throughput))
    n_raw = len(out)
    if prune:
        out = pareto_prune(out, sorted(by_name))
    stats = {"combos": n_combos, "templates_raw": n_raw,
             "templates": len(out), "seconds": time.time() - t0,
             "n_max": n_max, "rho": rho}
    return out, stats


def build_library(models: Sequence[ServedModel],
                  configs: Sequence[NodeConfig],
                  workloads: Dict[str, WorkloadStats],
                  n_max: int = 6, rho: float = 12.0,
                  prune: bool = True, solver: str = "exact",
                  max_stages: Optional[int] = None) -> TemplateLibrary:
    lib = TemplateLibrary(config_by_name={c.name: c for c in configs})
    for m in models:
        wl = workloads[m.name]
        for phase in ("prefill", "decode"):
            temps, stats = generate_templates(
                m, phase, configs, wl, n_max=n_max, rho=rho, prune=prune,
                solver=solver, max_stages=max_stages)
            lib.add((m.name, phase), temps, stats)
    return lib
