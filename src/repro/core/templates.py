"""Serving Template generation (paper §4.2).

Offline: for each (model, phase, SLO), enumerate node combinations with
at most ``n_max`` nodes and total memory within [fit, rho x model size],
and compute the throughput-optimal placement on each — yielding the
Serving Template Library the online allocator consumes.

Beyond the paper (DESIGN.md §6): usage-dominance Pareto pruning — a
template is dropped if another template of the same (model, phase) has
>= throughput and <= node usage of *every* config. Dominance in usage
implies dominance in cost (any price vector) and in every availability
constraint, so pruning is lossless for the online ILP.

Performance: the default ``solver="fast"`` path threads one
``repro.core.placement.PlacementCache`` per (model, phase) through the
combo enumeration, so partition structures and per-(stage-group, S) T̂
rows are shared across the thousands of combos drawn from the same small
config universe. Measured on this container (qwen3-32b decode, core
12-config setup, n_max=6, rho=12, 12,990 combos): 212s with the seed
per-combo exact solver -> ~6s, identical post-prune template set
(12,755 templates, max throughput delta 0.0; prefill: 203s -> ~6s over
12,980 templates). ``build_library(..., reuse=old_lib)`` skips every
(model, phase) pair whose generation inputs (config universe, n_max,
rho, SLO, workload) are unchanged — the incremental mode used by
``benchmarks.common.cached_library`` and epoch runtimes when the config
universe drifts.
"""
from __future__ import annotations

import itertools
import json
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig
from repro.core.modelspec import ServedModel
from repro.core.placement import (Placement, PlacementCache,
                                  optimal_placement_exact,
                                  optimal_placement_ilp)
from repro.core.profiles import ProfileTable, WorkloadStats


@dataclass(frozen=True)
class ServingTemplate:
    model: str
    phase: str                              # prefill | decode
    slo_ms: float
    counts: Tuple[Tuple[str, int], ...]     # sorted (config_name, n)
    placement: Placement
    throughput: float

    @property
    def key(self) -> Tuple:
        return (self.model, self.phase, self.counts)

    @property
    def n_nodes(self) -> int:
        return sum(n for _, n in self.counts)

    def usage(self) -> Dict[str, int]:
        return dict(self.counts)

    def cost(self, region, config_by_name: Dict[str, NodeConfig]) -> float:
        return sum(region.node_usd_per_hour(config_by_name[c]) * n
                   for c, n in self.counts)


def enumerate_combos(configs: Sequence[NodeConfig], n_max: int,
                     mem_lo_gb: float, mem_hi_gb: float
                     ) -> Iterable[Tuple[NodeConfig, ...]]:
    """Multisets of <= n_max nodes with total memory in [lo, hi]."""
    cfgs = sorted(configs, key=lambda c: c.mem_gb)
    min_mem = cfgs[0].mem_gb

    def rec(start: int, left: int, mem: float, acc):
        if mem >= mem_lo_gb:
            yield tuple(acc)
        if left == 0:
            return
        for i in range(start, len(cfgs)):
            m = cfgs[i].mem_gb
            if mem + m > mem_hi_gb:
                continue
            acc.append(cfgs[i])
            yield from rec(i, left - 1, mem + m, acc)
            acc.pop()

    yield from rec(0, n_max, 0.0, [])


@dataclass
class LibraryColumns:
    """Columnar (array-form) view of one (model, phase) template set.

    The online allocator consumes templates as arrays, not objects:
    ``usage`` is the (templates x configs) node-usage matrix over the
    library-wide sorted config universe, ``throughput`` the matching
    tokens/s vector.  ``region_cost(regions)`` collapses per-template
    per-region provisioning cost into one ``usage @ price.T`` matmul —
    the Pareto/var-cap selection, shortfall penalties and per-var upper
    bounds in ``repro.core.allocator`` are all vectorized ops over these
    arrays.
    """
    templates: List[ServingTemplate]
    keys: List[Tuple]
    config_names: Tuple[str, ...]
    config_by_name: Dict[str, NodeConfig]
    usage: np.ndarray          # (T, C) float64, counts per config
    throughput: np.ndarray     # (T,)  float64

    @property
    def n(self) -> int:
        return len(self.templates)

    def price_matrix(self, regions) -> np.ndarray:
        """(R, C) node $/h per (region, config)."""
        return np.array([[r.node_usd_per_hour(self.config_by_name[c])
                          for c in self.config_names] for r in regions])

    def region_cost(self, regions) -> np.ndarray:
        """(T, R) instance $/h of each template in each region."""
        return self.usage @ self.price_matrix(regions).T


def template_columns(temps: Sequence[ServingTemplate],
                     config_by_name: Dict[str, NodeConfig]
                     ) -> LibraryColumns:
    """Build the columnar view of a template list (see LibraryColumns)."""
    names = tuple(sorted(config_by_name))
    cidx = {c: i for i, c in enumerate(names)}
    usage = np.zeros((len(temps), len(names)))
    for i, t in enumerate(temps):
        for c, k in t.counts:
            usage[i, cidx[c]] = k
    thr = np.array([t.throughput for t in temps], dtype=float)
    return LibraryColumns(list(temps), [t.key for t in temps], names,
                          config_by_name, usage, thr)


@dataclass
class TemplateLibrary:
    templates: Dict[Tuple[str, str], List[ServingTemplate]] = field(
        default_factory=dict)
    config_by_name: Dict[str, NodeConfig] = field(default_factory=dict)
    stats: Dict[Tuple[str, str], Dict] = field(default_factory=dict)

    def get(self, model: str, phase: str) -> List[ServingTemplate]:
        return self.templates.get((model, phase), [])

    def add(self, key, temps: List[ServingTemplate], stats: Dict):
        self.templates[key] = temps
        self.stats[key] = stats
        self.__dict__.get("_columns_cache", {}).pop(key, None)

    def columns(self, model: str, phase: str) -> LibraryColumns:
        """Cached columnar view of one (model, phase) template set.

        The cache lives in ``__dict__`` (not a dataclass field) so
        libraries unpickled from older artifacts lazily grow it; ``add``
        invalidates the affected pair.
        """
        cache = self.__dict__.setdefault("_columns_cache", {})
        key = (model, phase)
        cols = cache.get(key)
        if cols is None:
            cols = template_columns(self.get(model, phase),
                                    self.config_by_name)
            cache[key] = cols
        return cols

    @property
    def size(self) -> int:
        return sum(len(v) for v in self.templates.values())


def pareto_prune(temps: List[ServingTemplate],
                 config_names: Sequence[str]) -> List[ServingTemplate]:
    """Drop usage-dominated templates (lossless, see module docstring).

    Processing in descending-throughput order, every already-kept
    template has throughput >= the candidate's, so dominance reduces to
    componentwise usage <= (equal-usage duplicates kept once). Usage
    vectors (counts <= 15) are packed into 5-bit SWAR fields, 12 configs
    per uint64 word: ``a <= b`` componentwise iff every field's guard
    bit survives ``(b | H) - a``, one subtract+mask per pair per word.
    The scan then runs as blocked numpy passes — each block against all
    previously kept words, then a short sequential pass inside the
    block — ~100x faster than the seed's per-template Python loop on
    paper-scale (~13k raw) libraries, where nearly every usage vector is
    distinct and the scan effectively certifies an antichain.
    """
    if not temps:
        return temps
    order = sorted(temps, key=lambda t: -t.throughput)
    n = len(order)
    d = len(config_names)
    usage = np.array([[t.usage().get(c, 0) for c in config_names]
                      for t in order], dtype=np.int64)
    if usage.max(initial=0) <= 15:
        # pack counts into 5-bit fields, 12 configs per uint64 word
        W = (d + 11) // 12
        packed = np.zeros((n, W), dtype=np.uint64)
        guard = np.zeros(W, dtype=np.uint64)
        for c in range(d):
            w, off = divmod(c, 12)
            packed[:, w] |= usage[:, c].astype(np.uint64) \
                << np.uint64(5 * off)
            guard[w] |= np.uint64(1) << np.uint64(5 * off + 4)

        def dominates(ku, blk):
            # (kept, cand): every 5-bit field of kept <= field of cand;
            # the guard bit of (cand | H) - kept survives iff no borrow,
            # i.e. cand_field >= kept_field
            ok = np.ones((ku.shape[0], blk.shape[0]), dtype=bool)
            for w in range(W):
                t = (blk[None, :, w] | guard[w]) - ku[:, None, w]
                ok &= (t & guard[w]) == guard[w]
            return ok
    else:
        # counts too large for the SWAR fields (n_max > 15): plain
        # broadcast comparison, same semantics
        packed = usage

        def dominates(ku, blk):
            return (ku[:, None, :] <= blk[None, :, :]).all(axis=2)

    kept_idx: List[int] = []
    kept = np.empty_like(packed)
    k = 0
    B, C = 256, 2048
    for b0 in range(0, n, B):
        blk = packed[b0:min(b0 + B, n)]
        cand = np.arange(len(blk))
        # early-kept (high-throughput, low-usage) rows eliminate most of
        # a block, so scan the kept set in chunks and shrink the block
        for c0 in range(0, k, C):
            dom = dominates(kept[c0:min(c0 + C, k)], blk[cand]).any(axis=0)
            cand = cand[~dom]
            if not len(cand):
                break
        k0 = k
        for i in cand:
            if k > k0 and dominates(kept[k0:k], blk[i:i + 1]).any():
                continue
            kept_idx.append(b0 + int(i))
            kept[k] = blk[i]
            k += 1
    return [order[i] for i in kept_idx]


def generation_fingerprint(model: ServedModel, phase: str,
                           configs: Sequence[NodeConfig], wl: WorkloadStats,
                           n_max: int, rho: float, prune: bool, solver: str,
                           max_stages: Optional[int]) -> Tuple:
    """Everything the template set of one (model, phase) depends on.

    Two generation requests with equal fingerprints produce equal
    template sets, which is what lets ``build_library(reuse=...)`` skip
    pairs whose config universe (or any other input) did not change.
    NodeConfig and WorkloadStats are frozen value objects, so they go in
    whole — any field feeding the cost model (including the embedded
    DeviceType's interconnect data) participates in the comparison.
    """
    cfg = tuple(sorted(configs, key=lambda c: c.name))
    return (model, phase, cfg, wl, n_max, rho, prune, solver, max_stages)


def generate_templates(model: ServedModel, phase: str,
                       configs: Sequence[NodeConfig], wl: WorkloadStats,
                       n_max: int = 6, rho: float = 12.0,
                       solver: str = "fast", prune: bool = True,
                       max_stages: Optional[int] = None,
                       cache: Optional[PlacementCache] = None,
                       ) -> Tuple[List[ServingTemplate], Dict]:
    """The Serving Template generator for one (model, SLO, phase).

    ``solver``: "fast" (default; cached/vectorized, same optimum),
    "exact" (reference per-combo combinatorial solver) or "ilp" (paper
    formulation). ``cache`` lets callers reuse a ``PlacementCache``
    across calls that share (model, phase, SLO, workload) — e.g. the
    per-config sub-universes of ``homo_library``.
    """
    t0 = time.time()
    slo_ms = model.prefill_slo_ms if phase == "prefill" else model.decode_slo_ms
    pt = ProfileTable(model, phase, slo_ms, wl)
    by_name = {c.name: c for c in configs}
    tables = lambda name, S: pt.table(by_name[name], S)

    model_gb = model.bytes_total / 1e9
    lo = model_gb * (0.9 if phase == "prefill" else 1.0)
    # tiny models: rho x model_size can undershoot even one node's HBM;
    # a single smallest node must always be admissible
    hi = max(model_gb * rho, min(c.mem_gb for c in configs) + 1e-9)
    if solver not in ("fast", "exact", "ilp"):
        raise ValueError(f"unknown solver {solver!r}; "
                         f"expected 'fast', 'exact' or 'ilp'")
    out: List[ServingTemplate] = []
    if solver == "fast":
        if cache is None:
            cache = PlacementCache(tables, model.n_layers)
        names_list = [[c.name for c in combo]
                      for combo in enumerate_combos(configs, n_max, lo, hi)]
        n_combos = len(names_list)
        placements = zip(names_list,
                         cache.solve_batch(names_list,
                                           max_stages=max_stages))
    else:
        solve = optimal_placement_exact if solver == "exact" \
            else optimal_placement_ilp

        def _solve_all():
            for combo in enumerate_combos(configs, n_max, lo, hi):
                names = [c.name for c in combo]
                yield names, solve(names, tables, model.n_layers,
                                   max_stages=max_stages)
        n_combos = 0
        placements = _solve_all()
    for names, pl in placements:
        if solver != "fast":
            n_combos += 1
        if pl is None or pl.throughput <= 0:
            continue
        counts: Dict[str, int] = {}
        for n in names:
            counts[n] = counts.get(n, 0) + 1
        out.append(ServingTemplate(
            model.name, phase, slo_ms,
            tuple(sorted(counts.items())), pl, pl.throughput))
    n_raw = len(out)
    if prune:
        out = pareto_prune(out, sorted(by_name))
    stats = {"combos": n_combos, "templates_raw": n_raw,
             "templates": len(out), "seconds": time.time() - t0,
             "n_max": n_max, "rho": rho,
             "fingerprint": generation_fingerprint(
                 model, phase, configs, wl, n_max, rho, prune, solver,
                 max_stages)}
    return out, stats


def build_library(models: Sequence[ServedModel],
                  configs: Sequence[NodeConfig],
                  workloads: Dict[str, WorkloadStats],
                  n_max: int = 6, rho: float = 12.0,
                  prune: bool = True, solver: str = "fast",
                  max_stages: Optional[int] = None,
                  reuse: Optional[TemplateLibrary] = None) -> TemplateLibrary:
    """Build the full Serving Template Library.

    ``reuse``: a previously built library; any (model, phase) whose
    generation fingerprint matches is copied over instead of re-solved
    (incremental rebuild when only part of the config universe or model
    set changed).
    """
    lib = TemplateLibrary(config_by_name={c.name: c for c in configs})
    for m in models:
        wl = workloads[m.name]
        for phase in ("prefill", "decode"):
            fp = generation_fingerprint(m, phase, configs, wl, n_max, rho,
                                        prune, solver, max_stages)
            if reuse is not None:
                old = reuse.stats.get((m.name, phase))
                if old is not None and old.get("fingerprint") == fp:
                    lib.add((m.name, phase),
                            list(reuse.templates[(m.name, phase)]),
                            dict(old, reused=True))
                    continue
            temps, stats = generate_templates(
                m, phase, configs, wl, n_max=n_max, rho=rho, prune=prune,
                solver=solver, max_stages=max_stages)
            lib.add((m.name, phase), temps, stats)
    return lib
