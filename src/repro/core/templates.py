"""Serving Template generation (paper §4.2).

Offline: for each (model, phase, SLO), enumerate node combinations with
at most ``n_max`` nodes and total memory within [fit, rho x model size],
and compute the throughput-optimal placement on each — yielding the
Serving Template Library the online allocator consumes.

Beyond the paper (DESIGN.md §6): usage-dominance Pareto pruning — a
template is dropped if another template of the same (model, phase) has
>= throughput and <= node usage of *every* config. Dominance in usage
implies dominance in cost (any price vector) and in every availability
constraint, so pruning is lossless for the online ILP. Throughput ties
break toward the smaller usage (``_template_order_key``), so a superset
combo that gains nothing over a sub-combo is always the one dropped.

Performance: the default ``solver="fast"`` path threads one
``repro.core.placement.PlacementCache`` per (model, phase) through a
*level-wise frontier* (``_frontier_generate``): combos grow one node at
a time and each is solved with its best enumerated sub-combo throughput
as the incumbent, so dominated combos — the majority of the extended
setup's search space — are discharged at the partition-bound stage and
the post-prune template set falls out of the enumeration directly
(``cross_check=True`` proves bit-identity against exhaustive
enumeration + ``pareto_prune``). Measured on this container: qwen3-32b
decode, core 12-config setup (n_max=6, rho=12, 12,990 combos): 212s
with the seed per-combo exact solver -> ~2s; extended 20-config
llama3-70b decode (n_max=6, 202k combos): ~7 min with the PR-1 batch
solver -> ~60s, which is what lets the benchmark suite run the
extended setup at the paper parameters (the old BENCH_FAST capped it
at n_max=5). ``build_library(..., reuse=old_lib)`` skips every
(model, phase) pair whose generation inputs (config universe, n_max,
rho, SLO, workload, ``GENERATION_VERSION``) are unchanged — the
incremental mode used by ``benchmarks.common.cached_library`` and
epoch runtimes when the config universe drifts.
"""
from __future__ import annotations

import itertools
import os
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig
from repro.core.modelspec import ServedModel
from repro.core.placement import (Placement, PlacementCache,
                                  optimal_placement_exact,
                                  optimal_placement_ilp)
from repro.core.profiles import ProfileTable, WorkloadStats


@dataclass(frozen=True)
class ServingTemplate:
    model: str
    phase: str                              # prefill | decode
    slo_ms: float
    counts: Tuple[Tuple[str, int], ...]     # sorted (config_name, n)
    placement: Placement
    throughput: float

    @property
    def key(self) -> Tuple:
        return (self.model, self.phase, self.counts)

    @property
    def n_nodes(self) -> int:
        return sum(n for _, n in self.counts)

    def usage(self) -> Dict[str, int]:
        return dict(self.counts)

    def cost(self, region, config_by_name: Dict[str, NodeConfig]) -> float:
        return sum(region.node_usd_per_hour(config_by_name[c]) * n
                   for c, n in self.counts)


def enumerate_combos(configs: Sequence[NodeConfig], n_max: int,
                     mem_lo_gb: float, mem_hi_gb: float
                     ) -> Iterable[Tuple[NodeConfig, ...]]:
    """Multisets of <= n_max nodes with total memory in [lo, hi]."""
    cfgs = sorted(configs, key=lambda c: c.mem_gb)
    min_mem = cfgs[0].mem_gb

    def rec(start: int, left: int, mem: float, acc):
        if mem >= mem_lo_gb:
            yield tuple(acc)
        if left == 0:
            return
        for i in range(start, len(cfgs)):
            m = cfgs[i].mem_gb
            if mem + m > mem_hi_gb:
                continue
            acc.append(cfgs[i])
            yield from rec(i, left - 1, mem + m, acc)
            acc.pop()

    yield from rec(0, n_max, 0.0, [])


@dataclass
class LibraryColumns:
    """Columnar (array-form) view of one (model, phase) template set.

    The online allocator consumes templates as arrays, not objects:
    ``usage`` is the (templates x configs) node-usage matrix over the
    library-wide sorted config universe, ``throughput`` the matching
    tokens/s vector.  ``region_cost(regions)`` collapses per-template
    per-region provisioning cost into one ``usage @ price.T`` matmul —
    the Pareto/var-cap selection, shortfall penalties and per-var upper
    bounds in ``repro.core.allocator`` are all vectorized ops over these
    arrays.
    """
    templates: List[ServingTemplate]
    keys: List[Tuple]
    config_names: Tuple[str, ...]
    config_by_name: Dict[str, NodeConfig]
    usage: np.ndarray          # (T, C) float64, counts per config
    throughput: np.ndarray     # (T,)  float64

    @property
    def n(self) -> int:
        return len(self.templates)

    def price_matrix(self, regions) -> np.ndarray:
        """(R, C) node $/h per (region, config)."""
        return np.array([[r.node_usd_per_hour(self.config_by_name[c])
                          for c in self.config_names] for r in regions])

    def region_cost(self, regions) -> np.ndarray:
        """(T, R) instance $/h of each template in each region."""
        return self.usage @ self.price_matrix(regions).T


def template_columns(temps: Sequence[ServingTemplate],
                     config_by_name: Dict[str, NodeConfig]
                     ) -> LibraryColumns:
    """Build the columnar view of a template list (see LibraryColumns)."""
    names = tuple(sorted(config_by_name))
    cidx = {c: i for i, c in enumerate(names)}
    usage = np.zeros((len(temps), len(names)))
    for i, t in enumerate(temps):
        for c, k in t.counts:
            usage[i, cidx[c]] = k
    thr = np.array([t.throughput for t in temps], dtype=float)
    return LibraryColumns(list(temps), [t.key for t in temps], names,
                          config_by_name, usage, thr)


@dataclass
class TemplateLibrary:
    templates: Dict[Tuple[str, str], List[ServingTemplate]] = field(
        default_factory=dict)
    config_by_name: Dict[str, NodeConfig] = field(default_factory=dict)
    stats: Dict[Tuple[str, str], Dict] = field(default_factory=dict)

    def get(self, model: str, phase: str) -> List[ServingTemplate]:
        return self.templates.get((model, phase), [])

    def add(self, key, temps: List[ServingTemplate], stats: Dict):
        self.templates[key] = temps
        self.stats[key] = stats
        self.__dict__.get("_columns_cache", {}).pop(key, None)

    def columns(self, model: str, phase: str) -> LibraryColumns:
        """Cached columnar view of one (model, phase) template set.

        The cache lives in ``__dict__`` (not a dataclass field) so
        libraries unpickled from older artifacts lazily grow it; ``add``
        invalidates the affected pair.
        """
        cache = self.__dict__.setdefault("_columns_cache", {})
        key = (model, phase)
        cols = cache.get(key)
        if cols is None:
            cols = template_columns(self.get(model, phase),
                                    self.config_by_name)
            cache[key] = cols
        return cols

    @property
    def size(self) -> int:
        return sum(len(v) for v in self.templates.values())


def _template_order_key(t: ServingTemplate):
    """Deterministic dominance-compatible total order: descending
    throughput, then ascending node count, then counts. Any potential
    dominator (usage <=, throughput >=) of a template sorts strictly
    before it — equal-throughput ties are broken toward the *smaller*
    usage (a proper sub-multiset has strictly fewer nodes), so a
    superset that gains nothing over a sub-combo is always dropped.
    (The pre-PR-4 prune broke throughput ties by enumeration order,
    which kept such redundant supersets whenever they happened to
    enumerate first; dropping them is lossless for the allocator —
    the kept sub-combo has <= usage, hence <= cost in every region.)"""
    return (-t.throughput, t.n_nodes, t.counts)


def pareto_prune(temps: List[ServingTemplate],
                 config_names: Sequence[str]) -> List[ServingTemplate]:
    """Drop usage-dominated templates (lossless, see module docstring).

    A template is dominated iff another template's usage is a
    sub-multiset of its own with throughput >= (equal-usage duplicates
    kept once). Since usage counts sum to <= n_max, each template has at
    most prod(count_i + 1) <= 2^n_max sub-multisets, so instead of the
    all-pairs scan the pruned set is found by *box enumeration*: hash
    every usage vector (packed integer code) to its best throughput,
    then probe each template's sub-multiset codes — O(n * 2^n_max)
    total, sub-quadratic in n, vectorized per usage shape with one
    int-matmul for the probe codes. Inputs whose counts or dimensions
    overflow the packing (or whose boxes are too large) fall back to the
    blocked pairwise SWAR scan, which implements the same semantics.

    Output is sorted by ``_template_order_key`` (deterministic).
    """
    if not temps:
        return temps
    order = sorted(temps, key=_template_order_key)
    names = list(config_names)
    usage = np.array([[t.usage().get(c, 0) for c in names]
                      for t in order], dtype=np.int64)
    thr = np.array([t.throughput for t in order], dtype=float)
    kept = _pareto_mask_boxes(usage, thr)
    if kept is None:
        kept = _pareto_mask_pairwise(usage)
    return [t for t, k in zip(order, kept) if k]


def _pareto_mask_boxes(usage: np.ndarray, thr: np.ndarray,
                       budget: float = 5e7) -> Optional[np.ndarray]:
    """Keep-mask over rows sorted by ``_template_order_key`` via
    sub-multiset (box) probing; ``None`` when the input doesn't fit the
    packed codes or the total box volume exceeds ``budget``."""
    n, d = usage.shape
    bits = max(int(usage.max(initial=0)).bit_length(), 1)
    if d * bits > 62:
        return None
    boxes = np.prod(usage + 1.0, axis=1)
    if boxes.sum() > budget:
        return None
    pw = np.int64(1) << (np.int64(bits) * np.arange(d, dtype=np.int64))
    codes = usage @ pw
    uniq, first = np.unique(codes, return_index=True)
    # rows are throughput-sorted, so the first row of a code group has
    # the group's max throughput (and is the one duplicate kept)
    bestT = thr[first]
    kept = np.zeros(n, dtype=bool)
    kept[first] = True
    # per usage-shape: one delta matrix enumerates every proper
    # sub-multiset, probe codes come from one int matmul
    rankd = np.arange(d)
    perm = np.lexsort((np.broadcast_to(rankd, usage.shape), -usage), axis=-1)
    us = np.take_along_axis(usage, perm, axis=1)
    shapes, sinv = np.unique(us, axis=0, return_inverse=True)
    sinv = sinv.ravel()
    for si in range(len(shapes)):
        srow = shapes[si]
        m = int(np.count_nonzero(srow))
        if m == 0:
            continue
        members = np.nonzero(sinv == si)[0]
        deltas = np.array(list(itertools.product(
            *(range(int(c) + 1) for c in srow[:m]))), dtype=np.int64)[1:]
        if not len(deltas):
            continue
        lab_pw = pw[perm[members][:, :m]]              # (C, m)
        step = max(1, int(2_000_000 // max(len(deltas), 1)))
        for c0 in range(0, len(members), step):
            mem = members[c0:c0 + step]
            sub = codes[mem, None] - lab_pw[c0:c0 + step] @ deltas.T
            pos = np.searchsorted(uniq, sub)
            pos_c = np.minimum(pos, len(uniq) - 1)
            hit = (uniq[pos_c] == sub) & (bestT[pos_c] >= thr[mem, None])
            kept[mem] &= ~hit.any(axis=1)
    return kept


def _pareto_mask_pairwise(usage: np.ndarray) -> np.ndarray:
    """Keep-mask over rows sorted by ``_template_order_key`` via the
    blocked pairwise scan (SWAR-packed when counts <= 15): every
    already-kept row sorts before the candidate, so dominance reduces
    to componentwise usage <=. Reference semantics for the box path."""
    n, d = usage.shape
    if usage.max(initial=0) <= 15:
        # pack counts into 5-bit fields, 12 configs per uint64 word
        W = (d + 11) // 12
        packed = np.zeros((n, W), dtype=np.uint64)
        guard = np.zeros(W, dtype=np.uint64)
        for c in range(d):
            w, off = divmod(c, 12)
            packed[:, w] |= usage[:, c].astype(np.uint64) \
                << np.uint64(5 * off)
            guard[w] |= np.uint64(1) << np.uint64(5 * off + 4)

        def dominates(ku, blk):
            # (kept, cand): every 5-bit field of kept <= field of cand;
            # the guard bit of (cand | H) - kept survives iff no borrow,
            # i.e. cand_field >= kept_field
            ok = np.ones((ku.shape[0], blk.shape[0]), dtype=bool)
            for w in range(W):
                t = (blk[None, :, w] | guard[w]) - ku[:, None, w]
                ok &= (t & guard[w]) == guard[w]
            return ok
    else:
        # counts too large for the SWAR fields: plain broadcast
        # comparison, same semantics
        packed = usage

        def dominates(ku, blk):
            return (ku[:, None, :] <= blk[None, :, :]).all(axis=2)

    mask = np.zeros(n, dtype=bool)
    kept = np.empty_like(packed)
    k = 0
    B, C = 256, 2048
    for b0 in range(0, n, B):
        blk = packed[b0:min(b0 + B, n)]
        cand = np.arange(len(blk))
        # early-kept (high-throughput, low-usage) rows eliminate most of
        # a block, so scan the kept set in chunks and shrink the block
        for c0 in range(0, k, C):
            dom = dominates(kept[c0:min(c0 + C, k)], blk[cand]).any(axis=0)
            cand = cand[~dom]
            if not len(cand):
                break
        k0 = k
        for i in cand:
            if k > k0 and dominates(kept[k0:k], blk[i:i + 1]).any():
                continue
            mask[b0 + int(i)] = True
            kept[k] = blk[i]
            k += 1
    return mask


# bump when the produced template set changes for identical inputs
# (e.g. the PR-4 dominance-compatible tie-break in pareto_prune), so
# cached libraries and ``build_library(reuse=...)`` invalidate cleanly
GENERATION_VERSION = 2


def generation_fingerprint(model: ServedModel, phase: str,
                           configs: Sequence[NodeConfig], wl: WorkloadStats,
                           n_max: int, rho: float, prune: bool, solver: str,
                           max_stages: Optional[int]) -> Tuple:
    """Everything the template set of one (model, phase) depends on.

    Two generation requests with equal fingerprints produce equal
    template sets, which is what lets ``build_library(reuse=...)`` skip
    pairs whose config universe (or any other input) did not change.
    NodeConfig and WorkloadStats are frozen value objects, so they go in
    whole — any field feeding the cost model (including the embedded
    DeviceType's interconnect data) participates in the comparison.
    """
    cfg = tuple(sorted(configs, key=lambda c: c.name))
    return (GENERATION_VERSION, model, phase, cfg, wl, n_max, rho, prune,
            solver, max_stages)


def _frontier_generate(model: ServedModel, phase: str, slo_ms: float,
                       configs: Sequence[NodeConfig], n_max: int,
                       lo: float, hi: float, max_stages: Optional[int],
                       cache: PlacementCache,
                       solve_chunk: int = 32768) -> Optional[Tuple]:
    """Level-wise (n -> n+1) pruned enumeration + solve (fast path).

    Grows combos one node at a time (canonical non-decreasing config
    order — the same multiset universe, memory window and fp memory
    sums as ``enumerate_combos``), carrying the best throughput of every
    *enumerated* combo in a code-indexed map. A level-n combo is solved
    with its best enumerated immediate sub-combo throughput as the
    incumbent: throughput is monotone non-decreasing under adding nodes,
    so a solve that fails to strictly beat the incumbent proves
    ``T(combo) == incumbent`` — the combo is usage-dominated by that
    sub-combo and emits no template, without paying the partition scan
    (``PlacementCache`` prunes it at the bound stage). Conversely a
    strict improvement proves no enumerated sub-multiset can dominate
    it, so the emitted set *is* the post-``pareto_prune`` set (emitted
    templates of incomparable usage never dominate each other).

    Dominated combos stay on the frontier — an extension of a dominated
    combo can strictly beat all its sub-combos (e.g. a second copy of a
    node that was individually too slow to hold a stage), so extending
    only non-dominated combos would be lossy; skipping their *solve*
    is what the incumbent makes free.

    Returns ``(templates, n_combos, n_raw, n_dominated)`` or ``None``
    when the config universe does not fit the frontier's packed codes
    (caller falls back to exhaustive enumeration).
    """
    cfgs = sorted(configs, key=lambda c: c.mem_gb)
    names = [c.name for c in cfgs]
    K = len(cfgs)
    bits = max(int(n_max).bit_length(), 1)
    if K * bits > 62:
        return None
    mems = np.array([c.mem_gb for c in cfgs])
    pw = np.int64(1) << (np.int64(bits) * np.arange(K, dtype=np.int64))
    master_codes = np.empty(0, dtype=np.int64)
    master_T = np.empty(0)
    emitted: List[Tuple[np.ndarray, Placement]] = []
    n_combos = n_raw = n_dom = 0
    cur_counts = np.eye(K, dtype=np.int64)
    cur_codes = pw.copy()
    cur_mem = mems.copy()
    cur_max = np.arange(K)
    keep = cur_mem <= hi
    cur_counts, cur_codes = cur_counts[keep], cur_codes[keep]
    cur_mem, cur_max = cur_mem[keep], cur_max[keep]
    for level in range(1, n_max + 1):
        if level > 1:
            parts = []
            for i in range(K):
                mask = (cur_max <= i) & (cur_mem + mems[i] <= hi)
                if not mask.any():
                    continue
                nc = cur_counts[mask].copy()
                nc[:, i] += 1
                parts.append((nc, cur_codes[mask] + pw[i],
                              cur_mem[mask] + mems[i],
                              np.full(int(mask.sum()), i)))
            if not parts:
                break
            cur_counts = np.concatenate([p[0] for p in parts])
            cur_codes = np.concatenate([p[1] for p in parts])
            cur_mem = np.concatenate([p[2] for p in parts])
            cur_max = np.concatenate([p[3] for p in parts])
        sol = np.nonzero(cur_mem >= lo)[0]
        if len(sol):
            sc, scode = cur_counts[sol], cur_codes[sol]
            n_combos += len(sol)
            inc = np.zeros(len(sol))
            if master_codes.size:
                for i in range(K):
                    hidx = np.nonzero(sc[:, i] > 0)[0]
                    if not len(hidx):
                        continue
                    sub = scode[hidx] - pw[i]
                    pos = np.searchsorted(master_codes, sub)
                    pos_c = np.minimum(pos, len(master_codes) - 1)
                    vals = np.where(master_codes[pos_c] == sub,
                                    master_T[pos_c], 0.0)
                    inc[hidx] = np.maximum(inc[hidx], vals)
            Ts = inc.copy()
            for c0 in range(0, len(sol), solve_chunk):
                cs = slice(c0, c0 + solve_chunk)
                res = cache.solve_batch_counts(
                    sc[cs], names, max_stages=max_stages,
                    incumbents=inc[cs])
                for j, r in enumerate(res):
                    if r is not None:
                        Ts[c0 + j] = r.throughput
                        emitted.append((sc[c0 + j], r))
            n_raw += int((Ts > 0).sum())
            master_codes = np.concatenate([master_codes, scode])
            master_T = np.concatenate([master_T, Ts])
            o = np.argsort(master_codes)
            master_codes, master_T = master_codes[o], master_T[o]
    temps = []
    for crow, pl in emitted:
        cnts = tuple(sorted((names[i], int(crow[i]))
                            for i in np.nonzero(crow)[0]))
        temps.append(ServingTemplate(model.name, phase, slo_ms, cnts,
                                     pl, pl.throughput))
    temps.sort(key=_template_order_key)
    n_dom = n_raw - len(temps)
    return temps, n_combos, n_raw, n_dom


def generate_templates(model: ServedModel, phase: str,
                       configs: Sequence[NodeConfig], wl: WorkloadStats,
                       n_max: int = 6, rho: float = 12.0,
                       solver: str = "fast", prune: bool = True,
                       max_stages: Optional[int] = None,
                       cache: Optional[PlacementCache] = None,
                       cross_check: bool = False,
                       ) -> Tuple[List[ServingTemplate], Dict]:
    """The Serving Template generator for one (model, SLO, phase).

    ``solver``: "fast" (default; cached/vectorized, same optimum),
    "exact" (reference per-combo combinatorial solver) or "ilp" (paper
    formulation). ``cache`` lets callers reuse a ``PlacementCache``
    across calls that share (model, phase, SLO, workload) — e.g. the
    per-config sub-universes of ``homo_library``.

    The default ``solver="fast", prune=True`` path runs the level-wise
    dominance-pruned frontier (``_frontier_generate``): dominated combos
    are skipped at the partition-bound stage and the post-prune template
    set falls out directly. ``cross_check=True`` (or env
    ``CORAL_TEMPLATE_CROSSCHECK=1``) additionally runs the exhaustive
    enumerate-all + ``pareto_prune`` reference on a fresh cache and
    asserts the two template sets are identical (keys and bit-exact
    throughputs); ``stats["cross_check"] == "ok"`` records the proof.
    """
    # corallint: disable=D1 - generation-stats telemetry only
    t0 = time.time()
    slo_ms = model.prefill_slo_ms if phase == "prefill" else model.decode_slo_ms
    pt = ProfileTable(model, phase, slo_ms, wl)
    by_name = {c.name: c for c in configs}
    tables = lambda name, S: pt.table(by_name[name], S)

    model_gb = model.bytes_total / 1e9
    lo = model_gb * (0.9 if phase == "prefill" else 1.0)
    # tiny models: rho x model_size can undershoot even one node's HBM;
    # a single smallest node must always be admissible
    hi = max(model_gb * rho, min(c.mem_gb for c in configs) + 1e-9)
    if solver not in ("fast", "exact", "ilp"):
        raise ValueError(f"unknown solver {solver!r}; "
                         f"expected 'fast', 'exact' or 'ilp'")

    def _stats(n_combos, n_raw, n_temps, extra=None):
        s = {"combos": n_combos, "templates_raw": n_raw,
             # corallint: disable=D1 - telemetry only
             "templates": n_temps, "seconds": time.time() - t0,
             "n_max": n_max, "rho": rho,
             "fingerprint": generation_fingerprint(
                 model, phase, configs, wl, n_max, rho, prune, solver,
                 max_stages)}
        if extra:
            s.update(extra)
        return s

    check = cross_check or (os.environ.get("CORAL_TEMPLATE_CROSSCHECK")
                            not in (None, "", "0"))
    if solver == "fast" and prune:
        if cache is None:
            cache = PlacementCache(tables, model.n_layers)
        fr = _frontier_generate(model, phase, slo_ms, configs, n_max,
                                lo, hi, max_stages, cache)
        if fr is not None:
            out, n_combos, n_raw, n_dom = fr
            extra = {"dominated": n_dom, "frontier": True}
            if check:
                ref, ref_stats = _exhaustive_generate(
                    model, phase, slo_ms, configs, wl, n_max, rho, lo, hi,
                    "fast", True, max_stages,
                    PlacementCache(tables, model.n_layers))
                _assert_template_sets_equal(out, ref, n_raw,
                                            ref_stats["templates_raw"])
                extra["cross_check"] = "ok"
            return out, _stats(n_combos, n_raw, len(out), extra)
    out, ex_stats = _exhaustive_generate(model, phase, slo_ms, configs, wl,
                                         n_max, rho, lo, hi, solver, prune,
                                         max_stages, cache, tables)
    return out, _stats(ex_stats["combos"], ex_stats["templates_raw"],
                       len(out))


def _exhaustive_generate(model, phase, slo_ms, configs, wl, n_max, rho,
                         lo, hi, solver, prune, max_stages, cache,
                         tables=None):
    """Reference path: enumerate every combo, solve, then prune."""
    if tables is None:
        pt = ProfileTable(model, phase, slo_ms, wl)
        by_name = {c.name: c for c in configs}
        tables = lambda name, S: pt.table(by_name[name], S)
    out: List[ServingTemplate] = []
    if solver == "fast":
        if cache is None:
            cache = PlacementCache(tables, model.n_layers)
        names_list = [[c.name for c in combo]
                      for combo in enumerate_combos(configs, n_max, lo, hi)]
        n_combos = len(names_list)
        placements = zip(names_list,
                         cache.solve_batch(names_list,
                                           max_stages=max_stages))
    else:
        solve = optimal_placement_exact if solver == "exact" \
            else optimal_placement_ilp

        def _solve_all():
            for combo in enumerate_combos(configs, n_max, lo, hi):
                names = [c.name for c in combo]
                yield names, solve(names, tables, model.n_layers,
                                   max_stages=max_stages)
        n_combos = 0
        placements = _solve_all()
    for names, pl in placements:
        if solver != "fast":
            n_combos += 1
        if pl is None or pl.throughput <= 0:
            continue
        counts: Dict[str, int] = {}
        for n in names:
            counts[n] = counts.get(n, 0) + 1
        out.append(ServingTemplate(
            model.name, phase, slo_ms,
            tuple(sorted(counts.items())), pl, pl.throughput))
    n_raw = len(out)
    if prune:
        out = pareto_prune(out, sorted(c.name for c in configs))
    return out, {"combos": n_combos, "templates_raw": n_raw}


def _assert_template_sets_equal(got: List[ServingTemplate],
                                ref: List[ServingTemplate],
                                got_raw: int, ref_raw: int) -> None:
    """Cross-check: the frontier's template set must be bit-identical
    (keys and throughputs, in the same deterministic order) to the
    exhaustive-enumeration + pareto_prune reference."""
    ga = [(t.key, t.throughput) for t in got]
    ra = [(t.key, t.throughput) for t in ref]
    if got_raw != ref_raw or ga != ra:
        only_g = set(ga) - set(ra)
        only_r = set(ra) - set(ga)
        raise AssertionError(
            f"frontier/exhaustive template-set mismatch: "
            f"raw {got_raw} vs {ref_raw}, kept {len(ga)} vs {len(ra)}, "
            f"{len(only_g)} frontier-only (e.g. {sorted(only_g)[:2]}), "
            f"{len(only_r)} reference-only (e.g. {sorted(only_r)[:2]})")


def build_library(models: Sequence[ServedModel],
                  configs: Sequence[NodeConfig],
                  workloads: Dict[str, WorkloadStats],
                  n_max: int = 6, rho: float = 12.0,
                  prune: bool = True, solver: str = "fast",
                  max_stages: Optional[int] = None,
                  reuse: Optional[TemplateLibrary] = None) -> TemplateLibrary:
    """Build the full Serving Template Library.

    ``reuse``: a previously built library; any (model, phase) whose
    generation fingerprint matches is copied over instead of re-solved
    (incremental rebuild when only part of the config universe or model
    set changed).
    """
    lib = TemplateLibrary(config_by_name={c.name: c for c in configs})
    for m in models:
        wl = workloads[m.name]
        for phase in ("prefill", "decode"):
            fp = generation_fingerprint(m, phase, configs, wl, n_max, rho,
                                        prune, solver, max_stages)
            if reuse is not None:
                old = reuse.stats.get((m.name, phase))
                if old is not None and old.get("fingerprint") == fp:
                    lib.add((m.name, phase),
                            list(reuse.templates[(m.name, phase)]),
                            dict(old, reused=True))
                    continue
            temps, stats = generate_templates(
                m, phase, configs, wl, n_max=n_max, rho=rho, prune=prune,
                solver=solver, max_stages=max_stages)
            lib.add((m.name, phase), temps, stats)
    return lib
