"""Debug-only runtime instrumentation.

``repro.debug.invariants`` is the CORAL_SANITIZE=1 invariant sanitizer
(tools/README.md "corallint + sanitizer"); nothing in here runs unless
that flag is set, so importing this package is always cheap.
"""
