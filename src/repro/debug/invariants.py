"""Runtime invariant sanitizer (enable with ``CORAL_SANITIZE=1``).

The repo's headline claims rest on exact accounting contracts: the
batched simulator is *bit-identical* to the per-iteration oracle, token
and request counters are conserved integers, and the epoch loop never
holds or places capacity that the market does not supply.  corallint
(tools/corallint) guards the static side of those contracts; this
module guards them at runtime, at the natural seams — span settlement,
event-queue pops, epoch edges — where a violation is still attributable
to the step that caused it.

Every check is gated on :func:`sanitize_enabled`, read once per call so
tests can flip the environment variable; hooks in the simulator bind a
``SimSanitizer`` at construction time instead (one env read per
``Simulator``).  A violation raises :class:`InvariantViolation`, an
``AssertionError`` subclass so test harnesses treat it like a failed
assert.

Checked invariants:

* **request conservation** (per model): arrivals observed up to ``now``
  equal finished + dropped + shed + queued + resident + in-flight
  requests still travelling through the event heap;
* **token conservation** (per model): the ``TokenRuns`` total equals the
  sum of per-instance ``tokens_out`` — including dead instances, whose
  produced tokens stay counted;
* **occupancy**: decode residents never exceed ``decode_capacity``, and
  every settled span segment's batch fits it too;
* **heap-time monotonicity**: the event queue never hands back a
  timestamp behind the simulation clock;
* **lifecycle**: dead instances leave the routing pools and never come
  back to life;
* **allocation/holdings**: a solved allocation uses only nodes its
  availability offered, and the cluster's held nodes fit the epoch's
  physical supply;
* **metrics sanity**: ``EpochMetrics`` counters are non-negative and
  per-model goodput never exceeds throughput.
"""
from __future__ import annotations

import math
import os
from typing import Dict, Tuple

EPS = 1e-6


class InvariantViolation(AssertionError):
    """A Coral accounting/lifecycle contract was broken at runtime."""


def sanitize_enabled() -> bool:
    return os.environ.get("CORAL_SANITIZE", "") not in ("", "0")


def _fail(msg: str):
    raise InvariantViolation(msg)


# ---------------------------------------------------------------- simulator
class SimSanitizer:
    """Per-``Simulator`` runtime checks.

    ``note_pop`` runs on every event pop (cheap: two comparisons);
    ``check_settle`` at every span settlement; ``check_sim`` — the full
    conservation audit, which scans the heap — only at ``run_until``
    boundaries.
    """

    def __init__(self):
        self._dead_seen = set()

    # ------------------------------------------------------- hot hooks
    def note_pop(self, t: float, now: float):
        # ``Simulator.now`` only ever advances (now = max(now, t)), so
        # a popped timestamp behind the clock means the heap returned
        # events out of order — the determinism contract is void
        if t < now - EPS:
            _fail(f"event heap time went backwards: popped t={t:.9f} "
                  f"behind sim clock now={now:.9f}")

    def check_settle(self, sim, inst, sp, n: int):
        cap = inst.cm.decode_capacity
        for off, k_j, b_j, dt, _lat, _ok in sp.segs:
            if min(n - off, k_j) <= 0:
                break
            if b_j < 0 or b_j > cap:
                _fail(f"span segment batch {b_j} outside [0, "
                      f"decode_capacity={cap}] on instance {inst.iid}")
            if dt < 0.0:
                _fail(f"negative iteration time {dt} in settled span "
                      f"on instance {inst.iid}")

    # ------------------------------------------------------ epoch edge
    def check_sim(self, sim):
        self._check_lifecycle(sim)
        self._check_occupancy(sim)
        self._check_tokens(sim)
        self._check_requests(sim)
        self._check_reqlog(sim)

    def _check_reqlog(self, sim):
        """RequestLog conservation: the observability layer's per-model
        outcome counters must mirror the simulator's own accounting
        exactly (a divergence means a lifecycle note was missed or
        double-recorded)."""
        rl = sim.reqlog
        if rl is None:
            return
        fin: Dict[str, int] = {}
        for r in sim.finished:
            fin[r.model] = fin.get(r.model, 0) + 1
        for m in sorted(rl.models):
            if rl.n_finished[m] != fin.get(m, 0):
                _fail(f"RequestLog finished count {rl.n_finished[m]} != "
                      f"simulator finished {fin.get(m, 0)} for {m!r}")
            if rl.n_dropped[m] != sim.dropped_by_model.get(m, 0):
                _fail(f"RequestLog dropped count {rl.n_dropped[m]} != "
                      f"simulator dropped "
                      f"{sim.dropped_by_model.get(m, 0)} for {m!r}")
            if rl.n_shed[m] != sim.shed_by_model.get(m, 0):
                _fail(f"RequestLog shed count {rl.n_shed[m]} != "
                      f"simulator shed {sim.shed_by_model.get(m, 0)} "
                      f"for {m!r}")
            # every finished request passed its first-token stamp, but
            # not vice versa (decode still in flight, or a request
            # dropped after prefill at the decode-dispatch edge)
            if rl.n_finished[m] > rl.n_first[m]:
                _fail(f"RequestLog records {rl.n_finished[m]} finished "
                      f"but only {rl.n_first[m]} first tokens for {m!r}")

    def _check_lifecycle(self, sim):
        for iid in sorted(sim.instances):
            inst = sim.instances[iid]
            if inst.dead:
                self._dead_seen.add(iid)
            elif iid in self._dead_seen:
                _fail(f"instance {iid} resurrected: dead flag cleared "
                      "after death")
        for pool_key in sorted(sim._by_pool):
            for inst in sim._by_pool[pool_key]:
                if inst.dead:
                    _fail(f"dead instance {inst.iid} still routable in "
                          f"pool {pool_key}")

    def _check_occupancy(self, sim):
        for iid in sorted(sim.instances):
            inst = sim.instances[iid]
            if len(inst.resident) != len(inst.res_keys):
                _fail(f"instance {iid}: resident/res_keys desync "
                      f"({len(inst.resident)} vs {len(inst.res_keys)})")
            if inst.phase == "decode" \
                    and len(inst.resident) > inst.cm.decode_capacity:
                _fail(f"instance {iid}: {len(inst.resident)} residents "
                      f"exceed decode_capacity "
                      f"{inst.cm.decode_capacity}")

    def _check_tokens(self, sim):
        by_model: Dict[str, int] = {}
        for iid in sorted(sim.instances):
            inst = sim.instances[iid]
            m = inst.template.model
            by_model[m] = by_model.get(m, 0) + inst.tokens_out
        for m in sorted(sim.tokens):
            logged = sim.tokens[m]._total
            produced = by_model.get(m, 0)
            if logged != produced:
                _fail(f"token conservation broken for {m!r}: TokenRuns "
                      f"total {logged} != sum of instance tokens_out "
                      f"{produced}")

    def _check_requests(self, sim):
        now = sim.now
        cut = now + EPS
        # requests still travelling through the event heap: re-pushed
        # arrival holds, prefill batches in flight, KV transfers.
        # Future arrivals (arrival > now) are not yet "arrived".
        heap_cnt: Dict[str, int] = {}
        for _t, _c, _fn, fargs in sim.ev._q:
            for a in fargs:
                if isinstance(a, list):
                    for r in a:
                        if hasattr(r, "arrival") and r.arrival <= cut:
                            heap_cnt[r.model] = heap_cnt.get(r.model, 0) + 1
                elif hasattr(a, "arrival") and hasattr(a, "model") \
                        and a.arrival <= cut:
                    heap_cnt[a.model] = heap_cnt.get(a.model, 0) + 1
        fin: Dict[str, int] = {}
        for r in sim.finished:
            fin[r.model] = fin.get(r.model, 0) + 1
        pend: Dict[str, int] = {}
        for iid in sorted(sim.instances):
            inst = sim.instances[iid]
            m = inst.template.model
            pend[m] = pend.get(m, 0) \
                + len(inst.queue) + len(inst.resident)
        for m in sorted(sim.obs):
            arrived, _p, _o = sim.obs[m].arrival.window(-math.inf, cut)
            accounted = (fin.get(m, 0)
                         + sim.dropped_by_model.get(m, 0)
                         + sim.shed_by_model.get(m, 0)
                         + pend.get(m, 0)
                         + heap_cnt.get(m, 0))
            if arrived != accounted:
                _fail(
                    f"request conservation broken for {m!r} at "
                    f"t={now:.3f}: {arrived} arrived != {accounted} "
                    f"accounted (finished={fin.get(m, 0)} "
                    f"dropped={sim.dropped_by_model.get(m, 0)} "
                    f"shed={sim.shed_by_model.get(m, 0)} "
                    f"queued+resident={pend.get(m, 0)} "
                    f"in_heap={heap_cnt.get(m, 0)})")


# ------------------------------------------------------------ control plane
def check_demands(demands):
    """Estimator/oracle demands are finite and non-negative."""
    for d in demands:
        v = d.tokens_per_s
        if not math.isfinite(v) or v < 0.0:
            _fail(f"demand ({d.model}, {d.phase}) has invalid "
                  f"tokens_per_s={v!r}")


def _node_usage(alloc) -> Dict[Tuple[str, str], int]:
    used: Dict[Tuple[str, str], int] = {}
    for (rname, tkey), n in alloc.instances.items():
        t = alloc.templates.get(tkey)
        if t is None:
            _fail(f"allocation references unknown template {tkey}")
        for cname, k in t.counts:
            key = (rname, cname)
            used[key] = used.get(key, 0) + n * k
    return used


def check_allocation(alloc, availability: Dict[Tuple[str, str], int]):
    """A *solved* allocation stays within the availability it saw."""
    for key in sorted(alloc.instances):
        n = alloc.instances[key]
        if not isinstance(n, int) or n < 0:
            _fail(f"allocation count for {key} is {n!r} "
                  "(must be a non-negative int)")
    used = _node_usage(alloc)
    for key in sorted(used):
        if used[key] > availability.get(key, 0):
            _fail(f"allocation uses {used[key]} x {key} but only "
                  f"{availability.get(key, 0)} were available")


def check_holdings(held: Dict[Tuple[str, str], int],
                   availability: Dict[Tuple[str, str], int]):
    """Held (live, non-draining) nodes fit the epoch's physical supply."""
    for key in sorted(held):
        if held[key] > availability.get(key, 0):
            _fail(f"cluster holds {held[key]} x {key} but the epoch's "
                  f"physical supply is {availability.get(key, 0)}")


def check_epoch_metrics(m):
    """EpochMetrics sanity: non-negative accounting, goodput below
    throughput (SLO-ok tokens are a subset of all tokens)."""
    for f in ("cost_per_hour", "init_cost", "solve_seconds",
              "assembly_ms", "solve_ms", "extract_ms"):
        v = getattr(m, f)
        if not math.isfinite(v) or v < -EPS:
            _fail(f"EpochMetrics.{f} = {v!r} (epoch {m.epoch})")
    for f in ("n_instances", "n_new", "n_drained", "n_preempted",
              "n_failed", "n_restarted", "n_shed", "n_mid_resolves"):
        if getattr(m, f) < 0:
            _fail(f"EpochMetrics.{f} = {getattr(m, f)} (epoch {m.epoch})")
    if m.solve_path not in ("", "decomposed", "rounded_lp", "monolithic",
                            "fallback"):
        _fail(f"EpochMetrics.solve_path = {m.solve_path!r} "
              f"(epoch {m.epoch})")
    for name in sorted(m.goodput):
        g, t = m.goodput[name], m.throughput.get(name, 0.0)
        if g < -EPS or t < -EPS:
            _fail(f"negative goodput/throughput for {name!r} "
                  f"(epoch {m.epoch}): {g}, {t}")
        if g > t + EPS + 1e-9 * max(abs(t), 1.0):
            _fail(f"goodput {g} exceeds throughput {t} for {name!r} "
                  f"(epoch {m.epoch})")
    for key in sorted(m.unmet):
        if m.unmet[key] < -EPS:
            _fail(f"negative unmet demand {m.unmet[key]} for {key} "
                  f"(epoch {m.epoch})")
    slo = getattr(m, "slo", None) or {}     # tolerate duck-typed stubs
    for name in sorted(slo):
        s = slo[name]
        for f in sorted(s):
            v = s[f]
            if not math.isfinite(v) or v < -EPS:
                _fail(f"EpochMetrics.slo[{name!r}][{f!r}] = {v!r} "
                      f"(epoch {m.epoch})")
        for fam in ("ttft", "tbt"):
            if not (s[f"{fam}_p50"] <= s[f"{fam}_p95"] + EPS
                    and s[f"{fam}_p95"] <= s[f"{fam}_p99"] + EPS):
                _fail(f"non-monotone {fam} percentiles for {name!r} "
                      f"(epoch {m.epoch}): p50={s[f'{fam}_p50']} "
                      f"p95={s[f'{fam}_p95']} p99={s[f'{fam}_p99']}")
            if s[f"{fam}_attain"] > 1.0 + EPS:
                _fail(f"{fam} SLO attainment {s[f'{fam}_attain']} > 1 "
                      f"for {name!r} (epoch {m.epoch})")
