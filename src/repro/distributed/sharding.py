"""Sharding rules and helpers.

Models are written mesh-agnostically: parameter initializers return a
parallel tree of ``PartitionSpec``s, and activations are constrained via
``constrain(x, spec)`` which is a no-op unless a mesh context is active
(smoke tests run unsharded on 1 CPU device; the dry-run and launchers
install the production mesh).

Axis convention (DESIGN.md §5):
  * "data"  — batch / FSDP shard axis (16 in production)
  * "model" — TP / EP axis (16 in production)
  * "pod"   — outer data axis across pods (2 in the multi-pod dry-run)
Batch dims use ("pod", "data") when the pod axis exists.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None or name not in mesh.shape:
        return 1
    return mesh.shape[name]


def batch_axes():
    """Logical batch partition: ("pod","data") if pod exists else ("data",)."""
    mesh = current_mesh()
    if mesh is not None and "pod" in mesh.shape:
        return ("pod", "data")
    return ("data",)


def _flatten_spec_axes(entry):
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


def sanitize_spec(spec: P, shape) -> P:
    """Drop mesh axes that do not evenly divide the corresponding dim.

    Lets one spec tree serve every mesh: e.g. a (12*128) fused-head dim
    shards over model=16, while a 12-head axis would not and falls back
    to replicated. Unknown axes (mesh without 'pod') are dropped too.
    """
    mesh = current_mesh()
    if mesh is None:
        return P()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        axes = _flatten_spec_axes(entry)
        kept = []
        prod = 1
        for a in axes:
            if a in mesh.shape and dim % (prod * mesh.shape[a]) == 0:
                kept.append(a)
                prod *= mesh.shape[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def constrain(x, *spec_entries):
    """with_sharding_constraint if a mesh is active (no-op otherwise)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = sanitize_spec(P(*spec_entries), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_shardings(tree_specs, tree_shapes):
    """Map a spec tree + shape tree -> NamedSharding tree (dry-run inputs)."""
    mesh = current_mesh()
    assert mesh is not None

    def one(spec, shaped):
        return NamedSharding(mesh, sanitize_spec(spec, shaped.shape))

    return jax.tree.map(one, tree_specs, tree_shapes,
                        is_leaf=lambda x: isinstance(x, P))
