"""Shared kernel utilities: impl selection, padding helpers."""
from __future__ import annotations

import os
from functools import lru_cache

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked softmax NaN-free


@lru_cache(None)
def default_impl() -> str:
    """'pallas' on TPU, 'ref' elsewhere (overridable via REPRO_KERNEL_IMPL).

    Pallas kernels are authored for the TPU target and validated on CPU in
    interpret mode ('pallas_interpret'); XLA-fused jnp references are the
    fast path on this CPU container.
    """
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def resolve_impl(impl: str | None) -> str:
    impl = impl or default_impl()
    assert impl in ("ref", "pallas", "pallas_interpret"), impl
    return impl


def pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0.0):
    """Pad ``axis`` of x up to a multiple; returns (padded, original_size)."""
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad, constant_values=value), size


def cdiv(a: int, b: int) -> int:
    return -(-a // b)
