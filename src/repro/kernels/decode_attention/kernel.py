"""Pallas TPU decode-attention kernel (single-token GQA over a KV cache).

Decode attention is the HBM-bandwidth-bound hot spot of every Coral
decode Serving Instance (paper §2.1): per generated token the full KV
cache must stream HBM->VMEM once. The kernel therefore:

  * lays KV out as (B, KH, S, D) so the streamed axis S is contiguous,
  * grid = (B, KH, S/bk) with the KV-block index minor/sequential;
    the fp32 (G, D) accumulator for the G = H/KH grouped query heads of
    one KV head lives in VMEM scratch across KV blocks (online softmax),
  * the G query rows share each streamed KV block — GQA turns a
    vector-matrix product into a (G x D) @ (D x bk) MXU matmul,
    raising arithmetic intensity by G without extra HBM traffic,
  * blocks beyond the valid cache length short-circuit via pl.when.

Validated on CPU via interpret=True against ref.decode_attention_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   bk: int, window: int, scale: float):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    k_lo = ik * bk
    live = k_lo < length
    if window > 0:
        live &= (k_lo + bk - 1) > (length - 1 - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, D)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < length
        if window > 0:
            mask &= k_pos > (length - 1 - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / (l_ref[...] + 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "bk",
                                             "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, lengths, *, scale=None,
                            window=0, bk=256, interpret=False):
    """q: (B, H, D); k/v_cache: (B, Smax, KH, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    assert H % KH == 0
    G = H // KH
    scale_v = scale if scale is not None else D ** -0.5
    bk = min(bk, S)
    assert S % bk == 0, (S, bk)

    qg = q.reshape(B, KH, G, D)
    kt = k_cache.transpose(0, 2, 1, 3)      # (B, KH, S, D)
    vt = v_cache.transpose(0, 2, 1, 3)
    lengths = lengths.astype(jnp.int32)

    grid = (B, KH, S // bk)
    kernel = functools.partial(_decode_kernel, bk=bk, window=window,
                               scale=scale_v)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # lengths: scalar prefetch
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, kt, vt)
    return out.reshape(B, H, D)
