"""jit'd public wrapper for decode attention (inference-only: no vjp)."""
from __future__ import annotations

from repro.kernels.common import resolve_impl
from repro.kernels.decode_attention import kernel as _kernel
from repro.kernels.decode_attention import ref as _ref


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None, window=0,
                     impl: str | None = None):
    """q: (B, H, D); k/v_cache: (B, Smax, KH, D); lengths: (B,) -> (B, H, D)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.decode_attention_reference(
            q, k_cache, v_cache, lengths, scale=scale, window=window)
    return _kernel.decode_attention_pallas(
        q, k_cache, v_cache, lengths, scale=scale, window=window,
        interpret=(impl == "pallas_interpret"))
