"""Pure-jnp oracle for single-token GQA decode attention over a KV cache.

Two variants: the repeat-based oracle, and a grouped-einsum form that —
like the Pallas kernel's index_map — never materializes the H/KH-fold
replicated KV (REPRO_GQA_GROUPED=1, the §Perf "kernel-faithful lowering"
iteration; see EXPERIMENTS.md).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def _grouped() -> bool:
    return os.environ.get("REPRO_GQA_GROUPED", "0") == "1"


def decode_attention_reference(q, k_cache, v_cache, lengths, *,
                               scale: float | None = None, window: int = 0):
    """q: (B, H, D); k/v_cache: (B, Smax, KH, D); lengths: (B,) int32.

    Position of the query token is lengths-1 (the cache already contains
    the current token's K/V at index lengths-1). Returns (B, H, D).
    """
    if _grouped():
        return decode_attention_grouped(q, k_cache, v_cache, lengths,
                                        scale=scale, window=window)
    B, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    g = H // KH
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = jnp.repeat(k_cache.astype(jnp.float32), g, axis=2)  # (B,S,H,D)
    vf = jnp.repeat(v_cache.astype(jnp.float32), g, axis=2)

    logits = jnp.einsum("bhd,bshd->bhs", qf, kf)
    k_pos = jnp.arange(S)[None, None, :]
    mask = k_pos < lengths[:, None, None]
    if window and window > 0:
        mask &= k_pos > (lengths[:, None, None] - 1 - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / (probs.sum(axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhs,bshd->bhd", probs, vf)
    return out.astype(q.dtype)


def decode_attention_grouped(q, k_cache, v_cache, lengths, *,
                             scale: float | None = None, window: int = 0):
    """GQA via grouped einsum: KV streamed once (no H/KH replication),
    in the cache's native dtype with fp32 accumulation — a full fp32 KV
    copy is exactly what the Pallas kernel avoids (it converts per-block
    in VMEM)."""
    B, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    g = H // KH
    scale = scale if scale is not None else D ** -0.5

    qg = (q.astype(jnp.float32) * scale).astype(k_cache.dtype)
    qg = qg.reshape(B, KH, g, D)

    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32)  # (B,KH,g,S)
    k_pos = jnp.arange(S)[None, None, None, :]
    mask = k_pos < lengths[:, None, None, None]
    if window and window > 0:
        mask &= k_pos > (lengths[:, None, None, None] - 1 - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / (probs.sum(axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bkgs,bskd->bkgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(B, H, D).astype(q.dtype)
