"""Pallas TPU flash-attention kernel (forward).

Blockwise online-softmax attention with GQA and sliding-window support.

TPU mapping (see DESIGN.md §3):
  * grid = (B, H, Sq/bq, Sk/bk); the KV block index is the minor
    (sequential) grid dimension, so VMEM scratch (acc/m/l) carries across
    KV blocks of one query block — the standard TPU flash pattern.
  * BlockSpecs tile Q (bq, D), K/V (bk, D) into VMEM; bq/bk default 128 to
    align the MXU's 128x128 systolic array; accumulation in fp32.
  * GQA is folded into the K/V index_map (q head h reads kv head h//g),
    so no materialized head replication touches HBM.
  * causal/sliding-window masking is applied in-block; fully-masked KV
    blocks short-circuit via pl.when (causal block pruning).

Validated on CPU via interpret=True against ref.mha_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import NEG_INF


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, causal: bool, window: int,
                      bq: int, bk: int, sq: int, sk: int):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal block pruning: skip KV blocks entirely above the diagonal,
    # and (for sliding window) entirely below the window.
    q_lo = iq * bq + (sk - sq)            # absolute position of first query row
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi
    if window > 0:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                           # (bq, bk)

        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / (l_ref[...] + 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention_fwd(q, k, v, *, causal=True, window=0, scale=None,
                        bq=128, bk=128, interpret=False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0
    g = H // KH
    scale_v = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)

    # (B, H, S, D) layout: heads become a grid dimension.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sq // bq, Sk // bk)
    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale_v, causal=causal, window=window,
        bq=bq, bk=bk, sq=Sq, sk=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
