"""jit'd public wrapper for flash attention with impl dispatch.

``impl``: 'ref' (jnp oracle; XLA-fused fast path on CPU), 'pallas'
(compiled TPU kernel), 'pallas_interpret' (kernel body interpreted on CPU
— used by the correctness sweeps).

Differentiation: the Pallas path is wrapped in jax.custom_vjp with a
recompute-from-reference backward (flash backward recomputes attention
anyway; on CPU/interpret this keeps the oracle as the single source of
gradient truth).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import resolve_impl
from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _pallas_attn(q, k, v, causal, window, scale, interpret):
    return _kernel.flash_attention_fwd(
        q, k, v, causal=causal, window=window, scale=scale,
        interpret=interpret)


def _pallas_attn_fwd(q, k, v, causal, window, scale, interpret):
    out = _pallas_attn(q, k, v, causal, window, scale, interpret)
    return out, (q, k, v)


def _pallas_attn_bwd(causal, window, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _ref.mha_reference(
            q_, k_, v_, causal=causal, window=window, scale=scale),
        q, k, v)
    return vjp(g)


_pallas_attn.defvjp(_pallas_attn_fwd, _pallas_attn_bwd)


CHUNK_THRESHOLD = 2048   # switch to the memory-bounded chunked path


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, impl: str | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KH, D) -> (B, Sq, H, D)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        if q.shape[1] > CHUNK_THRESHOLD:
            return _ref.mha_chunked(q, k, v, causal=causal, window=window,
                                    scale=scale)
        return _ref.mha_reference(q, k, v, causal=causal, window=window,
                                  scale=scale)
    return _pallas_attn(q, k, v, causal, window, scale,
                        impl == "pallas_interpret")
