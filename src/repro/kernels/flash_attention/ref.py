"""Pure-jnp oracles for blockwise flash attention (GQA + sliding window).

``mha_reference`` materializes the full (Sq, Sk) score matrix — the
bit-exact oracle for small shapes. ``mha_chunked`` processes query
chunks with a lax.map so peak memory is O(chunk x Sk) — semantically
identical, and the memory shape the Pallas kernel has on TPU; the
dry-run lowers this variant for long sequences so memory_analysis
reflects the kernelized data plane (DESIGN.md §3).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF


def _grouped() -> bool:
    # kernel-faithful GQA lowering (no repeated KV); see EXPERIMENTS §Perf
    return os.environ.get("REPRO_GQA_GROUPED", "0") == "1"


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None, kv_len=None):
    """Multi-head attention reference.

    q: (B, Sq, H, D); k, v: (B, Sk, KH, D) with H % KH == 0 (GQA).
    window > 0: sliding-window attention (each query attends to the last
    ``window`` positions, inclusive of itself).
    kv_len: optional (B,) valid KV lengths (decode with padded caches).
    Query position i is aligned so that query i corresponds to absolute
    position (Sk - Sq + i)  — standard "suffix" alignment for caches.
    Returns (B, Sq, H, D) in q.dtype.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    assert H % KH == 0, (H, KH)
    g = H // KH
    scale = scale if scale is not None else D ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads for GQA
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)

    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    mask = mask[None, None]
    if kv_len is not None:
        mask = mask & (k_pos[None, None] < kv_len[:, None, None, None])
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / (probs.sum(axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


def mha_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                scale: float | None = None, chunk: int = 512):
    """Query-chunked attention: O(chunk x Sk) live scores."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    scale = scale if scale is not None else D ** -0.5
    bq = min(chunk, Sq)
    if Sq % bq:
        bq = Sq            # odd sizes: fall back to one chunk
    nq = Sq // bq
    grouped = _grouped()

    if grouped:
        kf = k.astype(jnp.float32)                    # (B,Sk,KH,D)
        vf = v.astype(jnp.float32)
    else:
        kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
        vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    k_pos = jnp.arange(Sk)

    def one(i):
        qs = jax.lax.dynamic_slice_in_dim(q, i * bq, bq, axis=1)
        qf = qs.astype(jnp.float32) * scale
        if grouped:
            qg = qf.reshape(B, bq, KH, g, D)
            logits = jnp.einsum("bqcgd,bkcd->bcgqk", qg, kf)  # (B,KH,g,bq,Sk)
        else:
            logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        q_pos = i * bq + jnp.arange(bq) + (Sk - Sq)
        mask = jnp.ones((bq, Sk), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window and window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mexp = mask[None, None, None] if grouped else mask[None, None]
        logits = jnp.where(mexp, logits, NEG_INF)
        probs = jnp.exp(logits - logits.max(-1, keepdims=True))
        probs = probs / (probs.sum(-1, keepdims=True) + 1e-30)
        if grouped:
            out = jnp.einsum("bcgqk,bkcd->bqcgd", probs, vf)
            return out.reshape(B, bq, H, D).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, vf).astype(q.dtype)

    out = jax.lax.map(one, jnp.arange(nq))          # (nq, B, bq, H, D)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
