"""Pallas TPU chunked-SSD (Mamba2) scan kernel.

TPU adaptation of the SSD algorithm (DESIGN.md §3): instead of the GPU
implementation's warp-level scan, the sequence is processed in chunks of
T tokens; each chunk is three MXU matmuls (intra-chunk (T x T) decay-
masked attention-like product, inter-chunk state read, state update) and
the running (P x N) state is carried across the sequential chunk grid
dimension in VMEM scratch — the same carry idiom as flash attention's
online softmax.

grid = (B, H, S/T); per-step VMEM blocks: x (T,P), dt (T,1), B/C (T,N),
state scratch (P,N) fp32. T defaults to 64: (64x64)x(64xN) keeps all
operands resident and the TxT score matrix MXU-aligned for P=N=64.

Validated on CPU via interpret=True against ref.ssd_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(A_ref, D_ref, x_ref, dt_ref, B_ref, C_ref, s0_ref,
                y_ref, sf_ref, state_ref, *, T: int):
    h = pl.program_id(1)
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    a = A_ref[h]
    d = D_ref[h]
    x = x_ref[0, 0].astype(jnp.float32)            # (T, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (T, 1)
    Bm = B_ref[0].astype(jnp.float32)              # (T, N)
    Cm = C_ref[0].astype(jnp.float32)              # (T, N)

    loglam = dt * a                                # (T, 1)
    cum = jnp.cumsum(loglam, axis=0)               # (T, 1) log L_t
    Lt = jnp.exp(cum)                              # (T, 1)

    # intra-chunk score M[t,u] = (C_t.B_u) * dt_u * exp(cum_t - cum_u), u<=t
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (T, T)
    ratio = jnp.exp(cum - cum.reshape(1, T))       # (T, T) exp(cum_t - cum_u)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    u_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    M = cb * dt.reshape(1, T) * ratio
    M = jnp.where(u_idx <= t_idx, M, 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (T, P)

    # inter-chunk contribution: L_t * (state @ C_t)
    state = state_ref[...]                          # (P, N)
    y += Lt * jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y += d * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S <- L_T * S + sum_u exp(cum_T - cum_u) dt_u x_u B_u^T
    Lend = jnp.exp(cum[T - 1:T, :])                 # (1, 1)
    w = jnp.exp(cum[T - 1:T, :] - cum) * dt         # (T, 1)
    upd = jax.lax.dot_general(x * w, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = Lend[0, 0] * state + upd

    @pl.when(ic == nc - 1)
    def _finalize():
        sf_ref[0, 0] = state_ref[...].astype(sf_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, Bmat, Cmat, D, init_state=None, *, chunk=64,
               interpret=False):
    """x (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,N), D (H,) ->
    (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    T = min(chunk, S)
    assert S % T == 0, (S, T)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    xt = x.transpose(0, 2, 1, 3)                   # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)[..., None]         # (B,H,S,1)

    grid = (Bsz, H, S // T)
    y, sf = pl.pallas_call(
        functools.partial(_ssd_kernel, T=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # A (H,)
            pl.BlockSpec(memory_space=pltpu.SMEM),   # D (H,)
            pl.BlockSpec((1, 1, T, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, T, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, T, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, T, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(A.astype(jnp.float32), D.astype(jnp.float32), xt, dtt, Bmat, Cmat,
      init_state)
    return y.transpose(0, 2, 1, 3), sf
