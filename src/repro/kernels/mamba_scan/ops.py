"""jit'd public wrapper for the Mamba2 SSD scan."""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import resolve_impl
from repro.kernels.mamba_scan import kernel as _kernel
from repro.kernels.mamba_scan import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(7,))
def _ssd_pallas_diff(x, dt, A, Bmat, Cmat, D, init_state, interpret):
    y, _ = _kernel.ssd_pallas(x, dt, A, Bmat, Cmat, D, init_state,
                              interpret=interpret)
    return y


def _ssd_fwd(x, dt, A, Bmat, Cmat, D, init_state, interpret):
    return (_ssd_pallas_diff(x, dt, A, Bmat, Cmat, D, init_state, interpret),
            (x, dt, A, Bmat, Cmat, D, init_state))


def _ssd_bwd(interpret, res, g):
    x, dt, A, Bmat, Cmat, D, init_state = res
    _, vjp = jax.vjp(
        lambda *a: _ref.ssd_reference(*a)[0], x, dt, A, Bmat, Cmat, D,
        init_state)
    return vjp(g)


_ssd_pallas_diff.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x, dt, A, Bmat, Cmat, D, init_state=None, *,
             impl: str | None = None, with_state: bool = False):
    """Chunked Mamba2 SSD scan. Returns y, or (y, final_state)."""
    impl = resolve_impl(impl)
    if impl == "ref" or with_state:
        if impl == "ref":
            # chunked form: same math as the Pallas kernel (matmul blocks
            # + per-chunk state carry) so CPU-lowered memory/flops match
            # the TPU kernel's shape; per-token scan kept as test oracle
            S = x.shape[1]
            if S >= 64 and S % 64 == 0:
                y, sf = _ref.ssd_chunked_reference(x, dt, A, Bmat, Cmat, D,
                                                   init_state, chunk=64)
            else:
                y, sf = _ref.ssd_reference(x, dt, A, Bmat, Cmat, D,
                                           init_state)
        else:
            y, sf = _kernel.ssd_pallas(x, dt, A, Bmat, Cmat, D, init_state,
                                       interpret=(impl == "pallas_interpret"))
        return (y, sf) if with_state else y
    return _ssd_pallas_diff(x, dt, A, Bmat, Cmat, D, init_state,
                            impl == "pallas_interpret")


decode_step = _ref.ssd_decode_step
