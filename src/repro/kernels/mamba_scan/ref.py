"""Pure-jnp oracle for the Mamba2 SSD selective scan.

Semantics (per batch b, head h; state S in R^{P x N}):
    lam_t = exp(dt_t * A_h)                       (A_h < 0 => decay)
    S_t   = lam_t * S_{t-1} + (dt_t * x_t) outer B_t
    y_t   = S_t @ C_t + D_h * x_t
Shapes: x (B,S,H,P), dt (B,S,H) [post-softplus], A (H,), B/C (B,S,N),
D (H,), init_state (B,H,P,N). Returns (y (B,S,H,P), final_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_reference(x, dt, A, Bmat, Cmat, D, init_state=None):
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp            # (B,H,P), (B,H), (B,N), (B,N)
        lam = jnp.exp(dtt * Af[None, :])               # (B,H)
        upd = (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
        state = lam[..., None, None] * state + upd     # (B,H,P,N)
        y = jnp.einsum("bhpn,bn->bhp", state, Ct) + Df[None, :, None] * xt
        return state, y

    inputs = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
              Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init_state, inputs)
    y = ys.transpose(1, 0, 2, 3)          # (B,S,H,P)
    return y.astype(x.dtype), final


def ssd_chunked_reference(x, dt, A, Bmat, Cmat, D, init_state=None,
                          chunk: int = 64):
    """Chunk-parallel SSD (the math the Pallas kernel implements), in jnp.

    Mathematically identical to ssd_reference; used to validate the
    chunk decomposition separately from the Pallas lowering.
    """
    Bsz, S, H, P = x.shape
    N = Bmat.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, chunk, H)
    Bf = Bmat.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Cf = Cmat.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Af = A.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    def chunk_step(state, inp):
        xc, dtc, Bc, Cc = inp            # (B,T,H,P), (B,T,H), (B,T,N), (B,T,N)
        loglam = dtc * Af                                  # (B,T,H)
        cum = jnp.cumsum(loglam, axis=1)                   # log L_t
        Lt = jnp.exp(cum)                                  # (B,T,H)
        # intra-chunk: M[t,u] = (C_t.B_u) dt_u exp(cum_t - cum_u), u <= t
        ratio = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,T,U,H)
        cb = jnp.einsum("btn,bun->btu", Cc, Bc)            # (B,T,U)
        tri = jnp.tril(jnp.ones((xc.shape[1], xc.shape[1]), jnp.float32))
        M = cb[..., None] * dtc[:, None, :, :] * ratio * tri[None, :, :, None]
        y = jnp.einsum("btuh,buhp->bthp", M, xc)
        # inter-chunk: y += L_t * (S_0 @ C_t)
        y += Lt[..., None] * jnp.einsum("bhpn,btn->bthp", state, Cc)
        y += Df[None, None, :, None] * xc
        # state update
        Lend = jnp.exp(cum[:, -1:, :])                     # (B,1,H)
        w = jnp.exp(cum[:, -1:, :] - cum) * dtc            # (B,T,H)
        upd = jnp.einsum("bthp,btn,bth->bhpn", xc, Bc, w)
        state = Lend[:, 0, :, None, None] * state + upd
        return state, y

    inputs = tuple(a.transpose(1, 0, *range(2, a.ndim))
                   for a in (xf, dtf, Bf, Cf))
    final, ys = jax.lax.scan(chunk_step, init_state, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final


def ssd_decode_step(state, xt, dtt, A, Bt, Ct, D):
    """Single-token recurrence. state (B,H,P,N); xt (B,H,P); dtt (B,H);
    Bt/Ct (B,N). Returns (y (B,H,P), new_state)."""
    lam = jnp.exp(dtt.astype(jnp.float32) * A[None, :])
    upd = (dtt[..., None] * xt.astype(jnp.float32))[..., None] \
        * Bt.astype(jnp.float32)[:, None, None, :]
    state = lam[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Ct.astype(jnp.float32)) \
        + D[None, :, None] * xt.astype(jnp.float32)
    return y.astype(xt.dtype), state
