"""Pallas TPU grouped matmul for MoE expert FFNs.

Capacity-dispatched MoE expert compute is a batched matmul
(E, C, d) @ (E, d, f): per expert e, its C capacity slots hit its own
weight matrix. grid = (E, C/bc, f/bf, d/bd) with the contraction block
minor/sequential and an fp32 (bc, bf) accumulator in VMEM scratch.
Block shapes default to 128 to align the MXU; d is streamed so the
working set is 3 tiles regardless of expert size.

Validated on CPU via interpret=True against ref.gmm_reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _fit_block(dim: int, b: int) -> int:
    """Largest divisor of dim that is <= b (keeps blocks MXU-aligned when
    dim is a multiple of 128, degrades gracefully for odd shapes)."""
    b = min(b, dim)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def gmm_pallas(x, w, *, bc=128, bf=128, bd=128, interpret=False):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    E, C, d = x.shape
    _, _, f = w.shape
    bc, bf, bd = _fit_block(C, bc), _fit_block(f, bf), _fit_block(d, bd)

    grid = (E, C // bc, f // bf, d // bd)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
