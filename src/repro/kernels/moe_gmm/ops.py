"""jit'd public wrapper for the MoE grouped matmul."""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import resolve_impl
from repro.kernels.moe_gmm import kernel as _kernel
from repro.kernels.moe_gmm import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _gmm_diff(x, w, interpret):
    return _kernel.gmm_pallas(x, w, interpret=interpret)


def _gmm_fwd(x, w, interpret):
    return _gmm_diff(x, w, interpret), (x, w)


def _gmm_bwd(interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(_ref.gmm_reference, x, w)
    return vjp(g)


_gmm_diff.defvjp(_gmm_fwd, _gmm_bwd)


def grouped_matmul(x, w, *, impl: str | None = None):
    """x: (E, C, d); w: (E, d, f) -> (E, C, f)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return _ref.gmm_reference(x, w)
    return _gmm_diff(x, w, impl == "pallas_interpret")
