"""Pure-jnp oracle for the MoE grouped (per-expert batched) matmul."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_reference(x, w):
    """x: (E, C, d) capacity-dispatched tokens; w: (E, d, f) -> (E, C, f)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
