import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# (This also means: no `from __future__ import annotations` in this file.)

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jax.jit(step, in_shardings=..., donate...).lower(**specs).compile()
must succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod
mesh. The compiled artifact yields memory_analysis() (fits-in-HBM proof),
cost_analysis() (FLOPs / bytes for the roofline), and the optimized HLO
from which collective bytes are parsed (the roofline's third term).
Results are cached as JSON under artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
from typing import Dict, Optional

import numpy as np

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?"
    r"((?:\(|)[a-z0-9]+\[[^\]]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        total = 0.0
        for sm in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1.0
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES.get(dt, 4)
        out[op] = out.get(op, 0.0) + total
    return out


def _memory_dict(mem):
    if mem is None:
        return {}
    out = {}
    for name in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, name, None)
        if callable(v):
            try:
                v = v()
            except Exception:       # noqa: BLE001
                v = None
        if isinstance(v, (int, float)):
            out[name] = int(v)
    return out


def build_step(cfg, shape):
    """Returns (step_fn, example_inputs, in_shardings, donate) per kind."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import tree_shardings
    from repro.models import api as mapi
    from repro.train import optimizer as opt
    from repro.train import steps

    model = mapi.get_model(cfg)

    spec_box = {}

    def initfn(key):
        p, s = model.init(key, cfg)
        spec_box["s"] = s
        return p

    key = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
    param_shapes = jax.eval_shape(initfn, key)
    param_specs = spec_box["s"]
    p_shard = tree_shardings(param_specs, param_shapes)

    if shape.kind == "train":
        oc = opt.OptConfig()
        opt_shapes = jax.eval_shape(opt.init_opt_state, param_shapes)
        opt_specs = opt.opt_state_specs(param_specs)
        o_shard = tree_shardings(opt_specs, opt_shapes)
        batch, bspecs = mapi.input_specs(cfg, shape)
        b_shard = tree_shardings(bspecs, batch)
        step = steps.make_train_step(cfg, oc)
        return (step, (param_shapes, opt_shapes, batch),
                (p_shard, o_shard, b_shard), (0, 1))
    if shape.kind == "prefill":
        batch, bspecs = mapi.input_specs(cfg, shape)
        b_shard = tree_shardings(bspecs, batch)
        step = steps.make_prefill_step(cfg)
        return step, (param_shapes, batch), (p_shard, b_shard), ()
    # decode
    inputs, ispecs = mapi.input_specs(cfg, shape)
    c_shard = tree_shardings(ispecs["cache"], inputs["cache"])
    t_shard = tree_shardings(ispecs["tokens"], inputs["tokens"])
    step = steps.make_serve_step(cfg)
    return (step, (param_shapes, inputs["cache"], inputs["tokens"]),
            (p_shard, c_shard, t_shard), (1,))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR, verbose: bool = True,
             arch_overrides: Optional[dict] = None,
             tag: str = "") -> Dict:
    import jax
    from repro.configs.base import SHAPE_BY_NAME, cell_is_runnable
    from repro.configs.registry import get_config
    from repro.distributed.sharding import use_mesh
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if arch_overrides:
        cfg = cfg.with_(**arch_overrides)
    shape = SHAPE_BY_NAME[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id + ".json")

    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec = {"cell": cell_id, "arch": arch, "shape": shape_name,
               "mesh": mesh_name, "status": "skipped", "reason": why}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] {cell_id}: SKIPPED ({why})")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    with use_mesh(mesh):
        step, inputs, shardings, donate = build_step(cfg, shape)
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*inputs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)

    # loop-aware HLO accounting: cost_analysis counts a lax.scan body
    # once (trip count ignored — empirically verified), so flops/traffic/
    # collectives come from repro.launch.hlo_analysis which multiplies
    # while bodies by their trip counts and excludes fusion-internal
    # traffic.
    from repro.launch.hlo_analysis import analyse_hlo
    hc = analyse_hlo(hlo)

    n_dev = int(np.prod(mesh.devices.shape))
    rec = {
        "cell": cell_id, "arch": arch, "shape": shape_name,
        "mesh": mesh_name, "status": "ok",
        "n_devices": n_dev,
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        "flops_total": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "collective_bytes_total": float(sum(coll.values())),
        "hlo_flops": hc.flops,
        "hlo_traffic_bytes": hc.traffic,
        "hlo_collective_bytes": dict(hc.collectives),
        "hlo_collective_bytes_total": hc.collective_total,
        "memory": _memory_dict(mem),
        "params": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
        "arch_overrides": arch_overrides or {},
    }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        gf = rec["flops_total"] / 1e12
        print(f"[dryrun] {cell_id}: OK lower={t_lower:.1f}s "
              f"compile={t_compile:.1f}s TFLOPs={gf:.1f} "
              f"coll={rec['collective_bytes_total']/1e9:.2f}GB")
    return rec


def correct_cell(path: str) -> bool:
    """Add loop-aware HLO accounting to an existing artifact in place
    (recompiles the cell to recover the optimized HLO text)."""
    import jax
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_config
    from repro.distributed.sharding import use_mesh
    from repro.launch.hlo_analysis import analyse_hlo
    from repro.launch.mesh import make_production_mesh

    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok" or "hlo_flops" in rec:
        return False
    cfg = get_config(rec["arch"])
    for k, v in rec.get("arch_overrides", {}).items():
        cfg = cfg.with_(**{k: v})
    shape = SHAPE_BY_NAME[rec["shape"]]
    mesh = make_production_mesh(multi_pod=(rec["mesh"] == "2x16x16"))
    with use_mesh(mesh):
        step, inputs, shardings, donate = build_step(cfg, shape)
        compiled = jax.jit(step, in_shardings=shardings,
                           donate_argnums=donate).lower(*inputs).compile()
        hc = analyse_hlo(compiled.as_text())
    rec.update({
        "hlo_flops": hc.flops,
        "hlo_traffic_bytes": hc.traffic,
        "hlo_collective_bytes": dict(hc.collectives),
        "hlo_collective_bytes_total": hc.collective_total,
    })
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] hlo-analysed {rec['cell']}: "
          f"flops/dev {rec['hlo_flops']:.3g} "
          f"coll {rec['hlo_collective_bytes_total']/1e9:.2f}GB")
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--correct-only", action="store_true",
                    help="add the scan-depth correction to existing "
                         "artifacts (no main-cell recompilation)")
    args = ap.parse_args()

    if args.correct_only:
        import glob as _glob
        fails = []
        for path in sorted(_glob.glob(os.path.join(args.out, "*.json"))):
            try:
                correct_cell(path)
            except Exception as e:      # noqa: BLE001
                fails.append((path, repr(e)[:160]))
                print(f"[dryrun] correction FAILED {path}: {e!r}")
        if fails:
            raise SystemExit(1)
        print("corrections complete")
        return

    from repro.configs.registry import ARCH_IDS, SHAPES

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for sh in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = os.path.join(args.out,
                                    f"{arch}__{sh}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                try:
                    run_cell(arch, sh, mp, out_dir=args.out)
                except Exception as e:      # noqa: BLE001
                    failures.append((arch, sh, mesh_name, repr(e)[:200]))
                    print(f"[dryrun] {arch}/{sh}/{mesh_name}: FAIL {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells passed")


if __name__ == "__main__":
    main()
