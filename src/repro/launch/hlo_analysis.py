"""Loop-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop (lax.scan) body
ONCE, ignoring the trip count — useless for scanned-layer models. This
module parses the optimized HLO and aggregates, recursively through
``while`` (x trip count), ``fusion``, ``call`` and ``conditional``:

  * flops            — dot ops: 2 x prod(result dims) x contracted dims
  * traffic_bytes    — HBM traffic proxy: operand + result bytes of every
                       *top-level* op (fusion internals are VMEM-resident
                       and excluded; a fusion's own operands/results count
                       once)
  * collective_bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute

Trip counts come from each while's condition computation (largest
integer constant — the loop bound). Validated in
tests/test_hlo_analysis.py: flops scale ~linearly with scan length and
match the analytic 2*N*D for a dense forward pass.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[^\]]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\(([^)]*)\)(.*)$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_VIEW_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "iota", "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1.0
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.traffic += mult * other.traffic
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + mult * v

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


@dataclass
class _Comp:
    name: str
    ops: List[Tuple[str, str, str, str, str]]   # (name, type, opcode,
    #                                              operands, attrs)
    types: Dict[str, str]                        # op name -> result type


def _parse(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry = None
    cur: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and ("->" in s):
                hdr = s
                is_entry = hdr.startswith("ENTRY")
                if is_entry:
                    hdr = hdr[len("ENTRY"):].strip()
                name = hdr.split()[0].lstrip("%").split("(")[0].strip()
                cur = _Comp(name, [], {})
                if is_entry:
                    entry = name
            continue
        if s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            oname, rtype, opcode, operands, attrs = m.groups()
            cur.ops.append((oname, rtype, opcode, operands, attrs))
            cur.types[oname] = rtype
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Optional[_Comp],
                comps: Optional[Dict[str, "_Comp"]] = None) -> float:
    """Trip count = the integer constant compared against the induction
    variable in the loop condition (ROOT compare; +1 for LE)."""
    if cond is None:
        return 1.0

    def const_map(comp):
        out = {}
        for n, _t, opc, ops, _a in comp.ops:
            if opc == "constant":
                m = re.match(r"\s*(\d+)\s*$", ops)
                if m:
                    out[n] = int(m.group(1))
        return out

    comps = comps or {}
    consts = const_map(cond)
    candidates = []
    for n, _t, opc, ops, attrs in cond.ops:
        if opc == "compare":
            names = _OPERAND_RE.findall(ops)
            vals = [consts[x] for x in names if x in consts]
            if vals:
                bump = 1 if "direction=LE" in attrs else 0
                candidates.append(vals[0] + bump)
        elif opc == "fusion":
            fm = _CALLS_RE.search(attrs)
            callee = comps.get(fm.group(1)) if fm else None
            if callee is not None:
                inner = const_map(callee)
                inner.update(consts)
                for n2, _t2, opc2, ops2, attrs2 in callee.ops:
                    if opc2 == "compare":
                        names = _OPERAND_RE.findall(ops2)
                        vals = [inner[x] for x in names if x in inner]
                        if vals:
                            bump = 1 if "direction=LE" in attrs2 else 0
                            candidates.append(vals[0] + bump)
    if not candidates:
        return 1.0
    return float(candidates[-1])   # the ROOT-feeding compare comes last


def analyse_hlo(text: str) -> Cost:
    comps, entry = _parse(text)
    if entry is None and comps:
        entry = max(comps, key=lambda k: len(comps[k].ops))
    if entry is None:
        return Cost()
    memo: Dict[str, Cost] = {}

    def cost_of(cname: str, inside_fusion: bool) -> Cost:
        key = (cname, inside_fusion)
        if key in memo:
            return memo[key]
        memo[key] = Cost()
        comp = comps.get(cname)
        if comp is None:
            return memo[key]
        c = Cost()
        for oname, rtype, opcode, operands, attrs in comp.ops:
            full_attrs = operands + attrs
            if opcode == "dot":
                out = 1.0
                for d in _shape_dims(rtype):
                    out *= d
                contr = 1.0
                cm = _CONTRACT_RE.search(attrs)
                ops_names = _OPERAND_RE.findall(operands)
                if cm and ops_names:
                    lhs_t = comp.types.get(ops_names[0], "")
                    lhs_dims = _shape_dims(lhs_t)
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_dims):
                            contr *= lhs_dims[int(idx)]
                c.flops += 2.0 * out * contr
            is_coll = False
            for coll in COLLECTIVES:
                if opcode == coll or opcode == coll + "-start":
                    c.collectives[coll] = c.collectives.get(coll, 0.0) \
                        + _shape_bytes(rtype)
                    is_coll = True
                    break
            if opcode == "while":
                bm = _CALLS_RE.search(attrs)
                # XLA annotates loops with the exact trip count
                km = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attrs)
                if km:
                    trips = float(km.group(1))
                else:
                    cm_ = _COND_RE.search(attrs)
                    trips = _trip_count(comps.get(cm_.group(1)), comps) \
                        if cm_ else 1.0
                if bm:
                    c.add(cost_of(bm.group(1), inside_fusion), trips)
                # loop-carried tuple traffic is internal; skip
                continue
            if opcode == "fusion":
                fm = _CALLS_RE.search(attrs)
                if fm:
                    sub = cost_of(fm.group(1), True)
                    c.flops += sub.flops
                    for k, v in sub.collectives.items():
                        c.collectives[k] = c.collectives.get(k, 0.0) + v
                if not inside_fusion:
                    c.traffic += _shape_bytes(rtype)
                    for op_name in _OPERAND_RE.findall(operands):
                        c.traffic += _shape_bytes(comp.types.get(op_name, ""))
                continue
            if opcode in ("call", "async-start", "custom-call"):
                fm = _CALLS_RE.search(attrs)
                if fm:
                    c.add(cost_of(fm.group(1), inside_fusion))
            if opcode == "conditional":
                bm = _BRANCHES_RE.search(attrs)
                if bm:
                    subs = [cost_of(b.strip().lstrip("%"), inside_fusion)
                            for b in bm.group(1).split(",") if b.strip()]
                    if subs:
                        c.add(max(subs, key=lambda s: s.flops + s.traffic))
            if not inside_fusion and not is_coll and opcode not in _VIEW_OPS:
                c.traffic += _shape_bytes(rtype)
                for op_name in _OPERAND_RE.findall(operands):
                    c.traffic += _shape_bytes(comp.types.get(op_name, ""))
        memo[key] = c
        return c

    return cost_of(entry, False)
