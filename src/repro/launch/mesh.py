"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state. The single-pod
mesh is 16x16 = 256 chips ("data", "model"); the multi-pod mesh adds a
leading "pod" axis (2x16x16 = 512 chips). When more devices exist than
the mesh needs (the dry-run forces 512 host devices), the first
``prod(shape)`` devices are used.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run through launch/dryrun.py which forces "
            "xla_force_host_platform_device_count=512")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_debug_mesh(axis: str = "data"):
    """1-device mesh for smoke tests of sharded code paths."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape((1,)), (axis,))
