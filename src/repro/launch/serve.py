"""Serving launcher: run a model behind the JAX serving engine with
batched synthetic requests (the paper-kind end-to-end driver).

CPU container: use --smoke (reduced config). On TPU the same code path
serves the full config with the production mesh shardings.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models import api as mapi
from repro.obs.percentiles import percentiles
from repro.serving.engine import JaxEngine


def serve(cfg, n_requests: int = 32, rate: float = 5.0, max_batch: int = 8,
          max_len: int = 256, seed: int = 0):
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(seed), cfg)
    eng = JaxEngine(cfg, params, max_batch=max_batch, max_len=max_len)
    rng = np.random.default_rng(seed)

    prompts = rng.integers(8, 64, size=n_requests)
    outs = rng.integers(8, 32, size=n_requests)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))

    t0 = time.time()
    submitted, finished = 0, {}
    lat_first, lat_token = [], []
    sub_t = {}
    while len(finished) < n_requests:
        now = time.time() - t0
        while submitted < n_requests and arrivals[submitted] <= now:
            rid = submitted
            eng.submit(rid, rng.integers(0, cfg.vocab_size,
                                         size=(int(prompts[rid]),)),
                       int(outs[rid]))
            sub_t[rid] = time.time()
            submitted += 1
        if not any(eng.slots) and not eng.queue:
            if submitted < n_requests:
                time.sleep(0.005)
            continue
        reqs = {s.rid: s for s in eng.slots if s is not None}
        for rid, _tok, done in eng.step():
            if done:
                finished[rid] = reqs[rid]
    for rid, r in finished.items():
        lat_first.append(r.prefill_done - sub_t[rid])
        if len(r.token_times) > 1:
            lat_token += list(np.diff(r.token_times))
    wall = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished.values())
    print(f"[serve] {n_requests} requests, {total_tokens} tokens "
          f"in {wall:.1f}s -> {total_tokens / wall:.1f} tok/s")
    # repro.obs nearest-rank percentiles: the same semantics the
    # simulator's SLOReport uses, so engine and sim numbers line up
    f50, f95 = percentiles(lat_first, (0.50, 0.95))
    print(f"[serve] TTFT   p50={f50*1e3:.1f}ms p95={f95*1e3:.1f}ms")
    if lat_token:
        t50, t95 = percentiles(lat_token, (0.50, 0.95))
        print(f"[serve] TPOT   p50={t50*1e3:.1f}ms p95={t95*1e3:.1f}ms")
    return finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=5.0)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    serve(cfg, n_requests=args.requests, rate=args.rate,
          max_batch=args.max_batch)


if __name__ == "__main__":
    main()
