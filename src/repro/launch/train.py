"""Training launcher: end-to-end driver for the assigned architectures.

Small-scale runnable on this CPU container (examples/train_small.py uses
it to train a ~small model for a few hundred steps); the same loop with
the production mesh is what the dry-run lowers.

Features (DESIGN.md §8): synthetic data pipeline with prefetch, AdamW +
cosine/WSD schedule, grad clipping, remat via configs, async
checkpoint/restore (fault tolerance: restart resumes from the latest
step), periodic metrics.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.models import api as mapi
from repro.train import checkpoint as ckpt_mod
from repro.train import optimizer as opt
from repro.train import steps
from repro.train.data import SyntheticLM


def train_loop(cfg, steps_total: int = 200, batch_size: int = 8,
               seq_len: int = 64, ckpt_dir: Optional[str] = None,
               ckpt_every: int = 50, log_every: int = 10,
               seed: int = 0, resume: bool = False):
    model = mapi.get_model(cfg)
    key = jax.random.PRNGKey(seed)
    params, _specs = model.init(key, cfg)
    opt_state = opt.init_opt_state(params)
    oc = opt.OptConfig(total_steps=steps_total,
                       warmup_steps=max(steps_total // 20, 5),
                       schedule="wsd" if "minicpm" in cfg.name else "cosine")
    train_step = jax.jit(steps.make_train_step(cfg, oc),
                         donate_argnums=(0, 1))

    ckpt = ckpt_mod.Checkpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        (params, opt_state), start = ckpt.restore(
            {"p": params, "o": opt_state})["p" if False else slice(None)] \
            if False else (None, 0)
        state, start = ckpt.restore({"p": params, "o": opt_state})
        params, opt_state = state["p"], state["o"]
        print(f"[train] resumed from step {start}")

    data = SyntheticLM(cfg.vocab_size, seq_len, batch_size, seed=seed)
    losses = []
    t0 = time.time()
    try:
        for step_i in range(start, steps_total):
            batch = next(data)
            if cfg.family == "audio":
                batch["frames"] = np.zeros(
                    (batch_size, cfg.enc_seq, cfg.d_model), np.float32)
            if cfg.family == "vlm":
                batch["vision_embeds"] = np.zeros(
                    (batch_size, cfg.n_vision_tokens, cfg.d_model),
                    np.float32)
            params, opt_state, metrics = train_step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step_i + 1) % log_every == 0:
                rate = (step_i + 1 - start) / (time.time() - t0)
                print(f"[train] step {step_i+1}/{steps_total} "
                      f"loss={losses[-1]:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} {rate:.2f} it/s")
            if ckpt and (step_i + 1) % ckpt_every == 0:
                ckpt.save(step_i + 1, {"p": params, "o": opt_state})
    finally:
        data.close()
        if ckpt:
            ckpt.wait()
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced config (CPU container)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, _, losses = train_loop(cfg, args.steps, args.batch, args.seq,
                              ckpt_dir=args.ckpt_dir, resume=args.resume)
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
