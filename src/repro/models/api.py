"""Unified model API: family dispatch + dry-run input specs.

Every family module exposes: init, forward, prefill, decode_step,
init_cache. ``input_specs(cfg, shape)`` returns ShapeDtypeStruct
stand-ins for every input of the step lowered for that shape cell
(weak-type-correct, shardable, no device allocation) together with the
PartitionSpec tree used by the dry-run.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models import common as cm
from repro.models import hybrid, transformer, whisper, xlstm

_FAMILY = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "audio": whisper, "hybrid": hybrid, "ssm": xlstm,
}


def get_model(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def batch_specs(cfg: ModelConfig, shape: InputShape,
                with_labels: bool) -> Tuple[Dict, Dict]:
    """ShapeDtypeStructs + PartitionSpecs for a forward/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    dp = ("pod", "data")
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    specs = {"tokens": P(dp, None)}
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt)
        specs["frames"] = P(dp, None, None)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), dt)
        specs["vision_embeds"] = P(dp, None, None)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = P(dp, None)
    return batch, specs


def decode_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[Dict, Dict]:
    """(inputs, specs) for serve_step: one new token with a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    model = get_model(cfg)
    dt = jnp.dtype(cfg.dtype)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, B, S, dt)[0])
    _, cache_specs = model.init_cache(cfg, 1, 1, dt)
    inputs = {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    specs = {"cache": cache_specs, "tokens": P(("pod", "data"))}
    # audio cross-cache also present (already inside cache pytree)
    return inputs, specs


def input_specs(cfg: ModelConfig, shape: InputShape):
    """Dispatch per shape kind (train/prefill/decode)."""
    if shape.kind == "train":
        return batch_specs(cfg, shape, with_labels=True)
    if shape.kind == "prefill":
        return batch_specs(cfg, shape, with_labels=False)
    return decode_specs(cfg, shape)
