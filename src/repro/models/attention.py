"""GQA attention layer: full-sequence forward (train/prefill) and
single-token cached decode. RoPE / M-RoPE / sinusoidal-free variants.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.models import common as cm


def attn_init(key, cfg, dtype, d_in=None):
    d = d_in or cfg.d_model
    hd = cfg.resolved_head_dim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    fsdp = "data" if cfg.weight_sharding == "fsdp" else None
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.dense_init(ks[0], d, (d, H * hd), dtype),
        "wk": cm.dense_init(ks[1], d, (d, KH * hd), dtype),
        "wv": cm.dense_init(ks[2], d, (d, KH * hd), dtype),
        "wo": cm.dense_init(ks[3], H * hd, (H * hd, cfg.d_model), dtype),
    }
    s = {
        "wq": P(fsdp, "model"), "wk": P(fsdp, "model"), "wv": P(fsdp, "model"),
        "wo": P("model", fsdp),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KH * hd,), dtype)
        p["bv"] = jnp.zeros((KH * hd,), dtype)
        s["bq"] = s["bk"] = s["bv"] = P("model")
    return p, s


def _project_qkv(p, cfg, x):
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    B = x.shape[:-2]
    S = x.shape[-2]
    q = q.reshape(*B, S, H, hd)
    k = k.reshape(*B, S, KH, hd)
    v = v.reshape(*B, S, KH, hd)
    return q, k, v


def _rope_qk(cfg, q, k, positions, mrope_pos=None):
    if cfg.rope == "rope":
        q = cm.apply_rope(q, positions, cfg.rope_theta)
        k = cm.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = cm.apply_mrope(q, mrope_pos, cfg.rope_theta)
        k = cm.apply_mrope(k, mrope_pos, cfg.rope_theta)
    return q, k


def attn_forward(p, cfg, x, positions=None, mrope_pos=None, causal=True,
                 kv=None):
    """Full-sequence attention. x: (B,S,d). kv: optional (k,v) for
    cross-attention (then no rope/causality on kv)."""
    B, S, _ = x.shape
    dp = batch_axes()
    q, k, v = _project_qkv(p, cfg, x)
    if kv is not None:
        k, v = kv
    else:
        if positions is None:
            positions = jnp.arange(S)[None, :]
        q, k = _rope_qk(cfg, q, k, positions, mrope_pos)
    q = constrain(q, dp, None, "model", None)
    k = constrain(k, dp, None, "model", None)
    out = fa_ops.flash_attention(q, k, v, causal=causal,
                                 window=cfg.sliding_window)
    out = out.reshape(B, S, -1)
    return out @ p["wo"]


def attn_prefill(p, cfg, x, positions=None, mrope_pos=None):
    """Forward + return (out, (k_cache_slice, v_cache_slice))."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k = _rope_qk(cfg, q, k, positions, mrope_pos)
    out = fa_ops.flash_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window)
    out = out.reshape(B, S, -1)
    return out @ p["wo"], (k, v)


def attn_decode(p, cfg, x, cache_k, cache_v, lengths, mrope_pos=None):
    """One-token decode. x: (B,d). cache_k/v: (B,Smax,KH,hd); lengths (B,)
    = #valid tokens BEFORE this one. Returns (out (B,d), new_k, new_v)."""
    B, d = x.shape
    dp = batch_axes()
    q, k, v = _project_qkv(p, cfg, x[:, None, :])
    pos = lengths[:, None]                       # (B,1) current position
    if cfg.rope == "mrope":
        q, k = _rope_qk(cfg, q, k, None, mrope_pos)
    else:
        q, k = _rope_qk(cfg, q, k, pos)
    # write K/V at position `lengths`
    idx = lengths[:, None, None, None]
    S = cache_k.shape[1]
    onehot = (jnp.arange(S)[None, :, None, None] == idx)
    cache_k = jnp.where(onehot, k, cache_k)
    cache_v = jnp.where(onehot, v, cache_v)
    out = da_ops.decode_attention(q[:, 0], cache_k, cache_v, lengths + 1,
                                  window=cfg.sliding_window)
    out = constrain(out, dp, "model", None)
    return out.reshape(B, -1) @ p["wo"], cache_k, cache_v
