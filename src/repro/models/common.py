"""Shared pure-JAX model building blocks (no flax): params are nested
dicts of arrays; every initializer returns (params, specs) where specs is
a parallel tree of PartitionSpecs (see repro.distributed.sharding).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


def compute_dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------- init utils
def dense_init(key, fan_in: int, shape, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def stacked(init_fn, key, n: int):
    """Stack per-layer (params, specs): params -> (n, ...), specs -> P(None, *)."""
    keys = jax.random.split(key, n)
    p0, s0 = init_fn(keys[0])
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    specs = jax.tree.map(lambda s: P(None, *tuple(s)), s0,
                         is_leaf=lambda x: isinstance(x, P))
    return params, specs


# ------------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": P(None)}


def rmsnorm(x, p, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    half = head_dim // 2
    s1 = half // 4
    s2 = (half - s1) // 2
    return s1, s2, half - s1 - s2


def apply_mrope(x, positions3, theta: float):
    """M-RoPE: positions3 (3, ..., S) = (temporal, h, w) ids; frequency
    bands are split across the three components (Qwen2-VL §2)."""
    D = x.shape[-1]
    half = D // 2
    inv = rope_freqs(D, theta)
    secs = mrope_sections(D)
    parts, off = [], 0
    for comp, sec in zip(range(3), secs):
        ang = positions3[comp][..., None].astype(jnp.float32) * inv[off:off + sec]
        parts.append(ang)
        off += sec
    ang = jnp.concatenate(parts, axis=-1)                   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(S: int, d: int, offset=0):
    pos = jnp.arange(S, dtype=jnp.float32) + offset
    inv = 1.0 / (10000.0 ** (jnp.arange(d // 2, dtype=jnp.float32) / (d // 2)))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------- embeddings
def embedding_init(key, cfg, dtype):
    vp = padded_vocab(cfg.vocab_size)
    p = {"embed": dense_init(key, cfg.d_model, (vp, cfg.d_model), dtype)}
    s = {"embed": P("model", None)}
    if not cfg.tie_embeddings:
        k2 = jax.random.fold_in(key, 1)
        p["unembed"] = dense_init(k2, cfg.d_model, (cfg.d_model, vp), dtype)
        s["unembed"] = P(None, "model")
    return p, s


def embed_tokens(p, tokens):
    return p["embed"][tokens]


def unembed(p, cfg, x):
    w = p.get("unembed")
    if w is None:
        w = p["embed"].T
    return x @ w


# -------------------------------------------------------------------- loss
def cross_entropy(logits, labels, vocab_size: int):
    """Mean CE over valid labels; logits may be vocab-padded."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab_size)
    nll = (lse - ll) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
