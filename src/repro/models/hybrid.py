"""Zamba2-style hybrid: a stack of Mamba2 (SSD) layers with one *shared*
attention+MLP block (a single weight set) applied after every
``attn_every``-th SSM layer. The shared block consumes concat(h, emb0)
(2d -> d input projection), following Zamba2's global-residual design.

Decode state is O(1) per sequence (SSM state + conv tail) plus a KV cache
only at the few shared-attention insertion points => long_500k runs.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.kernels.mamba_scan import ops as ssd_ops
from repro.kernels.mamba_scan import ref as ssd_ref
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod


def _conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def n_insertions(cfg) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


# --------------------------------------------------------------- mamba layer
def mamba_init(key, cfg, dtype):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    cd = _conv_dim(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "ln": cm.rmsnorm_init(d, dtype)[0],
        "in_proj": cm.dense_init(ks[0], d, (d, di + cd + H), dtype),
        "conv_w": cm.dense_init(ks[1], cfg.ssm_conv, (cfg.ssm_conv, cd), dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "norm": cm.rmsnorm_init(di, dtype)[0],
        "out_proj": cm.dense_init(ks[2], di, (di, d), dtype),
    }
    fsdp = "data" if cfg.weight_sharding == "fsdp" else None
    s = {
        "ln": {"scale": P(None)},
        "in_proj": P(fsdp, "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None), "D": P(None), "dt_bias": P(None),
        "norm": {"scale": P("model")},
        "out_proj": P("model", fsdp),
    }
    return p, s


def _mamba_project(p, cfg, x):
    """x (..., d) -> z (..., di), xBC (..., cd), dt (..., H) post-activation."""
    di, H = cfg.d_inner, cfg.ssm_nheads
    cd = _conv_dim(cfg)
    proj = x @ p["in_proj"]
    z = proj[..., :di]
    xBC = proj[..., di:di + cd]
    dt = jax.nn.softplus(proj[..., di + cd:].astype(jnp.float32)
                         + p["dt_bias"])
    return z, xBC, dt


def _split_xbc(cfg, xBC):
    di, N = cfg.d_inner, cfg.ssm_state
    return xBC[..., :di], xBC[..., di:di + N], xBC[..., di + N:]


def mamba_forward(p, cfg, h, return_state=False):
    """Full-sequence Mamba2 layer. h (B,S,d)."""
    B, S, d = h.shape
    H, Pd, N = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)
    z, xBC, dt = _mamba_project(p, cfg, x_in)
    # causal depthwise conv (width ssm_conv) over the sequence
    w = p["conv_w"]
    pad = jnp.pad(xBC, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S, :] * w[i][None, None, :]
               for i in range(cfg.ssm_conv)) + p["conv_b"]
    conv = jax.nn.silu(conv)
    x, Bm, Cm = _split_xbc(cfg, conv)
    xh = x.reshape(B, S, H, Pd)
    A = -jnp.exp(p["A_log"])
    out = ssd_ops.ssd_scan(xh, dt, A, Bm, Cm, p["D"],
                           with_state=return_state)
    if return_state:
        y, state = out
    else:
        y, state = out, None
    y = y.reshape(B, S, cfg.d_inner)
    y = cm.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out_h = h + y @ p["out_proj"]
    if return_state:
        # last (conv-1) raw xBC inputs, needed to continue the conv
        conv_tail = xBC[:, S - (cfg.ssm_conv - 1):, :] if S >= cfg.ssm_conv - 1 \
            else jnp.pad(xBC, ((0, 0), (cfg.ssm_conv - 1 - S, 0), (0, 0)))
        return out_h, (state, conv_tail)
    return out_h


def mamba_decode(p, cfg, h, ssm_state, conv_buf):
    """One-token step. h (B,d); ssm_state (B,H,P,N); conv_buf (B,conv-1,cd)."""
    B, d = h.shape
    H, Pd = cfg.ssm_nheads, cfg.ssm_head_dim
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)
    z, xBC, dt = _mamba_project(p, cfg, x_in)          # (B,cd),(B,H)
    window = jnp.concatenate([conv_buf, xBC[:, None, :]], axis=1)  # (B,conv,cd)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)
    x, Bm, Cm = _split_xbc(cfg, conv)
    xh = x.reshape(B, H, Pd)
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_ref.ssd_decode_step(ssm_state, xh, dt, A, Bm, Cm,
                                           p["D"])
    y = y.reshape(B, cfg.d_inner)
    y = cm.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return h + y @ p["out_proj"], new_state, window[:, 1:, :]


# ------------------------------------------------------- shared attn block
def shared_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln"], s["ln"] = cm.rmsnorm_init(2 * d, dtype)
    p["attn"], s["attn"] = attn.attn_init(ks[0], cfg, dtype, d_in=2 * d)
    p["ln2"], s["ln2"] = cm.rmsnorm_init(d, dtype)
    p["mlp"], s["mlp"] = mlp_mod.mlp_init(ks[1], cfg, dtype)
    return p, s


def shared_forward(p, cfg, h, emb0, positions):
    x = jnp.concatenate([h, emb0], axis=-1)
    a = attn.attn_forward(p["attn"], cfg, cm.rmsnorm(x, p["ln"], cfg.norm_eps),
                          positions)
    h = h + a
    h = h + mlp_mod.mlp_forward(p["mlp"], cfg,
                                cm.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h


def shared_prefill(p, cfg, h, emb0, positions):
    x = jnp.concatenate([h, emb0], axis=-1)
    a, kv = attn.attn_prefill(p["attn"], cfg,
                              cm.rmsnorm(x, p["ln"], cfg.norm_eps), positions)
    h = h + a
    h = h + mlp_mod.mlp_forward(p["mlp"], cfg,
                                cm.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h, kv


def shared_decode(p, cfg, h, emb0, ck, cv, lengths):
    x = jnp.concatenate([h, emb0], axis=-1)
    a, ck, cv = attn.attn_decode(p["attn"], cfg,
                                 cm.rmsnorm(x, p["ln"], cfg.norm_eps),
                                 ck, cv, lengths)
    h = h + a
    h = h + mlp_mod.mlp_forward(p["mlp"], cfg,
                                cm.rmsnorm(h, p["ln2"], cfg.norm_eps))
    return h, ck, cv


# ------------------------------------------------------------------- model
def init(key, cfg, max_seq: int = 4096):
    dtype = cm.compute_dtype(cfg)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["emb"], s["emb"] = cm.embedding_init(ks[0], cfg, dtype)
    p["mamba"], s["mamba"] = cm.stacked(
        lambda k: mamba_init(k, cfg, dtype), ks[1], cfg.n_layers)
    p["shared"], s["shared"] = shared_init(ks[2], cfg, dtype)
    p["ln_f"], s["ln_f"] = cm.rmsnorm_init(cfg.d_model, dtype)
    return p, s


def _groups(cfg):
    """[(start, stop, attn_after)] covering all layers."""
    out, i = [], 0
    k = cfg.attn_every
    while i < cfg.n_layers:
        j = min(i + k, cfg.n_layers)
        out.append((i, j, (j - i) == k))
        i = j
    return out


def _slice_layers(stacked_params, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], stacked_params)


def forward(params, cfg, batch: Dict):
    tokens = batch["tokens"]
    h = cm.embed_tokens(params["emb"], tokens)
    emb0 = h
    positions = jnp.arange(tokens.shape[1])[None, :]

    def body(h, lp):
        h2 = mamba_forward(lp, cfg, h)
        return constrain(h2, batch_axes(), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    for lo, hi, has_attn in _groups(cfg):
        h, _ = jax.lax.scan(body_fn, h, _slice_layers(params["mamba"], lo, hi))
        if has_attn:
            h = shared_forward(params["shared"], cfg, h, emb0, positions)
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    return constrain(logits, batch_axes(), None, "model"), 0.0


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    L, H, Pd, N = cfg.n_layers, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    cd = _conv_dim(cfg)
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    ni = n_insertions(cfg)
    dp = ("data",)
    cache = {
        "ssm": jnp.zeros((L, batch_size, H, Pd, N), jnp.float32),
        "conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, cd), dtype),
        "k": jnp.zeros((ni, batch_size, max_len, KH, hd), dtype),
        "v": jnp.zeros((ni, batch_size, max_len, KH, hd), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }
    specs = {
        "ssm": P(None, dp, "model", None, None),
        "conv": P(None, dp, None, "model"),
        # long-context: shared-attn KV is sequence-sharded over "data"
        # when batch < data axis (DESIGN.md §5)
        "k": P(None, dp, None, "model", None),
        "v": P(None, dp, None, "model", None),
        "len": P(dp),
    }
    return cache, specs


def prefill(params, cfg, batch: Dict, last_pos=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = cm.embed_tokens(params["emb"], tokens)
    emb0 = h
    positions = jnp.arange(S)[None, :]

    def body(h, lp):
        h2, (state, conv_tail) = mamba_forward(lp, cfg, h, return_state=True)
        return h2, (state, conv_tail)

    states, convs, ks, vs = [], [], [], []
    for lo, hi, has_attn in _groups(cfg):
        h, (st, cv_) = jax.lax.scan(body, h,
                                    _slice_layers(params["mamba"], lo, hi))
        states.append(st)
        convs.append(cv_)
        if has_attn:
            h, kv = shared_prefill(params["shared"], cfg, h, emb0, positions)
            ks.append(kv[0])
            vs.append(kv[1])
    hl = h[:, -1] if last_pos is None else \
        jnp.take_along_axis(h, last_pos[:, None, None].astype(jnp.int32)
                            .repeat(h.shape[-1], -1), axis=1)[:, 0]
    hl = cm.rmsnorm(hl, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, hl)
    cache = {
        "ssm": jnp.concatenate(states, 0),
        "conv": jnp.concatenate(convs, 0),
        "k": jnp.stack(ks, 0) if ks else jnp.zeros((0, B, S, cfg.n_kv_heads,
                                                    cfg.resolved_head_dim)),
        "v": jnp.stack(vs, 0) if vs else jnp.zeros((0, B, S, cfg.n_kv_heads,
                                                    cfg.resolved_head_dim)),
        "len": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    lengths = cache["len"]
    h = cm.embed_tokens(params["emb"], tokens)
    emb0 = h

    def body(h, xs):
        lp, st, cb = xs
        h2, st, cb = mamba_decode(lp, cfg, h, st, cb)
        return h2, (st, cb)

    new_ssm, new_conv, new_k, new_v = [], [], [], []
    ins = 0
    for lo, hi, has_attn in _groups(cfg):
        xs = (_slice_layers(params["mamba"], lo, hi),
              cache["ssm"][lo:hi], cache["conv"][lo:hi])
        h, (st, cb) = jax.lax.scan(body, h, xs)
        new_ssm.append(st)
        new_conv.append(cb)
        if has_attn:
            h, ck, cv = shared_decode(params["shared"], cfg, h, emb0,
                                      cache["k"][ins], cache["v"][ins],
                                      lengths)
            new_k.append(ck)
            new_v.append(cv)
            ins += 1
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, 0),
        "conv": jnp.concatenate(new_conv, 0),
        "k": jnp.stack(new_k, 0) if new_k else cache["k"],
        "v": jnp.stack(new_v, 0) if new_v else cache["v"],
        "len": lengths + 1,
    }
    return logits, new_cache
