"""Dense SwiGLU MLP and MoE (top-k, capacity-dispatched, EP-shardable)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.kernels.moe_gmm import ops as gmm_ops
from repro.models import common as cm

PRODUCTION_TP = 16  # model-axis size of the production mesh (DESIGN.md §5)


def mlp_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    fsdp = "data" if cfg.weight_sharding == "fsdp" else None
    ks = jax.random.split(key, 3)
    p = {"wg": cm.dense_init(ks[0], d, (d, f), dtype),
         "wu": cm.dense_init(ks[1], d, (d, f), dtype),
         "wd": cm.dense_init(ks[2], f, (f, d), dtype)}
    s = {"wg": P(fsdp, "model"), "wu": P(fsdp, "model"),
         "wd": P("model", fsdp)}
    return p, s


def mlp_forward(p, cfg, x):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    h = constrain(h, batch_axes(), None, "model")
    return h @ p["wd"]


# ------------------------------------------------------------------- MoE
def moe_init(key, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    fsdp = "data" if cfg.weight_sharding == "fsdp" else None
    ks = jax.random.split(key, 4)
    # EP over the model axis when the expert count divides it; otherwise
    # TP over the per-expert hidden dim (granite: 40 experts, f=512).
    ep = (E % PRODUCTION_TP == 0)
    we_spec = P("model", fsdp, None) if ep else P(None, fsdp, "model")
    wd_spec = P("model", None, fsdp) if ep else P(None, "model", fsdp)
    p = {"router": cm.dense_init(ks[0], d, (d, E), jnp.float32),
         "wg": cm.dense_init(ks[1], d, (E, d, f), dtype),
         "wu": cm.dense_init(ks[2], d, (E, d, f), dtype),
         "wd": cm.dense_init(ks[3], f, (E, f, d), dtype)}
    s = {"router": P(None, None), "wg": we_spec, "wu": we_spec, "wd": wd_spec}
    return p, s


def moe_forward(p, cfg, x):
    if cfg.moe_impl == "sorted":
        return moe_forward_sorted(p, cfg, x)
    return moe_forward_onehot(p, cfg, x)


def moe_forward_onehot(p, cfg, x):
    """Capacity-factor top-k MoE (GShard-style dispatch via one-hot matmul).

    x: (B, S, d) -> (B, S, d). Returns also an aux load-balancing loss.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    cap = int(cfg.moe_capacity_factor * K * T / E + 0.999)
    cap = max(cap, 4)
    # position of each (token, k) slot within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos_in_e = jnp.cumsum(flat, axis=0) - 1                  # (T*K, E)
    slot = (pos_in_e * flat).sum(-1).reshape(T, K)           # (T, K)
    keep = (slot < cap) & (gate_vals > 0)

    # dispatch tensor (T, K) -> (E, cap) one-hot combine
    disp = (jax.nn.one_hot(gate_idx, E, dtype=xt.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, slot, cap), cap + 1,
                             dtype=xt.dtype)[:, :, None, :])  # (T,K,E,cap+1)
    disp = disp[..., :cap].sum(1)                            # (T, E, cap)
    xe = jnp.einsum("td,tec->ecd", xt, disp)                 # (E, cap, d)
    xe = constrain(xe, "model", None, None)

    h = jax.nn.silu(gmm_ops.grouped_matmul(xe, p["wg"])) \
        * gmm_ops.grouped_matmul(xe, p["wu"])                # (E, cap, f)
    ye = gmm_ops.grouped_matmul(h, p["wd"])                  # (E, cap, d)

    # combine: weight the dispatch tensor by each (token, expert)'s gate
    gates_e = jnp.einsum("tke,tk->te", onehot.astype(xt.dtype),
                         (gate_vals * keep).astype(xt.dtype))  # (T, E)
    comb = disp * gates_e[:, :, None]                          # (T, E, cap)
    y = jnp.einsum("ecd,tec->td", ye, comb)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(0)                                       # (E,)
    ce = (disp.sum(-1) > 0).astype(jnp.float32).mean(0)      # (E,)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------- sorted MoE dispatch
def moe_forward_sorted(p, cfg, x):
    """Sorted (argsort/scatter) capacity MoE dispatch — linear in tokens.

    The GShard one-hot dispatch materializes a (T, E, cap) tensor and two
    T x E x cap x d einsums (cap ~ T/E x factor => O(T^2) work/memory).
    Here tokens are grouped by sequence (the group axis shards over
    "data"), sorted by expert id inside each group, scattered into the
    (E, cap, d) expert buffers, processed by the grouped matmul kernel,
    and gathered back — O(T·K·d) bytes, no quadratic tensor.
    Capacity is per group: cap_g = ceil(factor * K * Tg / E).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    Tg = S * K
    cap = int(cfg.moe_capacity_factor * K * S / E + 0.999)
    cap = max(cap, 1)

    logits = (x.astype(jnp.float32) @ p["router"])             # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (B, S, K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    ids = gate_idx.reshape(B, Tg)                              # (B, S*K)
    order = jnp.argsort(ids, axis=-1, stable=True)             # (B, Tg)
    sorted_ids = jnp.take_along_axis(ids, order, axis=-1)
    counts = jax.nn.one_hot(ids, E, dtype=jnp.int32).sum(1)    # (B, E)
    starts = jnp.cumsum(counts, axis=-1) - counts              # exclusive
    rank = jnp.arange(Tg)[None, :] - jnp.take_along_axis(
        starts, sorted_ids, axis=-1)                           # (B, Tg)
    keep = rank < cap
    dest = jnp.where(keep, sorted_ids * cap + rank, E * cap)   # (B, Tg)
    src_tok = order // K                                       # (B, Tg)

    # scatter tokens into per-expert capacity buffers
    xs = jnp.take_along_axis(x, src_tok[..., None], axis=1)    # (B, Tg, d)
    buf = jnp.zeros((B, E * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda b, dst, v: b.at[dst].add(v))(buf, dest, xs)
    xe = buf[:, :E * cap, :].reshape(B, E, cap, d)
    xe2 = xe.transpose(1, 0, 2, 3).reshape(E, B * cap, d)
    # E % TP == 0: EP — experts sharded over "model", tokens routed by a
    # sized all-to-all. Otherwise expert-TP: tokens stay data-resident
    # and every device applies all experts with model-sharded hidden dims
    # (constraining E over a non-dividing axis would silently replicate
    # the buffers — a 32 GB/layer all-gather; see EXPERIMENTS §Perf A2).
    ep = (E % PRODUCTION_TP == 0)
    xe2 = constrain(xe2, "model" if ep else None, "data", None)

    h = jax.nn.silu(gmm_ops.grouped_matmul(xe2, p["wg"])) \
        * gmm_ops.grouped_matmul(xe2, p["wu"])
    ye = gmm_ops.grouped_matmul(h, p["wd"])                    # (E, B*cap, d)
    ye = ye.reshape(E, B, cap, d).transpose(1, 0, 2, 3)        # (B, E, cap, d)
    ye_flat = jnp.concatenate(
        [ye.reshape(B, E * cap, d), jnp.zeros((B, 1, d), ye.dtype)], axis=1)

    # gather back to (token, k) slots and combine with gates
    out_sorted = jnp.take_along_axis(ye_flat, dest[..., None], axis=1)
    inv = jnp.argsort(order, axis=-1)                          # (B, Tg)
    out_tk = jnp.take_along_axis(out_sorted, inv[..., None], axis=1)
    out_tk = out_tk.reshape(B, S, K, d)
    keep_tk = jnp.take_along_axis(keep.astype(x.dtype), inv, axis=-1
                                  ).reshape(B, S, K)
    y = jnp.einsum("bskd,bsk->bsd", out_tk,
                   gate_vals.astype(x.dtype) * keep_tk)

    # load-balancing aux (same definition as the one-hot path)
    me = probs.reshape(B * S, E).mean(0)
    ce = (counts.astype(jnp.float32) / Tg).mean(0)
    aux = E * jnp.sum(me * ce)
    return y, aux
