"""Decoder-only transformer LM (dense / MoE / VLM families).

Layers are stacked along a leading axis and executed with ``lax.scan``
(O(1) HLO in depth — critical for 40-cell x 512-device dry-run compile
times) with optional remat. Decode carries per-layer KV caches through
the same scan.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod


# ------------------------------------------------------------------ layers
def layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = cm.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attn.attn_init(ks[0], cfg, dtype)
    p["ln2"], s["ln2"] = cm.rmsnorm_init(cfg.d_model, dtype)
    if cfg.family == "moe":
        p["moe"], s["moe"] = mlp_mod.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"], s["mlp"] = mlp_mod.mlp_init(ks[1], cfg, dtype)
    return p, s


def layer_forward(p, cfg, h, positions, mrope_pos=None):
    a = attn.attn_forward(p["attn"], cfg, cm.rmsnorm(h, p["ln1"], cfg.norm_eps),
                          positions, mrope_pos)
    h = h + a
    x = cm.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = mlp_mod.moe_forward(p["moe"], cfg, x)
    else:
        y, aux = mlp_mod.mlp_forward(p["mlp"], cfg, x), 0.0
    return h + y, aux


def layer_prefill(p, cfg, h, positions, mrope_pos=None):
    xn = cm.rmsnorm(h, p["ln1"], cfg.norm_eps)
    a, kv = attn.attn_prefill(p["attn"], cfg, xn, positions, mrope_pos)
    h = h + a
    x = cm.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlp_mod.moe_forward(p["moe"], cfg, x)
    else:
        y = mlp_mod.mlp_forward(p["mlp"], cfg, x)
    return h + y, kv


def layer_decode(p, cfg, h, ck, cv, lengths, mrope_pos=None):
    xn = cm.rmsnorm(h, p["ln1"], cfg.norm_eps)
    a, ck, cv = attn.attn_decode(p["attn"], cfg, xn, ck, cv, lengths,
                                 mrope_pos)
    h = h + a
    x = cm.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = mlp_mod.moe_forward(p["moe"], cfg, x[:, None, :])
        y = y[:, 0, :]
    else:
        y = mlp_mod.mlp_forward(p["mlp"], cfg, x)
    return h + y, ck, cv


# ------------------------------------------------------------------- model
def init(key, cfg, max_seq: int = 4096):
    dtype = cm.compute_dtype(cfg)
    k_emb, k_layers = jax.random.split(key)
    p, s = {}, {}
    p["emb"], s["emb"] = cm.embedding_init(k_emb, cfg, dtype)
    p["layers"], s["layers"] = cm.stacked(
        lambda k: layer_init(k, cfg, dtype), k_layers, cfg.n_layers)
    p["ln_f"], s["ln_f"] = cm.rmsnorm_init(cfg.d_model, dtype)
    return p, s


def _positions_and_embeds(params, cfg, batch: Dict):
    """Token (+vision) embedding and (m)rope positions."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = cm.embed_tokens(params["emb"], tokens)
    mrope_pos = None
    if cfg.family == "vlm":
        ve = batch["vision_embeds"].astype(h.dtype)     # (B, V, d)
        V = ve.shape[1]
        h = jnp.concatenate([ve, h], axis=1)
        side = max(int(V ** 0.5), 1)
        vis_t = jnp.zeros((V,), jnp.int32)
        vis_h = jnp.arange(V) // side
        vis_w = jnp.arange(V) % side
        txt = side + jnp.arange(S)
        pos3 = jnp.stack([
            jnp.concatenate([vis_t, txt]),
            jnp.concatenate([vis_h, txt]),
            jnp.concatenate([vis_w, txt]),
        ])                                              # (3, V+S)
        mrope_pos = jnp.broadcast_to(pos3[:, None, :], (3, B, V + S))
        positions = None
    else:
        positions = jnp.arange(S)[None, :]
    return h, positions, mrope_pos


def forward(params, cfg, batch: Dict):
    """Teacher-forced logits (B, S_total, Vp)."""
    h, positions, mrope_pos = _positions_and_embeds(params, cfg, batch)
    h = constrain(h, batch_axes(), None, None)

    def body(carry, lp):
        h, aux = carry
        h2, a = layer_forward(lp, cfg, h, positions, mrope_pos)
        h2 = constrain(h2, batch_axes(), None, None)
        return (h2, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = jax.lax.scan(body_fn, (h, 0.0), params["layers"])
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    return constrain(logits, batch_axes(), None, "model"), aux


# ------------------------------------------------------------------ serving
def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    dp = ("data",)
    # kv_seq_shard: shard the sequence dim over "model" when kv heads
    # cannot use it (GQA kv < TP) — attention reductions over the sharded
    # seq become scalar psums (EXPERIMENTS §Perf C3)
    kv_spec = P(None, dp, "model", None, None) if cfg.kv_seq_shard \
        else P(None, dp, None, "model", None)
    cache = {
        "k": jnp.zeros((L, batch_size, max_len, KH, hd), dtype),
        "v": jnp.zeros((L, batch_size, max_len, KH, hd), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }
    specs = {"k": kv_spec, "v": kv_spec, "len": P(dp)}
    return cache, specs


def prefill(params, cfg, batch: Dict, last_pos=None):
    """Run the prompt; returns (logits at the last prompt position
    (B, Vp), cache). ``last_pos`` (B,) overrides the sampled position for
    bucket-padded prompts (pads are never attended: cache len is set by
    the engine)."""
    h, positions, mrope_pos = _positions_and_embeds(params, cfg, batch)

    def body(h, lp):
        h2, kv = layer_prefill(lp, cfg, h, positions, mrope_pos)
        return constrain(h2, batch_axes(), None, None), kv

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    hl = h[:, -1] if last_pos is None else \
        jnp.take_along_axis(h, last_pos[:, None, None].astype(jnp.int32)
                            .repeat(h.shape[-1], -1), axis=1)[:, 0]
    hl = cm.rmsnorm(hl, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, hl)
    S_tot = ks.shape[2]
    cache = {"k": ks, "v": vs,
             "len": jnp.full((h.shape[0],), S_tot, jnp.int32)}
    return logits, cache


def decode_step(params, cfg, cache, tokens):
    """One token for every sequence. tokens (B,) -> (logits (B,Vp), cache)."""
    B = tokens.shape[0]
    h = cm.embed_tokens(params["emb"], tokens)              # (B, d)
    lengths = cache["len"]
    mrope_pos = None
    if cfg.family == "vlm":
        pos = lengths[None, :, None]                        # (1,B,1)
        mrope_pos = jnp.broadcast_to(pos, (3, B, 1))

    def body(h, xs):
        lp, ck, cv = xs
        h2, ck, cv = layer_decode(lp, cfg, h, ck, cv, lengths, mrope_pos)
        return h2, (ck, cv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"],
                                         cache["v"]))
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    new_cache = {"k": ks, "v": vs, "len": lengths + 1}
    return logits, new_cache
