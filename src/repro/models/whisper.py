"""Whisper-style encoder-decoder (audio family).

The conv audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, enc_seq, d_model). Sinusoidal positions
on both sides (simplification noted in DESIGN.md). Decoder layers carry
causal self-attention + cross-attention into the encoder output; decode
caches both the self KV and the (fixed) cross KV.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.kernels.decode_attention import ops as da_ops
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlp_mod


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = cm.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attn.attn_init(ks[0], cfg, dtype)
    p["ln2"], s["ln2"] = cm.rmsnorm_init(cfg.d_model, dtype)
    p["mlp"], s["mlp"] = mlp_mod.mlp_init(ks[1], cfg, dtype)
    return p, s


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    p, s = _enc_layer_init(ks[0], cfg, dtype)
    p["ln_x"], s["ln_x"] = cm.rmsnorm_init(cfg.d_model, dtype)
    p["xattn"], s["xattn"] = attn.attn_init(ks[1], cfg, dtype)
    return p, s


def init(key, cfg, max_seq: int = 4096):
    dtype = cm.compute_dtype(cfg)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["emb"], s["emb"] = cm.embedding_init(ks[0], cfg, dtype)
    p["enc_layers"], s["enc_layers"] = cm.stacked(
        lambda k: _enc_layer_init(k, cfg, dtype), ks[1], cfg.n_enc_layers)
    p["dec_layers"], s["dec_layers"] = cm.stacked(
        lambda k: _dec_layer_init(k, cfg, dtype), ks[2], cfg.n_layers)
    p["ln_enc"], s["ln_enc"] = cm.rmsnorm_init(cfg.d_model, dtype)
    p["ln_f"], s["ln_f"] = cm.rmsnorm_init(cfg.d_model, dtype)
    return p, s


def encode(params, cfg, frames):
    """frames: (B, F, d) stub embeddings -> encoder states (B, F, d)."""
    h = frames + cm.sinusoidal_pos(frames.shape[1], cfg.d_model
                                   ).astype(frames.dtype)[None]
    h = constrain(h, batch_axes(), None, None)

    def body(h, lp):
        a = attn.attn_forward(lp["attn"], cfg,
                              cm.rmsnorm(h, lp["ln1"], cfg.norm_eps),
                              causal=False)
        h = h + a
        h = h + mlp_mod.mlp_forward(
            lp["mlp"], cfg, cm.rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return constrain(h, batch_axes(), None, None), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return cm.rmsnorm(h, params["ln_enc"], cfg.norm_eps)


def _cross_kv(lp, cfg, enc):
    """Per-decoder-layer cross K/V from encoder states."""
    B, F, _ = enc.shape
    KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = (enc @ lp["xattn"]["wk"] + (lp["xattn"].get("bk", 0))).reshape(B, F, KH, hd)
    v = (enc @ lp["xattn"]["wv"] + (lp["xattn"].get("bv", 0))).reshape(B, F, KH, hd)
    return k, v


def _dec_layer_forward(lp, cfg, h, enc, positions):
    a = attn.attn_forward(lp["attn"], cfg,
                          cm.rmsnorm(h, lp["ln1"], cfg.norm_eps), positions)
    h = h + a
    kx, vx = _cross_kv(lp, cfg, enc)
    x = cm.rmsnorm(h, lp["ln_x"], cfg.norm_eps)
    cx = attn.attn_forward(lp["xattn"], cfg, x, causal=False, kv=(kx, vx))
    h = h + cx
    h = h + mlp_mod.mlp_forward(lp["mlp"], cfg,
                                cm.rmsnorm(h, lp["ln2"], cfg.norm_eps))
    return h


def forward(params, cfg, batch: Dict):
    """batch: frames (B,F,d), tokens (B,S) -> (logits (B,S,Vp), aux=0)."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = cm.embed_tokens(params["emb"], tokens)
    h = h + cm.sinusoidal_pos(S, cfg.d_model).astype(h.dtype)[None]
    positions = jnp.arange(S)[None, :]

    def body(h, lp):
        h2 = _dec_layer_forward(lp, cfg, h, enc, positions)
        return constrain(h2, batch_axes(), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, h, params["dec_layers"])
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    return constrain(logits, batch_axes(), None, "model"), 0.0


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    L, KH, hd = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    F = cfg.enc_seq
    dp = ("data",)
    cache = {
        "k": jnp.zeros((L, batch_size, max_len, KH, hd), dtype),
        "v": jnp.zeros((L, batch_size, max_len, KH, hd), dtype),
        "xk": jnp.zeros((L, batch_size, F, KH, hd), dtype),
        "xv": jnp.zeros((L, batch_size, F, KH, hd), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }
    kv_spec = P(None, dp, "model", None, None) if cfg.kv_seq_shard \
        else P(None, dp, None, "model", None)
    specs = {"k": kv_spec,
             "v": kv_spec,
             "xk": P(None, dp, None, "model", None),
             "xv": P(None, dp, None, "model", None),
             "len": P(dp)}
    return cache, specs


def prefill(params, cfg, batch: Dict, last_pos=None):
    """Encode + run decoder prompt; returns (last logits, cache)."""
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = cm.embed_tokens(params["emb"], tokens)
    h = h + cm.sinusoidal_pos(S, cfg.d_model).astype(h.dtype)[None]
    positions = jnp.arange(S)[None, :]

    def body(h, lp):
        xn = cm.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, (k, v) = attn.attn_prefill(lp["attn"], cfg, xn, positions)
        h = h + a
        kx, vx = _cross_kv(lp, cfg, enc)
        x = cm.rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        h = h + attn.attn_forward(lp["xattn"], cfg, x, causal=False,
                                  kv=(kx, vx))
        h = h + mlp_mod.mlp_forward(lp["mlp"], cfg,
                                    cm.rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return h, (k, v, kx, vx)

    h, (ks, vs, xks, xvs) = jax.lax.scan(body, h, params["dec_layers"])
    hl = h[:, -1] if last_pos is None else \
        jnp.take_along_axis(h, last_pos[:, None, None].astype(jnp.int32)
                            .repeat(h.shape[-1], -1), axis=1)[:, 0]
    hl = cm.rmsnorm(hl, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, hl)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
             "len": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    lengths = cache["len"]
    h = cm.embed_tokens(params["emb"], tokens)
    # sinusoidal position of the new token (same for all seqs in dry-run;
    # per-seq offsets via lengths)
    d = cfg.d_model
    inv = 1.0 / (10000.0 ** (jnp.arange(d // 2, dtype=jnp.float32) / (d // 2)))
    ang = lengths[:, None].astype(jnp.float32) * inv[None, :]
    pos = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    h = h + pos.astype(h.dtype)
    F = cfg.enc_seq
    flen = jnp.full((B,), F, jnp.int32)

    def body(h, xs):
        lp, ck, cv, xk, xv = xs
        xn = cm.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        a, ck, cv = attn.attn_decode(lp["attn"], cfg, xn, ck, cv, lengths)
        h = h + a
        x = cm.rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        KH, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        q = (x @ lp["xattn"]["wq"] + lp["xattn"].get("bq", 0)).reshape(
            B, cfg.n_heads, hd)
        cx = da_ops.decode_attention(q, xk, xv, flen)
        h = h + cx.reshape(B, -1) @ lp["xattn"]["wo"]
        h = h + mlp_mod.mlp_forward(lp["mlp"], cfg,
                                    cm.rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return h, (ck, cv)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    new_cache = dict(cache, k=ks, v=vs, len=lengths + 1)
    return logits, new_cache
