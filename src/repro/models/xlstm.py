"""xLSTM LM: mLSTM (matrix-memory, exponential gating) blocks with an
sLSTM (scalar-memory, diagonal recurrence) block every ``slstm_every``
layers. Fully recurrent — decode state is O(1) in context length.

The mLSTM forward uses the stabilized *parallel* form for full sequences
(train/prefill) and the exact recurrent form for decode; the two are
mathematically identical because the output
    h_t = C_t q_t / max(|n_t . q_t|, exp(-m_t))
is invariant to the stabilizer m (see tests/test_models.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import constrain, batch_axes
from repro.models import common as cm

CONV = 4  # causal conv width in the mLSTM block


# ------------------------------------------------------------------- mLSTM
def mlstm_init(key, cfg, dtype):
    d, di, H = cfg.d_model, cfg.mlstm_d_inner, cfg.n_heads
    ks = jax.random.split(key, 6)
    fsdp = "data" if cfg.weight_sharding == "fsdp" else None
    p = {
        "ln": cm.rmsnorm_init(d, dtype)[0],
        "up": cm.dense_init(ks[0], d, (d, 2 * di), dtype),
        "conv_w": cm.dense_init(ks[1], CONV, (CONV, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": cm.dense_init(ks[2], di, (di, di), dtype),
        "wk": cm.dense_init(ks[3], di, (di, di), dtype),
        "wv": cm.dense_init(ks[4], di, (di, di), dtype),
        "w_if": cm.dense_init(ks[5], di, (di, 2 * H), jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]
                                ).astype(jnp.float32),
        "norm": cm.rmsnorm_init(di, dtype)[0],
        "down": cm.dense_init(jax.random.fold_in(key, 9), di, (di, d), dtype),
    }
    s = {
        "ln": {"scale": P(None)},
        "up": P(fsdp, "model"), "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "wq": P(None, "model"), "wk": P(None, "model"), "wv": P(None, "model"),
        "w_if": P("model", None), "b_if": P(None),
        "norm": {"scale": P("model")},
        "down": P("model", fsdp),
    }
    return p, s


def _mlstm_project(p, cfg, x_in, conv_window):
    """Shared projection math. x_in (..., d). conv_window: callable giving
    the causally-convolved x. Returns q,k,v,(log_i,log_f),z."""
    di, H = cfg.mlstm_d_inner, cfg.n_heads
    up = x_in @ p["up"]
    x, z = up[..., :di], up[..., di:]
    xc = conv_window(x)
    q = xc @ p["wq"]
    k = xc @ p["wk"]
    v = x @ p["wv"]
    gates = xc.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    log_i = gates[..., :H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])
    return q, k, v, log_i, log_f, z, x


def _heads(cfg, t):
    H = cfg.n_heads
    return t.reshape(*t.shape[:-1], H, t.shape[-1] // H)


CHUNK = 256  # chunk length for the memory-bounded parallel form


def _mlstm_chunked(qh, kh, vh, log_i, log_f, state=None):
    """Chunkwise-parallel stabilized mLSTM: O(chunk^2) score blocks with
    an inter-chunk (C, n, m) state recurrence — identical outputs to the
    token recurrence (property-tested). qh/kh/vh: (B,S,H,dh) fp32;
    log_i/log_f: (B,S,H). Returns (hh, (C, n, m) final state)."""
    B, S, H, dh = qh.shape
    Tc = CHUNK if S % CHUNK == 0 else S
    nc = S // Tc
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def resh(x):
        x = x.reshape(B, nc, Tc, *x.shape[2:])
        return jnp.moveaxis(x, 1, 0)

    t_idx = jnp.arange(Tc)
    causal = (t_idx[:, None] >= t_idx[None, :])[None, :, :, None]

    def chunk_step(carry, inp):
        C, n, m0c = carry
        qc, kc, vc, lic, lfc = inp                  # (B,Tc,H,dh)/(B,Tc,H)
        F = jnp.cumsum(lfc, axis=1)                 # (B,Tc,H)
        Dmat = F[:, :, None, :] - F[:, None, :, :] + lic[:, None, :, :]
        Dmat = jnp.where(causal, Dmat, -jnp.inf)    # (B,T,U,H)
        m_intra = jnp.max(Dmat, axis=2)             # (B,Tc,H)
        m_inter = F + m0c[:, None, :]               # (B,Tc,H)
        m = jnp.maximum(m_intra, m_inter)
        decay = jnp.exp(Dmat - m[:, :, None, :])
        scores = jnp.einsum("bthd,buhd->btuh", qc, kc) * decay
        w_inter = jnp.exp(m_inter - m)              # (B,Tc,H)
        num = jnp.einsum("btuh,buhd->bthd", scores, vc) \
            + w_inter[..., None] * jnp.einsum("bhde,bthe->bthd", C, qc)
        den = jnp.sum(scores, axis=2) \
            + w_inter * jnp.einsum("bhd,bthd->bth", n, qc)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m))
        hh = num / den[..., None]
        # carry the state to the end of the chunk
        Fe = F[:, -1:, :]                           # (B,1,H)
        dd = Fe - F + lic                           # (B,Tc,H)
        m_end = jnp.maximum(Fe[:, 0] + m0c, jnp.max(dd, axis=1))
        wu = jnp.exp(dd - m_end[:, None, :])
        C = jnp.exp(Fe[:, 0] + m0c - m_end)[..., None, None] * C \
            + jnp.einsum("buh,buhd,buhe->bhde", wu, vc, kc)
        n = jnp.exp(Fe[:, 0] + m0c - m_end)[..., None] * n \
            + jnp.einsum("buh,buhd->bhd", wu, kc)
        return (C, n, m_end), hh

    (Cf, nf, mf), hs = jax.lax.scan(
        chunk_step, (C0, n0, m0),
        (resh(qh), resh(kh), resh(vh), resh(log_i), resh(log_f)))
    hh = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, dh)
    return hh, (Cf, nf, mf)


def mlstm_forward(p, cfg, h, return_state=False):
    """Chunkwise-parallel mLSTM over a full sequence. h (B,S,d)."""
    B, S, d = h.shape
    H = cfg.n_heads
    di = cfg.mlstm_d_inner
    dh = di // H
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)

    def conv(x):
        pad = jnp.pad(x, ((0, 0), (CONV - 1, 0), (0, 0)))
        out = sum(pad[:, i:i + S, :] * p["conv_w"][i][None, None, :]
                  for i in range(CONV)) + p["conv_b"]
        return jax.nn.silu(out)

    q, k, v, log_i, log_f, z, x_raw = _mlstm_project(p, cfg, x_in, conv)
    qh = _heads(cfg, q).astype(jnp.float32)         # (B,S,H,dh)
    kh = _heads(cfg, k).astype(jnp.float32) / (dh ** 0.5)
    vh = _heads(cfg, v).astype(jnp.float32)

    hh, (C, n, m_S) = _mlstm_chunked(qh, kh, vh, log_i, log_f)

    y = hh.reshape(B, S, di).astype(h.dtype)
    y = cm.rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = h + y @ p["down"]
    if not return_state:
        return out
    conv_tail = x_raw[:, S - (CONV - 1):, :] if S >= CONV - 1 else \
        jnp.pad(x_raw, ((0, 0), (CONV - 1 - S, 0), (0, 0)))
    return out, (C, n, m_S, conv_tail)


def mlstm_decode(p, cfg, h, C, n, m, conv_buf):
    """One-token recurrent step. h (B,d); C (B,H,dh,dh); n (B,H,dh);
    m (B,H); conv_buf (B,CONV-1,di)."""
    B, d = h.shape
    H = cfg.n_heads
    di = cfg.mlstm_d_inner
    dh = di // H
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)

    store = {}

    def conv(x):
        window = jnp.concatenate([conv_buf, x[:, None, :]], axis=1)
        store["window"] = window
        out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        return jax.nn.silu(out)

    q, k, v, log_i, log_f, z, x_raw = _mlstm_project(p, cfg, x_in, conv)
    qh = _heads(cfg, q).astype(jnp.float32)         # (B,H,dh)
    kh = _heads(cfg, k).astype(jnp.float32) / (dh ** 0.5)
    vh = _heads(cfg, v).astype(jnp.float32)

    m_new = jnp.maximum(log_f + m, log_i)           # (B,H)
    f_s = jnp.exp(log_f + m - m_new)
    i_s = jnp.exp(log_i - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", vh, kh)
    n = f_s[..., None] * n + i_s[..., None] * kh
    b = jnp.einsum("bhd,bhd->bh", n, qh)
    denom = jnp.maximum(jnp.abs(b), jnp.exp(-m_new))
    hh = jnp.einsum("bhde,bhe->bhd", C, qh) / denom[..., None]

    y = hh.reshape(B, di).astype(h.dtype)
    y = cm.rmsnorm(y, p["norm"], cfg.norm_eps) * jax.nn.silu(z)
    return h + y @ p["down"], C, n, m_new, store["window"][:, 1:, :]


# ------------------------------------------------------------------- sLSTM
def slstm_init(key, cfg, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {
        "ln": cm.rmsnorm_init(d, dtype)[0],
        "W": cm.dense_init(ks[0], d, (d, 4 * d), jnp.float32),
        "r": (jax.random.normal(ks[1], (4, d)) * 0.1).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out": cm.dense_init(ks[2], d, (d, d), dtype),
    }
    s = {"ln": {"scale": P(None)}, "W": P(None, "model"), "r": P(None, None),
         "b": P(None), "out": P(None, None)}
    return p, s


def _slstm_cell(p, cfg, pre, state):
    """pre: (B,4d) = x @ W + b. state: (c, n, hs, m) each (B,d)."""
    c, n, hs, m = state
    d = cfg.d_model
    pre = pre + jnp.concatenate(
        [p["r"][g][None, :] * hs for g in range(4)], axis=-1)
    i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
    log_i = i_p
    log_f = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c + i_s * jnp.tanh(z_p)
    n = f_s * n + i_s
    hs = jax.nn.sigmoid(o_p) * c / jnp.maximum(n, jnp.exp(-m_new))
    return (c, n, hs, m_new)


def slstm_forward(p, cfg, h, state=None):
    """Sequence forward via lax.scan. h (B,S,d). Returns (out, state)."""
    B, S, d = h.shape
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)
    pre = x_in.astype(jnp.float32) @ p["W"] + p["b"]
    if state is None:
        zeros = jnp.zeros((B, d), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((B, d), -1e30, jnp.float32))

    def step(st, pre_t):
        st = _slstm_cell(p, cfg, pre_t, st)
        return st, st[2]

    state, ys = jax.lax.scan(step, state, pre.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(h.dtype)
    return h + y @ p["out"], state


def slstm_decode(p, cfg, h, state):
    x_in = cm.rmsnorm(h, p["ln"], cfg.norm_eps)
    pre = x_in.astype(jnp.float32) @ p["W"] + p["b"]
    state = _slstm_cell(p, cfg, pre, state)
    y = state[2].astype(h.dtype)
    return h + y @ p["out"], state


# ------------------------------------------------------------------- model
def _layout(cfg):
    """Groups of (n_mlstm, has_slstm) covering n_layers."""
    out, i = [], 0
    k = cfg.slstm_every
    nm = 0
    while i < cfg.n_layers:
        if k and (i + 1) % k == 0:
            out.append((nm, True))
            nm = 0
        else:
            nm += 1
        i += 1
    if nm:
        out.append((nm, False))
    return out


def n_mlstm(cfg):
    return cfg.n_layers - (cfg.n_layers // cfg.slstm_every if cfg.slstm_every
                           else 0)


def n_slstm(cfg):
    return cfg.n_layers // cfg.slstm_every if cfg.slstm_every else 0


def init(key, cfg, max_seq: int = 4096):
    dtype = cm.compute_dtype(cfg)
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["emb"], s["emb"] = cm.embedding_init(ks[0], cfg, dtype)
    p["mlstm"], s["mlstm"] = cm.stacked(
        lambda k: mlstm_init(k, cfg, dtype), ks[1], n_mlstm(cfg))
    if n_slstm(cfg):
        p["slstm"], s["slstm"] = cm.stacked(
            lambda k: slstm_init(k, cfg, dtype), ks[2], n_slstm(cfg))
    p["ln_f"], s["ln_f"] = cm.rmsnorm_init(cfg.d_model, dtype)
    return p, s


def _slice(stacked_params, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], stacked_params)


def _index(stacked_params, i):
    return jax.tree.map(lambda a: a[i], stacked_params)


def forward(params, cfg, batch: Dict):
    tokens = batch["tokens"]
    h = cm.embed_tokens(params["emb"], tokens)

    def body(h, lp):
        h2 = mlstm_forward(lp, cfg, h)
        return constrain(h2, batch_axes(), None, None), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    mi = si = 0
    for nm, has_s in _layout(cfg):
        if nm:
            h, _ = jax.lax.scan(body_fn, h, _slice(params["mlstm"], mi, mi + nm))
            mi += nm
        if has_s:
            h, _ = slstm_forward(_index(params["slstm"], si), cfg, h)
            si += 1
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    return constrain(logits, batch_axes(), None, "model"), 0.0


def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16):
    H, di = cfg.n_heads, cfg.mlstm_d_inner
    dh = di // H
    d = cfg.d_model
    Lm, Ls = n_mlstm(cfg), n_slstm(cfg)
    dp = ("data",)
    B = batch_size
    cache = {
        "mC": jnp.zeros((Lm, B, H, dh, dh), jnp.float32),
        "mn": jnp.zeros((Lm, B, H, dh), jnp.float32),
        "mm": jnp.full((Lm, B, H), -1e30, jnp.float32),
        "conv": jnp.zeros((Lm, B, CONV - 1, di), dtype),
        "sc": jnp.zeros((Ls, B, d), jnp.float32),
        "sn": jnp.zeros((Ls, B, d), jnp.float32),
        "sh": jnp.zeros((Ls, B, d), jnp.float32),
        "sm": jnp.full((Ls, B, d), -1e30, jnp.float32),
        "len": jnp.zeros((B,), jnp.int32),
    }
    specs = {
        "mC": P(None, dp, "model", None, None),
        "mn": P(None, dp, "model", None),
        "mm": P(None, dp, "model"),
        "conv": P(None, dp, None, "model"),
        "sc": P(None, dp, None), "sn": P(None, dp, None),
        "sh": P(None, dp, None), "sm": P(None, dp, None),
        "len": P(dp),
    }
    return cache, specs


def prefill(params, cfg, batch: Dict, last_pos=None):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = cm.embed_tokens(params["emb"], tokens)

    def body(h, lp):
        h2, st = mlstm_forward(lp, cfg, h, return_state=True)
        return h2, st

    mC, mn, mm, conv, sc, sn, sh, sm = [], [], [], [], [], [], [], []
    mi = si = 0
    for nm, has_s in _layout(cfg):
        if nm:
            h, (C, n, m, cv) = jax.lax.scan(
                body, h, _slice(params["mlstm"], mi, mi + nm))
            mC.append(C), mn.append(n), mm.append(m), conv.append(cv)
            mi += nm
        if has_s:
            h, st = slstm_forward(_index(params["slstm"], si), cfg, h)
            sc.append(st[0]), sn.append(st[1]), sh.append(st[2]), sm.append(st[3])
            si += 1
    hl = h[:, -1] if last_pos is None else \
        jnp.take_along_axis(h, last_pos[:, None, None].astype(jnp.int32)
                            .repeat(h.shape[-1], -1), axis=1)[:, 0]
    hl = cm.rmsnorm(hl, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, hl)
    cache = {
        "mC": jnp.concatenate(mC, 0), "mn": jnp.concatenate(mn, 0),
        "mm": jnp.concatenate(mm, 0), "conv": jnp.concatenate(conv, 0),
        "sc": jnp.stack(sc, 0), "sn": jnp.stack(sn, 0),
        "sh": jnp.stack(sh, 0), "sm": jnp.stack(sm, 0),
        "len": jnp.full((B,), S, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg, cache, tokens):
    B = tokens.shape[0]
    h = cm.embed_tokens(params["emb"], tokens)

    def body(h, xs):
        lp, C, n, m, cb = xs
        h2, C, n, m, cb = mlstm_decode(lp, cfg, h, C, n, m, cb)
        return h2, (C, n, m, cb)

    mC, mn, mm, conv = [], [], [], []
    sc, sn, sh, sm = [], [], [], []
    mi = si = 0
    for nm, has_s in _layout(cfg):
        if nm:
            xs = (_slice(params["mlstm"], mi, mi + nm), cache["mC"][mi:mi + nm],
                  cache["mn"][mi:mi + nm], cache["mm"][mi:mi + nm],
                  cache["conv"][mi:mi + nm])
            h, (C, n, m, cb) = jax.lax.scan(body, h, xs)
            mC.append(C), mn.append(n), mm.append(m), conv.append(cb)
            mi += nm
        if has_s:
            st = (cache["sc"][si], cache["sn"][si], cache["sh"][si],
                  cache["sm"][si])
            h, st = slstm_decode(_index(params["slstm"], si), cfg, h, st)
            sc.append(st[0]), sn.append(st[1]), sh.append(st[2]), sm.append(st[3])
            si += 1
    h = cm.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = cm.unembed(params["emb"], cfg, h)
    new_cache = {
        "mC": jnp.concatenate(mC, 0), "mn": jnp.concatenate(mn, 0),
        "mm": jnp.concatenate(mm, 0), "conv": jnp.concatenate(conv, 0),
        "sc": jnp.stack(sc, 0), "sn": jnp.stack(sn, 0),
        "sh": jnp.stack(sh, 0), "sm": jnp.stack(sm, 0),
        "len": cache["len"] + 1,
    }
    return logits, new_cache
