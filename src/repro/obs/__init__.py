"""Coral observability layer: shared percentile semantics,
per-request SLO latency records and structured control-plane tracing.

Everything in this package is observation-only — importing or enabling
it never changes a simulation outcome (the batched-vs-oracle gauntlet
runs with it on).
"""
from repro.obs.percentiles import (percentile, percentiles,
                                   weighted_percentile,
                                   weighted_percentiles)
from repro.obs.reqlog import (QS, RequestLog, SLOReport, SLOTargets)
from repro.obs.trace import TRACE_SCHEMA, TraceError, TraceLog, \
    validate_record

__all__ = [
    "percentile", "percentiles", "weighted_percentile",
    "weighted_percentiles", "QS", "RequestLog", "SLOReport",
    "SLOTargets", "TRACE_SCHEMA", "TraceError", "TraceLog",
    "validate_record",
]
