"""Shared percentile semantics for every latency/solve-time summary.

One definition, used by ``RunResult.solve_ms_percentiles``, the
``SLOReport`` TTFT/TBT summaries, the allocator resolve-stream bench
and the real-engine launcher prints — so simulator and engine SLO
numbers are computed identically.

Semantics: **nearest-rank with round-half-even** over the sorted
samples — ``sorted(xs)[round(q * (n - 1))]`` for ``q`` in ``[0, 1]``.
Every reported percentile is therefore an *observed* sample (a p99
latency someone actually experienced), never an interpolated value
between two samples; this matches the two pre-existing nearest-rank
implementations bit-for-bit, so porting them here changed no pinned
benchmark reference.

``weighted_percentile`` extends the same rule to run-length-compressed
samples: it returns exactly ``percentile(np.repeat(values, weights),
q)`` without materializing the expansion — the bridge from the
simulator's ``TokenRuns`` records (one record per span segment, weight
``k * b`` tokens) to token-level time-between-token percentiles.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple

import numpy as np


def percentile(xs: Iterable[float], q: float) -> float:
    """Nearest-rank percentile of ``xs`` at fraction ``q`` in [0, 1]."""
    xs = sorted(xs)
    if not xs:
        return 0.0
    return float(xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))])


def percentiles(xs: Iterable[float],
                qs: Sequence[float]) -> Tuple[float, ...]:
    """``percentile`` at several fractions with a single sort."""
    xs = sorted(xs)
    if not xs:
        return tuple(0.0 for _ in qs)
    top = len(xs) - 1
    return tuple(float(xs[min(top, int(round(q * top)))]) for q in qs)


def weighted_percentiles(values, weights,
                         qs: Sequence[float]) -> Tuple[float, ...]:
    """Nearest-rank percentiles of the run-length expansion
    ``np.repeat(values, weights)`` — computed from the compressed form.

    ``weights`` are positive integer multiplicities.  Exactly
    equivalent to ``percentiles(np.repeat(values, weights), qs)``
    (property-tested in tests/test_obs.py)."""
    v = np.asarray(values, dtype=float)
    w = np.asarray(weights, dtype=np.int64)
    if v.size == 0 or int(w.sum()) == 0:
        return tuple(0.0 for _ in qs)
    order = np.argsort(v, kind="stable")
    v = v[order]
    cw = np.cumsum(w[order])
    top = int(cw[-1]) - 1
    out = []
    for q in qs:
        h = min(top, int(round(q * top)))
        # first compressed entry whose cumulative weight exceeds the
        # expanded index h — the sample the expansion would hold there
        out.append(float(v[int(np.searchsorted(cw, h, side="right"))]))
    return tuple(out)


def weighted_percentile(values, weights, q: float) -> float:
    return weighted_percentiles(values, weights, (q,))[0]
