"""Per-request latency records and SLO summaries (observability
pillar (a) — see tools/README.md "Observability").

``RequestLog`` is the simulator's per-request lifecycle record, built
for a near-free event path and lazily-built, cached numpy views on the
query side.  Two tables per model:

* **first-token table** — one ``(rid, arrival, t_first)`` tuple per
  request at its first generated token; TTFT is ``t_first - arrival``.
  In this simulator the first token lands at prefill completion (the
  decode pipeline latency is the *per-token* SLO), so ``t_first`` is
  the request's ``prefill_done`` stamp and a request that loses a
  prefill pass to a node failure records nothing for the lost pass —
  exactly the retired ``Simulator.prefill_lat`` semantics, minus the
  unbounded per-model Python float lists.  Tuples are snapshotted
  eagerly because a re-prefill after a kill overwrites the request's
  ``prefill_done`` field.
* **terminal table** — one row per request outcome: ``finished``,
  ``dropped`` (no pool and none initializing) or ``shed`` (admission
  control).  The event path appends only the ``Request`` object itself
  (the simulator keeps finished requests alive anyway); columns are
  synthesized on first query, and lost rows always read
  ``(-1, -1, 0, 0, 0)`` for the post-arrival fields regardless of how
  far the request got — so batched and oracle runs, which may drop a
  request at different internal points, still produce identical
  records.  The per-model outcome counters mirror the simulator's
  ``dropped_by_model``/``shed_by_model``/``finished`` accounting and
  are cross-checked against them by the ``CORAL_SANITIZE=1`` sanitizer
  (repro.debug.invariants).

Time-between-tokens (TBT) needs no per-token instrumentation at all:
``TokenRuns.gap_samples`` serves iteration-gap samples straight from
the existing run-length token records (one ``(dt, k*b)`` pair per
segment), and ``weighted_percentiles`` reads token-level percentiles
off the compressed form.  Batched mode therefore pays near-zero
logging overhead — gated <5% on the ``sim_loop`` bench.

``SLOReport`` combines both: per-(model, window) TTFT and TBT
p50/p95/p99, SLO-attainment fractions against configurable
``SLOTargets`` (defaulting to each model's ``prefill_slo_ms`` /
``decode_slo_ms``), and windowed tail series.  Everything here is
observation-only: nothing feeds back into simulation decisions, so the
batched-vs-oracle bit-identity contract is untouched (gauntlet-tested
with logging on).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Mapping, Tuple

import numpy as np

from repro.obs.percentiles import percentiles, weighted_percentiles

# statuses of a terminal record
FINISHED, DROPPED, SHED = 0, 1, 2

# the percentile grid every SLO summary reports
QS = (0.50, 0.95, 0.99)


class _ModelLog:
    """One model's lifecycle tables: first-token tuples (snapshotted)
    plus the terminal ``Request`` references, with a lazy numpy view
    over the first-token table."""

    __slots__ = ("first", "fin", "drop", "shd", "_np")

    def __init__(self):
        self.first: List[Tuple[int, float, float]] = []
        self.fin: list = []     # finished Request objects
        self.drop: list = []    # dropped Request objects
        self.shd: list = []     # shed Request objects
        self._np = None         # cached (t_first sorted, ttft sorted)

    def first_arrays(self):
        if self._np is None:
            if self.first:
                a = np.array(self.first, dtype=float)
                t = a[:, 2]
                ttft = t - a[:, 1]
                order = np.argsort(t, kind="stable")
                self._np = (np.ascontiguousarray(t[order]),
                            np.ascontiguousarray(ttft[order]))
            else:
                self._np = (np.zeros(0), np.zeros(0))
        return self._np


class RequestLog:
    """Per-request lifecycle log for one ``Simulator``.  The event-path
    methods do one list append each — priced under the <5% budget on
    the sim_loop bench's pure-decode drain."""

    __slots__ = ("models", "_logs")

    def __init__(self, models: Iterable[str]):
        self.models = tuple(models)
        self._logs: Dict[str, _ModelLog] = {m: _ModelLog()
                                            for m in self.models}

    # ------------------------------------------------------ event path
    def note_first(self, model: str, rid: int, arrival: float, t: float):
        lg = self._logs[model]
        lg.first.append((rid, arrival, t))
        lg._np = None

    def note_finished(self, req):
        self._logs[req.model].fin.append(req)

    def finished_sink(self, model: str) -> list:
        """The raw finished-request list for ``model``: the simulator's
        finish boundary binds it once per settle and appends Request
        objects directly (same effect as ``note_finished``, minus a
        method call per request on the hottest path)."""
        return self._logs[model].fin

    def note_dropped(self, req):
        self._logs[req.model].drop.append(req)

    def note_shed(self, req):
        self._logs[req.model].shd.append(req)

    # ------------------------------------------------------- counters
    # built on demand so the event path never touches a dict counter
    @property
    def n_first(self) -> Dict[str, int]:
        return {m: len(lg.first) for m, lg in self._logs.items()}

    @property
    def n_finished(self) -> Dict[str, int]:
        return {m: len(lg.fin) for m, lg in self._logs.items()}

    @property
    def n_dropped(self) -> Dict[str, int]:
        return {m: len(lg.drop) for m, lg in self._logs.items()}

    @property
    def n_shed(self) -> Dict[str, int]:
        return {m: len(lg.shd) for m, lg in self._logs.items()}

    # ------------------------------------------------------ query side
    def ttft_values(self, model: str) -> np.ndarray:
        """Every recorded TTFT (first-token time minus arrival)."""
        return self._logs[model].first_arrays()[1]

    def ttft_in(self, model: str, t0: float, t1: float) -> np.ndarray:
        """TTFT samples whose first-token time lies in [t0, t1)."""
        t, ttft = self._logs[model].first_arrays()
        i0 = int(np.searchsorted(t, t0, side="left"))
        i1 = int(np.searchsorted(t, t1, side="left"))
        return ttft[i0:i1]

    def first_records(self, model: str) -> List[Tuple]:
        """Sorted (rid, arrival, t_first) rows — the batched and oracle
        loops may record them in a different order, but the *sets* must
        be identical (equivalence tests sort before comparing)."""
        return sorted(self._logs[model].first)

    def terminal_records(self, model: str) -> List[Tuple]:
        """Sorted (rid, status, arrival, prefill_done, finish,
        output_len, tokens_ok, slo_ok) rows.  Lost rows read constant
        post-arrival fields (see module docstring) so batched and
        oracle runs compare equal."""
        lg = self._logs[model]
        rows = [(r.rid, FINISHED, r.arrival, r.prefill_done, r.finish,
                 r.output_len, r.decode_tokens_ok, r.decode_slo_ok)
                for r in lg.fin]
        rows += [(r.rid, DROPPED, r.arrival, -1.0, -1.0, 0, 0, 0)
                 for r in lg.drop]
        rows += [(r.rid, SHED, r.arrival, -1.0, -1.0, 0, 0, 0)
                 for r in lg.shd]
        return sorted(rows)


# --------------------------------------------------------------- targets
@dataclass(frozen=True)
class SLOTargets:
    """Per-model latency targets the attainment fractions score
    against: TTFT (seconds) and time-between-tokens (seconds)."""

    ttft_s: Mapping[str, float]
    tbt_s: Mapping[str, float]

    @staticmethod
    def from_models(models: Mapping[str, object]) -> "SLOTargets":
        """Defaults from each ServedModel's paper SLOs: the prefill
        latency SLO bounds TTFT, the decode SLO bounds the token gap."""
        return SLOTargets(
            ttft_s={m: sm.prefill_slo_ms / 1e3
                    for m, sm in models.items()},
            tbt_s={m: sm.decode_slo_ms / 1e3 for m, sm in models.items()})


# ---------------------------------------------------------------- report
class SLOReport:
    """Windowed TTFT / TBT percentile + attainment summaries over a
    simulator's ``RequestLog`` and ``TokenRuns`` tables.

    Window semantics: a TTFT sample belongs to the window containing
    its *first-token* time; a TBT sample (one iteration gap, weighted
    by the tokens it emitted) to the window containing its iteration
    boundary — matching ``goodput``'s token-window rule.  Empty windows
    report 0.0 percentiles and vacuous attainment 1.0 with
    ``n_ttft``/``n_tbt_tokens`` saying how many samples backed the
    numbers.
    """

    def __init__(self, reqlog: RequestLog, tokens: Dict[str, object],
                 targets: SLOTargets):
        self.reqlog = reqlog
        self.tokens = tokens
        self.targets = targets

    def model_window(self, model: str, t0: float,
                     t1: float) -> Dict[str, float]:
        ttft = self.reqlog.ttft_in(model, t0, t1)
        p50, p95, p99 = percentiles(ttft, QS)
        tgt_f = self.targets.ttft_s.get(model, float("inf"))
        attain_f = float((ttft <= tgt_f).mean()) if ttft.size else 1.0
        vals, wts = self.tokens[model].gap_samples(t0, t1)
        g50, g95, g99 = weighted_percentiles(vals, wts, QS)
        tgt_g = self.targets.tbt_s.get(model, float("inf"))
        n_tok = int(wts.sum())
        attain_g = float(wts[vals <= tgt_g].sum()) / n_tok \
            if n_tok else 1.0
        return {
            "ttft_p50": p50, "ttft_p95": p95, "ttft_p99": p99,
            "tbt_p50": g50, "tbt_p95": g95, "tbt_p99": g99,
            "ttft_attain": attain_f, "tbt_attain": attain_g,
            "n_ttft": float(ttft.size), "n_tbt_tokens": float(n_tok),
        }

    def window(self, t0: float, t1: float) -> Dict[str, Dict[str, float]]:
        return {m: self.model_window(m, t0, t1)
                for m in self.reqlog.models}

    def series(self, model: str, window_s: float, t0: float,
               t1: float) -> List[Dict[str, float]]:
        """Windowed tail series: one summary per ``window_s`` stretch
        of [t0, t1), each tagged with its window edges."""
        out = []
        n = max(int(round((t1 - t0) / window_s)), 1)
        for w in range(n):
            w0 = t0 + w * window_s
            w1 = min(w0 + window_s, t1)
            d = self.model_window(model, w0, w1)
            d["t0"], d["t1"] = w0, w1
            out.append(d)
        return out
