"""Structured control-plane tracing (observability pillar (b)).

``TraceLog`` collects typed event/span records from the epoch loop —
``ClusterRuntime`` (solve spans, reconcile actions, preemptions,
restarts, detections), ``ReSolveController`` (trigger decisions with
their reason and drift diagnostics) and ``FaultInjector`` (planned
injections) — and writes them as JSONL to ``artifacts/trace_*.jsonl``.

Each record is a flat JSON object with three required envelope fields
(``kind``, ``t`` — simulation seconds — and ``epoch``) plus the
kind-specific fields listed in :data:`TRACE_SCHEMA`.  Validation is
two-layered: ``emit`` checks the envelope and required fields at write
time (cheap, always on), and ``tools/trace_tools.py`` re-validates the
full schema plus *causal ordering* when reading a file back — e.g.
every ``fault_detect`` must name a prior ``fault_inject`` for its
instance, every ``restart`` a prior detection.

A subtlety the causal checker must honor: ``fault_inject`` records are
emitted when the injector *plans* an epoch, so they carry a future
``t`` and appear in the file before records with smaller timestamps.
Causal order is therefore judged on the ``t`` fields, never on record
position in the file.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

# kind -> required kind-specific fields (beyond the envelope).
# Optional fields seen in practice are listed in tools/README.md.
TRACE_SCHEMA: Dict[str, tuple] = {
    # epoch solve span: the three-stage breakdown and which ladder
    # tier produced the allocation
    "solve": ("path", "solve_ms", "assembly_ms", "extract_ms",
              "total_ms", "alloc_source"),
    # controller (or fixed-cadence fallback) decision for the epoch
    "trigger": ("resolve", "reason"),
    # mid-epoch event-driven re-solve actually performed
    "mid_resolve": ("reason", "solve_ms"),
    # reconcile summary after an allocation lands
    "reconcile": ("n_new", "n_drained", "n_kept"),
    # capacity reclaimed by the market (spot preemption)
    "preempt": ("iid",),
    # a fault the injector planned (t is the *future* injection time);
    # ``fault`` is the fault class: crash | degrade | flake
    "fault_inject": ("fault", "iid"),
    # the control plane noticed a dead/straggling instance
    "fault_detect": ("iid", "detect_lag_s"),
    # restart attempt outcome for a detected failure
    "restart": ("for_iid", "outcome"),
}

_ENVELOPE = ("kind", "t", "epoch")


class TraceError(ValueError):
    """A trace record broke the schema at emit or read time."""


class TraceLog:
    """In-memory list of trace records with schema-checked ``emit``
    and JSONL ``write``.  Pure observation: emitters never read it."""

    __slots__ = ("records",)

    def __init__(self):
        self.records: List[dict] = []

    def emit(self, kind: str, t: float, epoch: int, **fields):
        if kind not in TRACE_SCHEMA:
            raise TraceError(f"unknown trace record kind {kind!r}")
        missing = [f for f in TRACE_SCHEMA[kind] if f not in fields]
        if missing:
            raise TraceError(
                f"trace record {kind!r} missing fields {missing}")
        rec = {"kind": kind, "t": float(t), "epoch": int(epoch)}
        rec.update(fields)
        self.records.append(rec)

    def by_kind(self, kind: str) -> List[dict]:
        return [r for r in self.records if r["kind"] == kind]

    def write(self, path) -> int:
        """Write all records as JSONL; returns the record count."""
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return len(self.records)


def validate_record(rec: dict) -> Optional[str]:
    """Full-schema check of one parsed record; returns an error
    string or ``None`` (shared by TraceLog.emit's cheap path and the
    trace_tools reader)."""
    for f in _ENVELOPE:
        if f not in rec:
            return f"missing envelope field {f!r}"
    kind = rec["kind"]
    if kind not in TRACE_SCHEMA:
        return f"unknown kind {kind!r}"
    if not isinstance(rec["t"], (int, float)):
        return f"non-numeric t {rec['t']!r}"
    if not isinstance(rec["epoch"], int):
        return f"non-integer epoch {rec['epoch']!r}"
    missing = [f for f in TRACE_SCHEMA[kind] if f not in rec]
    if missing:
        return f"kind {kind!r} missing fields {missing}"
    return None
