"""Cluster runtime: epoch-loop orchestration (paper §5.1 instance
life-cycle + §6.1 evaluation protocol).

Every epoch: estimate demand, read availability, re-solve allocation
(Coral ILP or a baseline), reconcile the running cluster (graceful drain
on scale-down, INIT_DELAY on scale-up), then advance the event simulator
through the epoch while accounting hourly cost (provisioning + amortized
initialization).

The loop closes without oracle inputs (repro.control): leave
``demands_per_epoch`` unset and demands come from a ``DemandEstimator``
fed by the simulator's windowed observables; install a
``ReSolveController`` to gate solves behind demand-drift /
availability-delta triggers, and a ``TransitionPlanner`` to warm-start
``AllocatorState.set_incumbent`` with the cheapest-to-reach target.
``spot_market=True`` reinterprets the availability series as *total*
reclaimable supply: held instances that no longer fit are preempted
(killed, never auto-replaced), and reconcile scale-up is capped by the
epoch's availability.

Pass a persistent ``repro.core.allocator.AllocatorState`` as
``allocator_fn`` to reuse the assembled ILP structure across epoch
re-solves (incumbent warm-start included).  A failed or timed-out solve
(``Allocation.ok == False``) is *not* a scale-to-zero target: the
runtime keeps the previous epoch's allocation and flags the epoch via
``EpochMetrics.solver_failed``.

Fault tolerance: ``fail_instance`` kills a running instance (node
failure) at a random time *within* the epoch via
``Simulator.kill_instance``, which settles the batched event loop's
in-flight accounting and re-routes the victim's work (decode requests
— resident and admission-queued alike — rejoin the decode pool
directly; they never pass through prefill again).  The coordinator
immediately restarts a replacement instance toward the standing
allocation target (paying the initialization delay and amortized init
cost), and the next epoch re-solve re-optimizes the whole cluster
(DESIGN.md §8).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.allocator import AllocProblem, Allocation, Demand
from repro.core.hardware import NodeConfig, Region
from repro.debug import invariants as _inv
from repro.core.modelspec import ServedModel
from repro.core.templates import TemplateLibrary
from repro.obs.percentiles import percentiles as _percentiles
from repro.obs.reqlog import SLOReport, SLOTargets
from repro.simulator.sim import INIT_DELAY_S, SimInstance, Simulator
from repro.traces.workloads import Request


@dataclass
class EpochMetrics:
    epoch: int
    cost_per_hour: float
    init_cost: float
    goodput: Dict[str, float]
    throughput: Dict[str, float]
    n_instances: int
    n_new: int
    n_drained: int
    solve_seconds: float
    unmet: Dict
    # the epoch's solve failed/timed out and the previous epoch's
    # allocation (or an incumbent fallback) was kept instead
    solver_failed: bool = False
    # controller observability: did this epoch run the allocator, and
    # why (initial/epoch/demand_drift/avail_delta/preempted/cadence/
    # cooldown/steady/bootstrap) — "epoch" is the fixed every-epoch
    # cadence used when no ReSolveController is installed
    resolve_triggered: bool = True
    trigger_reason: str = "epoch"
    # spot-market preemptions suffered this epoch (reclaimed instances)
    n_preempted: int = 0
    # fault-recovery observability: failures *detected* this epoch (the
    # coordinator's view — a crash is counted when its health probe
    # fires, which may be the epoch after the node actually died),
    # replacements started mid-epoch, and arrivals shed by admission
    # control
    n_failed: int = 0
    n_restarted: int = 0
    n_shed: int = 0
    # the epoch touched fault recovery: a failure was detected, a
    # replacement started, or a crashed-but-undetected node is still
    # black-holing requests at the epoch edge
    recovering: bool = False
    # degradation-ladder provenance of the epoch's allocation target:
    # solved / fallback (solver timed out, incumbent returned) /
    # last_good (solve failed outright, previous target kept) / kept
    # (trigger-gated skip) / none (failed with no previous target)
    alloc_source: str = "solved"
    # solve-time breakdown of the epoch's allocator call (all zero when
    # the solve was trigger-gated away) and the tier that produced the
    # target, so scenarios and fault_bench can attribute regressions
    assembly_ms: float = 0.0
    solve_ms: float = 0.0               # pure solver time across tiers
    extract_ms: float = 0.0
    solve_path: str = ""    # decomposed|rounded_lp|monolithic|fallback|""
    # event-driven re-solves run *inside* this epoch (availability
    # events: detected failures, blocked restarts)
    n_mid_resolves: int = 0
    # per-model SLO latency summary for the epoch window (repro.obs):
    # model -> {ttft_p50/p95/p99, tbt_p50/p95/p99, ttft_attain,
    # tbt_attain, n_ttft, n_tbt_tokens}
    slo: Dict[str, Dict[str, float]] = field(default_factory=dict)


@dataclass
class RunResult:
    epochs: List[EpochMetrics] = field(default_factory=list)
    # the run's SLOReport, for arbitrary-window / tail-series queries
    # beyond the per-epoch EpochMetrics.slo summaries
    slo_report: Optional[SLOReport] = None

    def avg_cost(self) -> float:
        if not self.epochs:
            return 0.0
        return sum(e.cost_per_hour for e in self.epochs) / len(self.epochs)

    def avg_goodput(self, model: str) -> float:
        if not self.epochs:
            return 0.0
        return sum(e.goodput[model] for e in self.epochs) / len(self.epochs)

    def n_resolves(self) -> int:
        return sum(1 for e in self.epochs if e.resolve_triggered)

    def total_failed(self) -> int:
        if not self.epochs:
            return 0
        return sum(e.n_failed for e in self.epochs)

    def total_restarted(self) -> int:
        if not self.epochs:
            return 0
        return sum(e.n_restarted for e in self.epochs)

    def total_shed(self) -> int:
        if not self.epochs:
            return 0
        return sum(e.n_shed for e in self.epochs)

    def recovery_epochs(self) -> int:
        if not self.epochs:
            return 0
        return sum(1 for e in self.epochs if e.recovering)

    def solve_path_counts(self) -> Dict[str, int]:
        """How many epoch solves each tier served (skips excluded)."""
        out: Dict[str, int] = {}
        for e in self.epochs:
            if e.solve_path:
                out[e.solve_path] = out.get(e.solve_path, 0) + 1
        return out

    def solve_ms_percentiles(self) -> Tuple[float, float]:
        """(p50, p95) of per-epoch solver time, solved epochs only
        (obs.percentiles nearest-rank semantics)."""
        return _percentiles(
            (e.solve_ms for e in self.epochs if e.resolve_triggered),
            (0.50, 0.95))

    def total_mid_resolves(self) -> int:
        return sum(e.n_mid_resolves for e in self.epochs)


AllocatorFn = Callable[[AllocProblem], Allocation]


class ClusterRuntime:
    def __init__(self, models: Dict[str, ServedModel],
                 regions: Sequence[Region], configs: Sequence[NodeConfig],
                 library: TemplateLibrary, allocator_fn: AllocatorFn,
                 workloads: Dict, epoch_s: float = 360.0,
                 init_amortize_s: float = 3600.0,
                 allocator_time_limit: float = 60.0,
                 sim_batched: bool = True, spot_market: bool = False,
                 health_check_s: float = 0.0, restart_policy=None,
                 shed_policy=None, trace=None, slo_targets=None):
        self.models = models
        self.regions = regions
        self.configs = configs
        self.library = library
        self.allocator_fn = allocator_fn
        self.workloads = workloads
        self.epoch_s = epoch_s
        # spot-market availability semantics: the per-epoch availability
        # series is the provider's *total* reclaimable supply (held
        # nodes included) — held instances exceeding it are preempted
        # at the epoch edge.  Default (False) keeps the classic "we
        # keep what we hold" reading where the series is free supply.
        self.spot_market = spot_market
        self.init_k = INIT_DELAY_S / init_amortize_s
        self.time_limit = allocator_time_limit
        # failure-detection latency: a crashed node black-holes routed
        # work for this long before its health probe fires and the
        # queue is re-routed (0 = the seed's instant detection)
        self.health_check_s = health_check_s
        # repro.control.faults.RestartPolicy (backoff + budget +
        # availability check); None = immediate availability-checked
        # restart on every detected failure
        self.restart_policy = restart_policy
        self.sim = Simulator(models, {c.name: c for c in configs}, workloads,
                             batched=sim_batched)
        if shed_policy is not None:     # admission control / load shed
            self.sim.shed_policy = shed_policy
        # observability (repro.obs): structured control-plane tracing
        # (a TraceLog, or None for no tracing) and the run's SLO
        # report over the simulator's request/token records
        self.trace = trace
        self.slo = SLOReport(self.sim.reqlog, self.sim.tokens,
                             slo_targets if slo_targets is not None
                             else SLOTargets.from_models(models))
        self._epoch_idx = 0             # current epoch, for trace records
        self.region_by_name: Dict[str, Region] = {r.name: r for r in regions}
        self.running: Dict[Tuple[str, Tuple], List[SimInstance]] = {}
        # last successful allocation, kept as the target when a later
        # epoch's solve fails (never scale-to-zero on solver failure)
        self._last_alloc: Optional[Allocation] = None
        # mid-epoch failure-replacement accounting (folded into the
        # current epoch's n_new / init_cost by run())
        self._epoch_new = 0
        self._epoch_init_cost = 0.0
        # fault-recovery accounting for the running epoch
        self._epoch_failed = 0
        self._epoch_restarted = 0
        self._epoch_failed_keys: set = set()
        self._fail_pending = 0          # detections since the last decide
        self._epoch_avail: Optional[Dict[Tuple[str, str], int]] = None
        self._injector = None
        # mid-epoch (event-driven) re-solve wiring: run() installs the
        # controller + the epoch's demand/raw-availability snapshots so
        # availability events can trigger a solve inside the epoch
        self._controller = None
        self._epoch_demands: Optional[Sequence[Demand]] = None
        self._epoch_raw_avail: Optional[Dict[Tuple[str, str], int]] = None
        self._epoch_mid_resolves = 0
        self._epoch_mid_drained = 0

    # ------------------------------------------------------------ helpers
    def _emit(self, kind: str, **fields):
        """Trace a control-plane event at the simulator's current time
        in the current epoch (no-op without a TraceLog)."""
        if self.trace is not None:
            self.trace.emit(kind, self.sim.now, self._epoch_idx, **fields)

    def _held_nodes(self) -> Dict[Tuple[str, str], int]:
        held: Dict[Tuple[str, str], int] = {}
        for (region, key), insts in self.running.items():
            live = [i for i in insts if not i.dead and not i.draining]
            for inst in live:
                for c, n in inst.template.counts:
                    held[(region, c)] = held.get((region, c), 0) + n
        return held

    def _current_counts(self) -> Dict[Tuple[str, Tuple], int]:
        return {k: len([i for i in v if not i.dead and not i.draining])
                for k, v in self.running.items()}

    def reconcile(self, alloc: Allocation,
                  avail: Optional[Dict[Tuple[str, str], int]] = None
                  ) -> Tuple[int, int, float]:
        """Scale instances toward the target allocation. Returns
        (n_new, n_drained, init_cost_per_hour_amortized).

        When ``avail`` is given (the same (region, config) -> nodes map
        the allocator solved against), scale-up is capped by it: an
        instance whose node usage no longer fits is *not* started (the
        capacity it wanted was lost — e.g. preempted spot supply — and
        cannot be conjured back by reconciliation).  ILP targets always
        fit their own availability, so the cap only binds for targets
        computed against stale supply (static baselines, kept
        allocations on skipped/failed solves)."""
        n_new = n_drained = 0
        init_cost = 0.0
        cfg = self.library.config_by_name
        targets = dict(alloc.instances)
        # scale down / drain extras (lowest load first, §5.1)
        for key, insts in list(self.running.items()):
            live = [i for i in insts if not i.dead and not i.draining]
            tgt = targets.get(key, 0)
            if len(live) > tgt:
                live.sort(key=lambda i: len(i.queue) + len(i.resident))
                for inst in live[:len(live) - tgt]:
                    self.sim.drain_instance(inst)
                    n_drained += 1
        # scale up
        held = self._held_nodes() if avail is not None else None
        for (region_name, tkey), tgt in targets.items():
            key = (region_name, tkey)
            live = [i for i in self.running.get(key, [])
                    if not i.dead and not i.draining]
            template = alloc.templates[tkey]
            region = self.region_by_name[region_name]
            for _ in range(tgt - len(live)):
                if held is not None:
                    if any(held.get((region_name, c), 0) + n
                           > avail.get((region_name, c), 0)
                           for c, n in template.counts):
                        break               # this template no longer fits
                    for c, n in template.counts:
                        held[(region_name, c)] = \
                            held.get((region_name, c), 0) + n
                inst = self.sim.add_instance(region_name, template)
                self.running.setdefault(key, []).append(inst)
                n_new += 1
                init_cost += template.cost(region, cfg) * self.init_k
        return n_new, n_drained, init_cost

    def _reclaim(self, avail: Dict[Tuple[str, str], int]) -> int:
        """Spot-market preemption: kill held instances until every
        (region, config) holding fits inside the epoch's *total* supply
        (``spot_market`` semantics).  Victims are the least-loaded
        instances using the over-held (region, config); there is no
        automatic replacement — recovering capacity is the allocator's
        job at the next (trigger-driven) re-solve."""
        killed = 0
        while True:
            held = self._held_nodes()
            over = [k for k, h in held.items()
                    if h > avail.get(k, 0)]
            if not over:
                return killed
            region, cname = over[0]
            cands = [i for (rname, _tk), insts in self.running.items()
                     if rname == region
                     for i in insts
                     if not i.dead and not i.draining
                     and any(c == cname for c, _n in i.template.counts)]
            if not cands:       # defensive hang-guard: unreachable while
                return killed   # _held_nodes excludes draining/dead
            victim = min(cands,
                         key=lambda i: len(i.queue) + len(i.resident))
            self.sim.kill_instance(victim)
            self._emit("preempt", iid=victim.iid, region=victim.region,
                       model=victim.template.model)
            killed += 1

    def _shortfall(self, alloc: Allocation,
                   demands: Sequence[Demand]) -> Dict:
        """Unmet tokens/s of a kept allocation against fresh demands."""
        unmet = {}
        for d in demands:
            short = d.tokens_per_s - alloc.served(d.model, d.phase)
            if short > 1e-6:
                unmet[(d.model, d.phase)] = short
        return unmet

    def fail_instance(self, rng: random.Random) -> Optional[SimInstance]:
        """Kill one random live instance (node-failure injection) and
        start a replacement toward the allocation target — if the
        epoch's availability admits one.

        Victims are drawn from *serving* (ready) instances when any
        exist — a node that is still initializing has nothing to lose to
        a failure, and the seed behavior of repeatedly striking the
        just-started replacement at the epoch boundary left the cluster
        permanently without capacity. The replacement pays the usual
        ``INIT_DELAY_S`` and its amortized init cost is charged to the
        current epoch.  Under ``spot_market=True`` the replacement goes
        through the same availability check ``reconcile`` applies: a
        fully-reclaimed (region, config) cannot conjure one back.
        """
        live = [i for i in self.sim.instances.values()
                if not i.dead and not i.draining]
        ready = [i for i in live if i.ready_at <= self.sim.now + 1e-9]
        pool = ready or live
        if not pool:
            return None
        inst = rng.choice(pool)
        # kill_instance settles the batched loop's in-flight accounting
        # and re-routes the victim's work: decode requests (resident AND
        # queued for admission — both already prefilled) rejoin the
        # decode pool via _join_decode, never back through prefill
        self.sim.kill_instance(inst)
        # the legacy fail_rate path bypasses the injector and the
        # health probe: trace the injection and its instant detection
        # here so every restart still follows a detect
        self._emit("fault_inject", fault="crash", iid=inst.iid)
        self._emit("fault_detect", iid=inst.iid, detect_lag_s=0.0)
        self._epoch_failed += 1
        self._epoch_failed_keys.add((inst.region, inst.template.key))
        # immediate replacement: the standing allocation still targets
        # this (region, template); do not wait for the next re-solve
        self._restart(inst)
        self._maybe_mid_resolve()
        return inst

    # ----------------------------------------------- crash / detection
    def _crash(self, inst: SimInstance):
        """Node failure with health-check detection latency: the
        simulator black-holes the node until the probe fires, then the
        coordinator notices (``_on_failure_detected``) and the restart
        policy decides what happens."""
        if inst.dead or inst.failed:
            return
        t_detect = self.sim.crash_instance(inst, self.health_check_s)
        # pushed after crash_instance's own kill event at t_detect, so
        # the queue has been re-routed by the time the coordinator acts
        self.sim.ev.push(t_detect, self._on_failure_detected, inst)

    def _on_failure_detected(self, inst: SimInstance):
        self._epoch_failed += 1
        self._fail_pending += 1
        key = (inst.region, inst.template.key)
        self._epoch_failed_keys.add(key)
        self._emit("fault_detect", iid=inst.iid,
                   detect_lag_s=max(self.health_check_s, 0.0))
        pol = self.restart_policy
        if pol is None:
            self._restart(inst)
        elif pol.allow():
            delay = pol.delay(key)
            pol.note_restart(key)
            if delay > 0.0:
                self.sim.ev.push(self.sim.now + delay, self._restart, inst)
            else:
                self._restart(inst)
        else:
            # restart budget exhausted — the failure-driven re-solve
            # below (or the epoch-edge reconcile) heals it
            self._emit("restart", for_iid=inst.iid,
                       outcome="budget_exhausted")
        self._maybe_mid_resolve()

    def _maybe_mid_resolve(self):
        """Sub-epoch trigger evaluation: ask the controller whether the
        availability event that just fired (a detected failure, a
        blocked restart) warrants re-solving *now* instead of at the
        epoch edge — affordable since the decomposed tier made the
        online solve sub-second.  A successful solve immediately
        becomes the reconcile target, so replacement capacity is placed
        mid-epoch (where ThunderServe's lightweight re-deployment wins
        live)."""
        ctl = self._controller
        if ctl is None or not hasattr(ctl, "decide_event") \
                or self._epoch_raw_avail is None \
                or self._epoch_demands is None:
            return
        n_held = sum(len([i for i in v if not i.dead and not i.draining])
                     for v in self.running.values())
        dec = ctl.decide_event(self.sim.now, 1, n_held)
        if not dec.resolve:
            return
        raw = self._epoch_raw_avail
        if self.spot_market:
            avail = dict(raw)
        else:
            avail = dict(raw)           # we keep what we hold
            for k, n in self._held_nodes().items():
                avail[k] = avail.get(k, 0) + n
        demands = self._epoch_demands
        prob = AllocProblem(
            self.regions, self.configs, avail, demands, self.library,
            current=self._current_counts(), init_penalty_k=self.init_k,
            time_limit=self.time_limit)
        alloc = self.allocator_fn(prob)
        self._epoch_mid_resolves += 1
        self._emit("mid_resolve", reason="availability_event",
                   solve_ms=getattr(alloc, "solver_seconds", 0.0) * 1e3,
                   ok=bool(alloc.ok
                           and not getattr(alloc, "fallback", False)))
        if not alloc.ok or getattr(alloc, "fallback", False):
            return      # a failed mid-epoch solve keeps the standing
            # target; the epoch-edge decide() sees the losses anyway
        if _inv.sanitize_enabled():
            _inv.check_allocation(alloc, avail)
        self._last_alloc = alloc
        n_new, n_drained, init_cost = self.reconcile(
            alloc, self._epoch_avail)
        self._epoch_new += n_new
        self._epoch_mid_drained += n_drained
        self._epoch_init_cost += init_cost
        ctl.notify_solved(demands, raw)

    def _restart(self, inst: SimInstance) -> Optional[SimInstance]:
        """Start a replacement for a failed instance, bounded by the
        epoch's availability; charges the amortized init cost to the
        current epoch and draws the injector's flaky-restart outcome."""
        if not self._restart_fits(inst.region, inst.template):
            # the capacity is gone (e.g. fully-reclaimed spot supply):
            # only a re-solve can move the load somewhere that exists
            self._emit("restart", for_iid=inst.iid, outcome="blocked")
            return None
        key = (inst.region, inst.template.key)
        repl = self.sim.add_instance(inst.region, inst.template)
        self.running.setdefault(key, []).append(repl)
        region = self.region_by_name[inst.region]
        self._epoch_new += 1
        self._epoch_restarted += 1
        self._epoch_init_cost += inst.template.cost(
            region, self.library.config_by_name) * self.init_k
        self._emit("restart", for_iid=inst.iid, outcome="started",
                   new_iid=repl.iid, ready_at=repl.ready_at)
        if self._injector is not None:
            flake = self._injector.restart_outcome()
            if flake is not None:       # crash loop: it dies again
                self.sim.ev.push(repl.ready_at + flake, self._crash, repl)
                if self.trace is not None:
                    # planned like the injector's records: t is the
                    # *future* re-crash time of the flaky replacement
                    self.trace.emit("fault_inject",
                                    repl.ready_at + flake,
                                    self._epoch_idx, fault="flake",
                                    iid=repl.iid)
        return repl

    def _restart_fits(self, region_name: str, template) -> bool:
        """Same availability bound ``reconcile`` applies to scale-up:
        current holdings plus the replacement must fit the availability
        the epoch solved against (which includes held nodes outside the
        spot market, so non-spot replacements always fit)."""
        pol = self.restart_policy
        if pol is not None and not pol.check_availability:
            return True
        avail = self._epoch_avail
        if avail is None:       # outside run(): nothing to check against
            return True
        held = self._held_nodes()
        return all(held.get((region_name, c), 0) + n
                   <= avail.get((region_name, c), 0)
                   for c, n in template.counts)

    # ---------------------------------------------------------------- run
    def run(self, requests: List[Request],
            availability_per_epoch: List[Dict[Tuple[str, str], int]],
            demands_per_epoch: Optional[List[List[Demand]]] = None,
            fail_rate_per_epoch: float = 0.0, seed: int = 0,
            estimator=None, controller=None, planner=None,
            fault_injector=None) -> RunResult:
        """Run the epoch loop.

        Demand source: pass oracle ``demands_per_epoch`` (the classic
        path), or leave it ``None`` to close the loop — demands then
        come from a ``repro.control.estimator.DemandEstimator`` (the
        given one, or a default-configured one) fed by the simulator's
        observables after every epoch.

        Re-solve policy: with a ``repro.control.controller``
        ``ReSolveController`` the allocator only runs on demand-drift /
        availability-delta triggers (or the cadence fallback); skipped
        epochs keep the standing allocation.  A ``TransitionPlanner``
        additionally feeds the allocator the cheapest-to-reach recent
        target as its incumbent warm start (requires an allocator with
        ``set_incumbent``, e.g. ``AllocatorState``).

        Fault injection: a ``repro.control.faults.FaultInjector`` plans
        per-epoch crash / straggler events (scheduled mid-epoch into
        the simulator), may serve the control plane a stale
        availability feed (the physical market — spot reclaim,
        reconcile caps, restart checks — always uses the true series),
        and draws flaky-restart outcomes for every replacement the
        restart path starts.
        """
        rng = random.Random(seed)
        self._injector = fault_injector
        self._controller = controller
        # hand the control-plane components this run's TraceLog unless
        # the caller already wired their own
        if self.trace is not None:
            if controller is not None \
                    and getattr(controller, "trace", None) is None:
                controller.trace = self.trace
                if getattr(controller, "clock", None) is None:
                    controller.clock = lambda: self.sim.now
            if fault_injector is not None \
                    and getattr(fault_injector, "trace", None) is None:
                fault_injector.trace = self.trace
        if demands_per_epoch is not None and estimator is not None:
            raise ValueError("pass oracle demands_per_epoch OR an "
                             "estimator, not both")
        if demands_per_epoch is None and estimator is None:
            from repro.control.estimator import DemandEstimator
            estimator = DemandEstimator(list(self.models), self.workloads)
        for r in requests:
            self.sim.submit(r)
        result = RunResult()
        n_epochs = len(availability_per_epoch)
        can_warm = planner is not None \
            and hasattr(self.allocator_fn, "set_incumbent")
        for e in range(n_epochs):
            self._epoch_idx = e
            t0 = e * self.epoch_s
            t1 = t0 + self.epoch_s
            if estimator is not None:
                demands = estimator.estimate(horizon_s=self.epoch_s)
            else:
                demands = demands_per_epoch[e]
            if _inv.sanitize_enabled():
                _inv.check_demands(demands)
            true_avail = dict(availability_per_epoch[e])
            n_preempted = 0
            if self.spot_market:
                # the series is total supply: shed preempted holdings,
                # then solve against the supply itself.  Preemption is
                # physical — it uses the true series even when the
                # control plane's feed is stale.
                n_preempted = self._reclaim(true_avail)
            if fault_injector is not None:
                raw = dict(fault_injector.observed_availability(
                    e, true_avail))
            else:
                raw = true_avail
            if self.spot_market:
                avail = raw
                rec_avail = true_avail
            else:
                avail = dict(raw)       # the controller drifts on the
                # raw market series; only the solver sees held nodes
                rec_avail = dict(true_avail)
                for k, n in self._held_nodes().items():
                    avail[k] = avail.get(k, 0) + n  # we keep what we hold
                    rec_avail[k] = rec_avail.get(k, 0) + n
            # physical capacity bound for reconcile scale-up and
            # mid-epoch restarts: the provider grants what exists, not
            # what a stale feed claims
            self._epoch_avail = rec_avail
            # snapshots for the event-driven mid-epoch re-solve hook
            self._epoch_demands = demands
            self._epoch_raw_avail = raw
            self._epoch_mid_resolves = 0
            self._epoch_mid_drained = 0
            n_failed_detected = self._fail_pending
            self._fail_pending = 0
            if controller is not None:
                decision = controller.decide(e, demands, raw,
                                             n_preempted=n_preempted,
                                             n_failed=n_failed_detected)
                resolve, reason = decision.resolve, decision.reason
            else:
                # no controller: fixed every-epoch cadence — the
                # runtime traces the decision itself (a controller
                # emits its own trigger records from decide())
                resolve, reason = True, "epoch"
                self._emit("trigger", resolve=True, reason="epoch")
            if not resolve and self._last_alloc is None:
                resolve, reason = True, "bootstrap"
                self._emit("trigger", resolve=True, reason="bootstrap")
            solver_failed = False
            alloc_source = "kept"
            if resolve:
                prob = AllocProblem(
                    self.regions, self.configs, avail, demands,
                    self.library, current=self._current_counts(),
                    init_penalty_k=self.init_k, time_limit=self.time_limit)
                if can_warm:
                    inc = planner.choose_incumbent(self._current_counts())
                    if inc is not None:
                        self.allocator_fn.set_incumbent(inc)
                alloc = self.allocator_fn(prob)
                solver_failed = not alloc.ok \
                    or getattr(alloc, "fallback", False)
                solve_s, unmet = alloc.solve_seconds, alloc.unmet
                # breakdown captured before any fallback reassignment
                solve_path = getattr(alloc, "solve_path", "monolithic")
                assembly_ms = getattr(alloc, "build_seconds", 0.0) * 1e3
                solve_ms = getattr(alloc, "solver_seconds", 0.0) * 1e3
                extract_ms = getattr(alloc, "extract_seconds", 0.0) * 1e3
                if not alloc.ok:
                    # bottom rungs of the degradation ladder: the solve
                    # failed outright (no incumbent to fall back on) —
                    # an empty allocation is NOT a scale-to-zero
                    # target, keep the previous epoch's allocation (if
                    # any) instead of draining the cluster, reporting
                    # its shortfall against *this* epoch's demands
                    if self._last_alloc is not None:
                        alloc = self._last_alloc
                        unmet = self._shortfall(alloc, demands)
                        alloc_source = "last_good"
                    else:
                        alloc_source = "none"
                else:
                    # middle rung: a deadline-bounded solve that timed
                    # out returns the incumbent (Allocation.fallback)
                    alloc_source = "fallback" if solver_failed \
                        else "solved"
                    if alloc_source == "solved" \
                            and _inv.sanitize_enabled():
                        # a fresh solve must fit the availability it
                        # saw; kept/fallback targets may legitimately
                        # overshoot a shrunken market (reconcile caps
                        # them), so only "solved" is checked
                        _inv.check_allocation(alloc, avail)
                    self._last_alloc = alloc
                    # a fallback (failed-HiGHS) result is a usable
                    # target but NOT a solve: the controller's drift
                    # references must not advance (the trigger should
                    # keep firing until a real re-solve lands), and the
                    # planner must not score it as a reached optimum
                    if not solver_failed:
                        if controller is not None:
                            controller.notify_solved(demands, raw)
                        if planner is not None:
                            planner.record(alloc)
            else:
                # trigger-gated skip: keep the standing allocation as
                # the target (reconcile still replaces lost capacity)
                alloc = self._last_alloc
                solve_s = 0.0
                unmet = self._shortfall(alloc, demands)
                solve_path = ""
                assembly_ms = solve_ms = extract_ms = 0.0
            if resolve:
                self._emit("solve", path=solve_path, solve_ms=solve_ms,
                           assembly_ms=assembly_ms,
                           extract_ms=extract_ms, total_ms=solve_s * 1e3,
                           alloc_source=alloc_source,
                           solver_failed=solver_failed)
            n_new, n_drained, init_cost = self.reconcile(alloc, rec_avail)
            self._emit("reconcile", n_new=n_new, n_drained=n_drained,
                       n_kept=max(
                           len([i for i in self.sim.instances.values()
                                if not i.dead and not i.draining])
                           - n_new, 0))
            self._epoch_new = 0
            self._epoch_init_cost = 0.0
            self._epoch_failed = 0
            self._epoch_restarted = 0
            prev_failed_keys = self._epoch_failed_keys
            self._epoch_failed_keys = set()
            shed0 = self.sim.shed
            if self.restart_policy is not None:
                self.restart_policy.begin_epoch(prev_failed_keys)
            if fail_rate_per_epoch > 0 and rng.random() < fail_rate_per_epoch:
                # the node dies at a random point of the epoch, not at
                # the reconcile instant
                self.sim.ev.push(t0 + rng.random() * self.epoch_s,
                                 self.fail_instance, rng)
            if fault_injector is not None:
                for f in fault_injector.plan_epoch(
                        e, t0, self.epoch_s,
                        self.sim.instances.values()):
                    if f.kind == "crash":
                        self.sim.ev.push(f.t, self._crash, f.inst)
                    else:
                        self.sim.ev.push(f.t, self.sim.degrade_instance,
                                         f.inst, f.factor, f.duration_s)
            self.sim.run_until(t1)
            if _inv.sanitize_enabled():
                pol = self.restart_policy
                if pol is None or pol.check_availability:
                    # a restart policy that skips availability checks
                    # deliberately over-holds; everyone else must fit
                    # the epoch's physical supply
                    _inv.check_holdings(self._held_nodes(), rec_avail)
            if estimator is not None:
                estimator.observe(self.sim, t0, t1)
            n_new += self._epoch_new
            n_drained += self._epoch_mid_drained
            init_cost += self._epoch_init_cost
            # provisioning cost of the live cluster
            cfg = self.library.config_by_name
            cost = 0.0
            for (region_name, tkey), insts in self.running.items():
                region = self.region_by_name[region_name]
                live = [i for i in insts if not i.dead]
                for inst in live:
                    cost += inst.template.cost(region, cfg)
            em = EpochMetrics(
                epoch=e, cost_per_hour=cost + init_cost, init_cost=init_cost,
                goodput={m: self.sim.goodput(m, t0, t1) for m in self.models},
                throughput={m: self.sim.throughput(m, t0, t1)
                            for m in self.models},
                n_instances=len([i for i in self.sim.instances.values()
                                 if not i.dead]),
                n_new=n_new, n_drained=n_drained,
                solve_seconds=solve_s, unmet=unmet,
                solver_failed=solver_failed,
                resolve_triggered=resolve, trigger_reason=reason,
                n_preempted=n_preempted,
                n_failed=self._epoch_failed,
                n_restarted=self._epoch_restarted,
                n_shed=self.sim.shed - shed0,
                recovering=(self._epoch_failed > 0
                            or self._epoch_restarted > 0
                            or any(i.failed and not i.dead
                                   for i in self.sim.instances.values())),
                alloc_source=alloc_source,
                assembly_ms=assembly_ms, solve_ms=solve_ms,
                extract_ms=extract_ms, solve_path=solve_path,
                n_mid_resolves=self._epoch_mid_resolves,
                slo=self.slo.window(t0, t1))
            if _inv.sanitize_enabled():
                _inv.check_epoch_metrics(em)
            result.epochs.append(em)
        result.slo_report = self.slo
        return result
