"""Real JAX serving engine: slot-based continuous batching.

This is the per-node execution engine of a Serving Instance (the role
vLLM plays in the paper's runtime, §5.2) — implemented in pure JAX so
the whole serving path runs on this container with small models, and on
TPU unchanged. It is the "real system" against which the event
simulator's latency CDFs are validated (benchmarks/fig6_fidelity.py).

Design: a fixed pool of B decode slots with a pre-allocated KV/state
cache. Prefill runs per-request (bucketed padding), its cache is
inserted into a free slot, and one ``serve_step`` advances every active
slot by a token (inactive slots compute garbage that is masked out —
the standard static-shape TPU serving pattern).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api as mapi
from repro.train import steps


def _bucket(n: int) -> int:
    b = 16
    while b < n:
        b *= 2
    return b


@dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray
    max_new: int
    out_tokens: List[int] = field(default_factory=list)
    submitted: float = 0.0
    prefill_done: float = 0.0
    token_times: List[float] = field(default_factory=list)


class JaxEngine:
    def __init__(self, cfg, params, max_batch: int = 8, max_len: int = 512,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.model = mapi.get_model(cfg)
        self.max_batch = max_batch
        self.max_len = max_len
        dt = jnp.dtype(cfg.dtype)
        self.cache, _ = self.model.init_cache(cfg, max_batch, max_len, dt)
        self._serve = jax.jit(steps.make_serve_step(cfg), donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b, lp: self.model.prefill(p, cfg, b, lp))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.slots: List[Optional[EngineRequest]] = [None] * max_batch
        self.queue: List[EngineRequest] = []
        self.greedy = greedy
        self.iteration_log: List[Tuple[str, int, float]] = []

    # ------------------------------------------------------------ plumbing
    def _insert_impl(self, cache, pre_cache, slot, length):
        def upd(c, p):
            if c.ndim == 1:                     # per-slot lengths
                return c.at[slot].set(length)
            # batch axis is 1; zero-pad trailing dims (kv seq) up to cache
            pads = [(0, 0), (0, 0)]
            for i in range(2, c.ndim):
                pads.append((0, c.shape[i] - p.shape[i]))
            p = jnp.pad(p, pads).astype(c.dtype)
            return jax.lax.dynamic_update_slice_in_dim(c, p, slot, axis=1)
        return jax.tree.map(upd, cache, pre_cache)

    def submit(self, rid: int, prompt: np.ndarray, max_new: int):
        self.queue.append(EngineRequest(rid, np.asarray(prompt), max_new,
                                        submitted=time.time()))

    def _admit(self):
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                S = len(req.prompt)
                # recurrent state absorbs trailing pads, so SSM/xLSTM
                # prefill must run at the exact prompt length; attention
                # families bucket-pad (pads masked via cache len = S).
                bucket = S if self.cfg.is_recurrent \
                    else min(_bucket(S), self.max_len)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :S] = req.prompt[:bucket]
                t0 = time.time()
                batch = {"tokens": jnp.asarray(toks)}
                logits, pre_cache = self._prefill(
                    self.params, batch, jnp.full((1,), S - 1, jnp.int32))
                first = int(jnp.argmax(logits[0, :self.cfg.vocab_size])) \
                    if self.greedy else 0
                self.cache = self._insert(self.cache, pre_cache,
                                          jnp.int32(i), jnp.int32(S))
                jax.block_until_ready(self.cache["len"])
                req.prefill_done = time.time()
                req.out_tokens.append(first)
                self.iteration_log.append(("prefill", bucket,
                                           req.prefill_done - t0))
                self.slots[i] = req

    # ---------------------------------------------------------------- step
    def step(self) -> List[Tuple[int, int, bool]]:
        """Admit + advance every active slot one token.
        Returns [(rid, token, done)]."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        toks = np.zeros((self.max_batch,), np.int32)
        for i in active:
            toks[i] = self.slots[i].out_tokens[-1]
        t0 = time.time()
        logits, self.cache = self._serve(self.params, self.cache,
                                         jnp.asarray(toks))
        nxt = np.asarray(jnp.argmax(logits[:, :self.cfg.vocab_size], -1))
        jax.block_until_ready(nxt)
        dt = time.time() - t0
        self.iteration_log.append(("decode", len(active), dt))
        out = []
        now = time.time()
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i]))
            req.token_times.append(now)
            done = len(req.out_tokens) - 1 >= req.max_new
            out.append((req.rid, int(nxt[i]), done))
            if done:
                self.slots[i] = None
        return out

    def drain(self) -> Dict[int, EngineRequest]:
        """Run to completion; returns finished requests by rid."""
        finished: Dict[int, EngineRequest] = {}
        while any(s is not None for s in self.slots) or self.queue:
            reqs = {s.rid: s for s in self.slots if s is not None}
            for rid, _tok, done in self.step():
                if done:
                    finished[rid] = reqs[rid]
        return finished
