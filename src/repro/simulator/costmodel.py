"""Stage-granularity cost model for the event simulator (paper §5.2:
"the simulator's cost model is fitted from offline profiling data; the
simulator advances execution at the granularity of individual pipeline
stages on each engine node").

``InstanceCostModel`` turns a Serving Template's placement into
per-stage iteration-time functions using the same roofline terms as
repro.core.profiles — so the allocator's predictions and the simulator's
measurements share one calibrated model, and deviations between them
come only from queueing/batching dynamics.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.core import profiles as prof
from repro.core.hardware import (INTER_NODE_GBPS, INTER_NODE_LATENCY_S,
                                 NodeConfig)
from repro.core.modelspec import ServedModel
from repro.core.placement import Placement
from repro.core.profiles import WorkloadStats

KV_TRANSFER_GBPS = 2.5       # prefill->decode KV over CPU RDMA (GLOO)
KV_TRANSFER_LAT = 0.010


@dataclass
class StageModel:
    j: int                      # layers held
    fixed: float                # per-iteration fixed seconds (alpha + weights)
    per_token: float            # marginal seconds per token (aggregated DP)
    capacity_seqs: float        # resident sequences the stage can hold
    # exact decode-iteration model (per DP node): (node, eff_bw, eff_fl,
    # fixed_wo_weights, share) — shares split the batch by node speed
    nodes: tuple = ()


class InstanceCostModel:
    def __init__(self, model: ServedModel, phase: str, placement: Placement,
                 config_by_name: Dict[str, NodeConfig], wl: WorkloadStats):
        self.model = model
        self.phase = phase
        self.wl = wl
        self.placement = placement
        self.slo_s = (model.prefill_slo_ms if phase == "prefill"
                      else model.decode_slo_ms) / 1e3
        self.stages: List[StageModel] = []
        ctx = wl.avg_ctx_decode
        for j, names in zip(placement.layer_counts, placement.stage_nodes):
            fixed = 0.0
            inv_rate = 0.0
            cap = 0.0
            node_terms = []
            for nm in names:
                node = config_by_name[nm]
                eff_bw = node.bw_tbps * 1e12 * prof.BW_EFF
                w_bytes = model.bytes_for_layers(j)
                if phase == "prefill":
                    eff_fl = node.tflops * 1e12 * node.tp_efficiency() \
                        * prof.MFU_PREFILL
                    f_tok = model.flops_per_token_layer(
                        wl.avg_prompt / 2, "prefill") * j
                    fx = prof.ALPHA_PREFILL + w_bytes / eff_bw \
                        + INTER_NODE_LATENCY_S
                    pt = f_tok / eff_fl + model.d_model * model.dtype_bytes \
                        / (INTER_NODE_GBPS * 1e9)
                    cap += prof.MAX_PREFILL_CHUNK
                else:
                    eff_fl = node.tflops * 1e12 * node.tp_efficiency() \
                        * prof.MFU_DECODE
                    f_tok = model.flops_per_token_layer(ctx, "decode") * j
                    fx = prof.ALPHA_DECODE + INTER_NODE_LATENCY_S \
                        + model.decode_read_bytes(j, 0.0, ctx) / eff_bw
                    pt = (model.decode_read_bytes(j, 1.0, ctx)
                          - model.decode_read_bytes(j, 0.0, ctx)) / eff_bw \
                        + f_tok / eff_fl + model.d_model * model.dtype_bytes \
                        / (INTER_NODE_GBPS * 1e9)
                    mem = node.mem_gb * 1e9 * prof.MEM_HEADROOM
                    kv_seq = model.kv_bytes_per_seq(j, wl.max_ctx) if not \
                        model.recurrent else j * 64 * model.d_model * 4
                    cap += max((mem - w_bytes) / max(kv_seq, 1.0), 0.0)
                    node_terms.append((j, eff_bw, eff_fl,
                                       f_tok, 1.0 / pt))
                fixed = max(fixed, fx)
                inv_rate += 1.0 / pt
            shares = tuple((jj, bw, fl, ft, inv / inv_rate)
                           for jj, bw, fl, ft, inv in node_terms)
            self.stages.append(StageModel(j, fixed, 1.0 / inv_rate, cap,
                                          shares))

    # ------------------------------------------------------------- prefill
    def prefill_iter_time(self, tokens: int) -> float:
        """Bottleneck-stage time for one chunked-prefill iteration."""
        return max(s.fixed + tokens * s.per_token for s in self.stages)

    def prefill_pipeline_latency(self, tokens: int) -> float:
        return sum(s.fixed + tokens * s.per_token for s in self.stages)

    @property
    def prefill_chunk(self) -> int:
        """SLO-aware chunked-prefill admission budget (the C* the template
        generator assumed): largest chunk whose pipeline traversal meets
        the prefill SLO."""
        if hasattr(self, "_pchunk"):
            return self._pchunk
        fixed = sum(s.fixed for s in self.stages)
        pt = sum(s.per_token for s in self.stages)
        if fixed >= self.slo_s:
            self._pchunk = max(int(self.wl.avg_prompt), 1)
        else:
            c = int((self.slo_s - fixed) / max(pt, 1e-12))
            self._pchunk = max(min(c, prof.MAX_PREFILL_CHUNK), 1)
        return self._pchunk

    # -------------------------------------------------------------- decode
    def _decode_stage_time(self, s: StageModel, batch: int) -> float:
        """Exact per-stage decode iteration time: the nonlinear
        decode_read_bytes (MoE expert reads saturate once every expert is
        activated) evaluated per DP node at its share of the batch."""
        if not s.nodes or self.phase != "decode":
            return s.fixed + batch * s.per_token
        ctx = self.wl.avg_ctx_decode
        t = 0.0
        for j, eff_bw, eff_fl, f_tok, share in s.nodes:
            b = batch * share
            tn = (prof.ALPHA_DECODE + INTER_NODE_LATENCY_S
                  + self.model.decode_read_bytes(j, b, ctx) / eff_bw
                  + b * f_tok / eff_fl
                  + b * self.model.d_model * self.model.dtype_bytes
                  / (INTER_NODE_GBPS * 1e9))
            t = max(t, tn)
        return t

    def decode_iter_time(self, batch: int) -> float:
        return max(self._decode_stage_time(s, batch) for s in self.stages)

    def decode_pipeline_latency(self, batch: int) -> float:
        return sum(self._decode_stage_time(s, batch) for s in self.stages)

    def decode_times(self, batch: int) -> Tuple[float, float]:
        """Batched-loop API: (iteration time, pipeline latency) from a
        single per-stage sweep — the same floats ``decode_iter_time`` /
        ``decode_pipeline_latency`` produce, computed once instead of
        twice per scheduled iteration."""
        ts = [self._decode_stage_time(s, batch) for s in self.stages]
        return max(ts), sum(ts)

    @property
    def decode_capacity(self) -> int:
        """Resident-batch cap: KV memory AND SLO-aware admission — the
        largest batch whose inter-token (pipeline) latency meets the SLO."""
        if hasattr(self, "_dcap"):
            return self._dcap
        b_mem = max(int(min(s.capacity_seqs for s in self.stages)), 1)
        if self.decode_pipeline_latency(1) > self.slo_s:
            self._dcap = 1
            return 1
        lo, hi = 1, b_mem
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.decode_pipeline_latency(mid) <= self.slo_s:
                lo = mid
            else:
                hi = mid - 1
        self._dcap = lo
        return lo

    # ------------------------------------------------------------ transfer
    def kv_transfer_time(self, prompt_tokens: int) -> float:
        bytes_ = prompt_tokens * self.model.kv_bytes_per_token_layer() \
            * self.model.n_layers
        if self.model.recurrent:
            bytes_ = self.model.n_layers * 64 * self.model.d_model * 4
        return KV_TRANSFER_LAT + bytes_ / (KV_TRANSFER_GBPS * 1e9)
