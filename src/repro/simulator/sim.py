"""Event-based multi-LLM serving simulator (paper §5.2, §6.2).

Faithful to the Coral runtime design (Fig. 5): a coordinator hosts the
router (weighted round-robin by template throughput, with EWMA straggler
feedback); each Serving Instance runs chunked-prefill or
continuous-batching decode iterations whose durations come from the
stage-granularity cost model; KV caches are transferred prefill->decode
with a bandwidth/latency model; scale-down drains, scale-up pays an
initialization delay.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.hardware import NodeConfig, Region
from repro.core.modelspec import ServedModel
from repro.core.templates import ServingTemplate
from repro.simulator.costmodel import InstanceCostModel
from repro.traces.workloads import Request

INIT_DELAY_S = 90.0           # node start + weight load + warmup (§5.1)


class EventQueue:
    def __init__(self):
        self._q: List = []
        self._c = itertools.count()

    def push(self, t: float, fn: Callable, *args):
        heapq.heappush(self._q, (t, next(self._c), fn, args))

    def pop(self):
        return heapq.heappop(self._q)

    def __bool__(self):
        return bool(self._q)


@dataclass
class TokenRecord:
    t: float
    latency: float
    ok: bool


class SimInstance:
    """One Serving Instance (prefill or decode role)."""

    def __init__(self, iid: int, region: str, template: ServingTemplate,
                 model: ServedModel, cm: InstanceCostModel, ready_at: float):
        self.iid = iid
        self.region = region
        self.template = template
        self.model = model
        self.cm = cm
        self.ready_at = ready_at
        self.draining = False
        self.dead = False
        self.busy = False
        self.queue: List[Request] = []          # prefill queue
        self.resident: List[Tuple[Request, int]] = []  # decode (req, emitted)
        self.ewma_load = 0.0

    @property
    def phase(self) -> str:
        return self.template.phase

    @property
    def weight(self) -> float:
        return self.template.throughput / (1.0 + self.ewma_load)

    def idle(self) -> bool:
        return not self.queue and not self.resident and not self.busy


class Simulator:
    def __init__(self, models: Dict[str, ServedModel],
                 config_by_name: Dict[str, NodeConfig],
                 workloads: Dict[str, "WorkloadStats"]):
        self.models = models
        self.configs = config_by_name
        self.workloads = workloads
        self.ev = EventQueue()
        self.now = 0.0
        self._iid = itertools.count()
        self.instances: Dict[int, SimInstance] = {}
        self.tokens: Dict[str, List[TokenRecord]] = {m: [] for m in models}
        self.prefill_lat: Dict[str, List[float]] = {m: [] for m in models}
        self.finished: List[Request] = []
        self.dropped: int = 0

    # ------------------------------------------------------------ cluster
    def add_instance(self, region: str, template: ServingTemplate,
                     ready_delay: float = INIT_DELAY_S,
                     cm: Optional[object] = None) -> SimInstance:
        """cm: override the cost model (e.g. a profiling-fitted one for the
        simulator-fidelity study, §6.2)."""
        model = self.models[template.model]
        if cm is None:
            cm = InstanceCostModel(model, template.phase, template.placement,
                                   self.configs,
                                   self.workloads[template.model])
        inst = SimInstance(next(self._iid), region, template, model, cm,
                           self.now + ready_delay)
        self.instances[inst.iid] = inst
        return inst

    def drain_instance(self, inst: SimInstance):
        inst.draining = True

    def pool(self, model: str, phase: str) -> List[SimInstance]:
        return [i for i in self.instances.values()
                if i.template.model == model and i.phase == phase
                and not i.draining and not i.dead
                and i.ready_at <= self.now + 1e-9]

    # ------------------------------------------------------------- router
    def route(self, model: str, phase: str) -> Optional[SimInstance]:
        pool = self.pool(model, phase)
        if not pool:
            return None
        # weighted selection: least (queue depth / weight) — weighted-RR
        # with EWMA straggler correction (DESIGN.md §8)
        def load(i: SimInstance) -> float:
            depth = len(i.queue) + len(i.resident)
            return (depth + 1.0) / max(i.weight, 1e-9)
        return min(pool, key=load)

    # ------------------------------------------------------------ arrival
    def submit(self, req: Request):
        self.ev.push(req.arrival, self._on_arrival, req)

    def _on_arrival(self, req: Request):
        inst = self.route(req.model, "prefill")
        if inst is None:
            self.dropped += 1
            return
        inst.queue.append(req)
        self._maybe_start(inst)

    # ------------------------------------------------------------ prefill
    def _maybe_start(self, inst: SimInstance):
        if inst.busy or inst.dead or self.now < inst.ready_at:
            if not inst.busy and not inst.dead and self.now < inst.ready_at \
                    and (inst.queue or inst.resident):
                self.ev.push(inst.ready_at, self._maybe_start, inst)
            return
        if inst.phase == "prefill" and inst.queue:
            batch, tokens = [], 0
            while inst.queue and tokens < inst.cm.prefill_chunk:
                r = inst.queue.pop(0)
                batch.append(r)
                tokens += r.prompt_len
            # successive iterations pipeline across stages: the instance
            # re-admits after the bottleneck-stage time, while the batch
            # completes after the full pipeline traversal.
            free = inst.cm.prefill_iter_time(tokens)
            done = inst.cm.prefill_pipeline_latency(tokens)
            inst.busy = True
            inst.ewma_load = 0.9 * inst.ewma_load + 0.1 * len(inst.queue)
            self.ev.push(self.now + free, self._free, inst)
            self.ev.push(self.now + done, self._prefill_done, inst, batch)
        elif inst.phase == "decode" and (inst.resident or inst.queue):
            while inst.queue and len(inst.resident) < inst.cm.decode_capacity:
                inst.resident.append((inst.queue.pop(0), 0))
            b = len(inst.resident)
            free = inst.cm.decode_iter_time(b)
            lat = inst.cm.decode_pipeline_latency(b)
            inst.busy = True
            self.ev.push(self.now + free, self._decode_done, inst, lat)

    def _free(self, inst: SimInstance):
        inst.busy = False
        self._maybe_start(inst)

    def _prefill_done(self, inst: SimInstance, batch: List[Request]):
        for r in batch:
            r.prefill_done = self.now
            self.prefill_lat[r.model].append(self.now - r.arrival)
            # KV transfer to a decode instance
            dst = self.route(r.model, "decode")
            if dst is None:
                self.dropped += 1
                continue
            delay = inst.cm.kv_transfer_time(r.prompt_len)
            self.ev.push(self.now + delay, self._join_decode, dst, r)

    # ------------------------------------------------------------- decode
    def _join_decode(self, inst: SimInstance, req: Request):
        if inst.dead:
            inst2 = self.route(req.model, "decode")
            if inst2 is None:
                self.dropped += 1
                return
            inst = inst2
        if len(inst.resident) < inst.cm.decode_capacity:
            inst.resident.append((req, 0))
        else:
            inst.queue.append(req)      # SLO-aware admission control
        self._maybe_start(inst)

    def _decode_done(self, inst: SimInstance, lat: float):
        inst.busy = False
        slo = inst.model.decode_slo_ms / 1e3
        ok = lat <= slo
        still = []
        for req, emitted in inst.resident:
            emitted += 1
            self.tokens[req.model].append(TokenRecord(self.now, lat, ok))
            if ok:
                req.decode_slo_ok += 1
            req.decode_tokens_ok += 1
            if emitted >= req.output_len:
                req.finish = self.now
                self.finished.append(req)
            else:
                still.append((req, emitted))
        cap = inst.cm.decode_capacity
        inst.resident = still
        # admit pending requests up to the SLO/memory cap
        while inst.queue and len(inst.resident) < cap:
            inst.resident.append((inst.queue.pop(0), 0))
        if inst.draining and not inst.resident and not inst.queue:
            inst.dead = True
        self._maybe_start(inst)

    # ---------------------------------------------------------------- run
    def run_until(self, t_end: float):
        while self.ev and self.ev._q[0][0] <= t_end:
            t, _, fn, args = self.ev.pop()
            self.now = max(self.now, t)
            fn(*args)
        self.now = t_end

    # ------------------------------------------------------------ metrics
    def goodput(self, model: str, t0: float, t1: float) -> float:
        """Generated tokens/s within [t0, t1) meeting the decode SLO."""
        recs = [r for r in self.tokens[model] if t0 <= r.t < t1 and r.ok]
        return len(recs) / max(t1 - t0, 1e-9)

    def throughput(self, model: str, t0: float, t1: float) -> float:
        recs = [r for r in self.tokens[model] if t0 <= r.t < t1]
        return len(recs) / max(t1 - t0, 1e-9)
