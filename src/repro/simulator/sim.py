"""Event-based multi-LLM serving simulator (paper §5.2, §6.2).

Faithful to the Coral runtime design (Fig. 5): a coordinator hosts the
router (weighted round-robin by template throughput, with EWMA straggler
feedback); each Serving Instance runs chunked-prefill or
continuous-batching decode iterations whose durations come from the
stage-granularity cost model; KV caches are transferred prefill->decode
with a bandwidth/latency model; scale-down drains, scale-up pays an
initialization delay.

Event-loop performance (ROADMAP "simulator event loop"): the decode hot
path is batched.  Decode dynamics are piecewise-deterministic — between
KV joins, the resident set only changes at *known* iteration counts
(each request finishes after its remaining output tokens) — so instead
of one heap event per iteration the simulator schedules a *span*: a
segment schedule (batch size, iteration time, SLO verdict per segment)
covering many iterations, ending where the schedule would be
invalidated (an admission from a non-empty queue at the first finisher,
the run horizon, or the adaptive span budget).  A KV join or a node
failure mid-span settles the iterations whose boundaries have already
passed and converts the in-flight iteration back into a per-iteration
event; a join that lands on a *full* instance merely queues (it cannot
change the running batch) and is logged for the EWMA replay, leaving
the span intact.  Iteration boundaries are accumulated sequentially
(``t += dt``, never reconstructed as ``t0 + i*dt``), so the batched
loop reproduces the reference per-iteration loop's accounting
bit-for-bit.  ``batched=False`` keeps the one-event-per-iteration loop
as the equivalence oracle (see tests/test_sim.py and
benchmarks/sim_loop.py).

Two data-structure choices keep span bookkeeping off the O(batch) path:

* Residents are a list sorted by *absolute finish iteration* (the
  instance's cumulative iteration counter at join + the request's
  output length).  Settling a span pops finishers off the front;
  requests that did not finish are untouched.  Per-request token/SLO
  counters derive in O(1) at finish from the instance's cumulative
  ``iters``/``ok_iters`` counters snapshotted at join time (they
  materialize when a request finishes or is re-routed by a failure).
* Token accounting is run-length compressed (``TokenRuns``): one
  record per span segment instead of ``k * batch`` per-token objects;
  ``goodput``/``throughput`` queries count whole runs with vectorized
  numpy masks, expanding only the (rare) runs that straddle a query
  edge.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_right
from collections import deque
from dataclasses import dataclass
from itertools import accumulate, islice, repeat
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware import NodeConfig, Region
from repro.core.modelspec import ServedModel
from repro.core.templates import ServingTemplate
from repro.debug import invariants as _inv
from repro.obs.reqlog import RequestLog
from repro.simulator.costmodel import InstanceCostModel
from repro.traces.workloads import Request

INIT_DELAY_S = 90.0           # node start + weight load + warmup (§5.1)

SPAN_MAX = 4096               # hard cap on the adaptive span budget


@dataclass
class ShedPolicy:
    """Admission control: shed a new arrival when its prefill pool's
    total queued requests exceed ``max_queue_per_instance`` per live
    *ready* instance — a drain bound: backlog beyond it cannot be
    worked off before it goes stale, so accepting it only inflates
    every queue behind it.  Shedding applies to fresh arrivals at
    admission (``_on_arrival``) only; requests already admitted —
    including cold-start holds being flushed and a killed instance's
    re-routed queue — are never shed.  With no ready prefill instance
    the cold-start hold/drop path decides instead."""

    max_queue_per_instance: float = 32.0


class EventQueue:
    def __init__(self):
        self._q: List = []
        self._c = itertools.count()

    def push(self, t: float, fn: Callable, *args):
        heapq.heappush(self._q, (t, next(self._c), fn, args))

    def pop(self):
        return heapq.heappop(self._q)

    def __bool__(self):
        return bool(self._q)


class TokenRuns:
    """Generated-token accounting for one model, as run-length records.

    A run is ``k`` consecutive decode iterations of constant batch size
    ``b`` and constant SLO verdict ``ok``; its iteration boundaries are
    ``t0 + dt, (t0 + dt) + dt, ...`` accumulated *sequentially* (the
    floats the per-iteration loop would have produced) with the last
    boundary stored as ``end``.  ``count`` resolves window queries from
    the run table: runs entirely inside the window contribute ``k * b``
    via one vectorized mask; only runs straddling a window edge are
    expanded boundary-by-boundary.
    """

    def __init__(self):
        self._t0: List[float] = []
        self._dt: List[float] = []
        self._k: List[int] = []
        self._b: List[int] = []
        self._ok: List[bool] = []
        self._end: List[float] = []
        self._total = 0
        self._np = None         # cached numpy view (invalidated on add)

    def add(self, t0: float, dt: float, k: int, b: int, ok: bool,
            end: float):
        self._t0.append(t0)
        self._dt.append(dt)
        self._k.append(k)
        self._b.append(b)
        self._ok.append(ok)
        self._end.append(end)
        self._total += k * b
        self._np = None

    def __len__(self) -> int:
        """Total generated tokens (sum of k*b over runs)."""
        return self._total

    @property
    def n_runs(self) -> int:
        return len(self._t0)

    def _arrays(self):
        if self._np is None:
            self._np = (np.array(self._t0), np.array(self._dt),
                        np.array(self._k), np.array(self._b),
                        np.array(self._ok, dtype=bool),
                        np.array(self._end))
        return self._np

    def count(self, q0: float, q1: float, ok_only: bool = False) -> int:
        """Tokens whose iteration boundary lies in [q0, q1)."""
        if not self._t0:
            return 0
        t0, dt, k, b, ok, end = self._arrays()
        first = t0 + dt
        hit = (end >= q0) & (first < q1)
        if ok_only:
            hit &= ok
        full = hit & (first >= q0) & (end < q1)
        total = int((k[full] * b[full]).sum())
        for i in np.nonzero(hit & ~full)[0]:
            t, c = t0[i], 0
            for _ in range(int(k[i])):
                t = t + dt[i]
                if t >= q1:
                    break
                if t >= q0:
                    c += 1
            total += c * int(b[i])
        return total

    def gap_samples(self, q0: float,
                    q1: float) -> Tuple[np.ndarray, np.ndarray]:
        """Time-between-tokens samples for boundaries in [q0, q1), in
        run-length form: (iteration gaps, token weights).  Each run
        contributes its ``dt`` weighted by the tokens whose boundary
        falls inside the window (``k * b`` for fully-covered runs;
        straddlers expand boundary-by-boundary like ``count``).  Feeds
        ``obs.weighted_percentiles`` for token-level TBT percentiles
        with zero per-token bookkeeping."""
        if not self._t0:
            return (np.empty(0, dtype=float),
                    np.empty(0, dtype=np.int64))
        t0, dt, k, b, ok, end = self._arrays()
        first = t0 + dt
        hit = (end >= q0) & (first < q1)
        full = hit & (first >= q0) & (end < q1)
        part_v: List[float] = []
        part_w: List[int] = []
        for i in np.nonzero(hit & ~full)[0]:
            t, c = t0[i], 0
            for _ in range(int(k[i])):
                t = t + dt[i]
                if t >= q1:
                    break
                if t >= q0:
                    c += 1
            if c:
                part_v.append(float(dt[i]))
                part_w.append(c * int(b[i]))
        vals = np.concatenate(
            [dt[full], np.asarray(part_v, dtype=float)])
        wts = np.concatenate(
            [(k[full] * b[full]).astype(np.int64),
             np.asarray(part_w, dtype=np.int64)])
        return vals, wts


class _ObsLog:
    """Append-only (time, prompt tokens, output tokens) event log with
    O(log n) window queries — the windowed observable feed for the
    control plane's demand estimator (repro.control.estimator).  Events
    may be appended out of time order (requests are submitted up
    front); the query-side arrays sort lazily and cache until the next
    append."""

    __slots__ = ("_t", "_p", "_o", "_np", "n_total", "prompt_total",
                 "output_total")

    def __init__(self):
        self._t: List[float] = []
        self._p: List[int] = []
        self._o: List[int] = []
        self._np = None
        self.n_total = 0
        self.prompt_total = 0
        self.output_total = 0

    def add(self, t: float, prompt: int, output: int):
        self._t.append(t)
        self._p.append(prompt)
        self._o.append(output)
        self.n_total += 1
        self.prompt_total += prompt
        self.output_total += output
        self._np = None

    def _arrays(self):
        if self._np is None:
            t = np.array(self._t)
            order = np.argsort(t, kind="stable")
            t = t[order]
            p = np.cumsum(np.array(self._p, dtype=float)[order])
            o = np.cumsum(np.array(self._o, dtype=float)[order])
            self._np = (t, p, o)
        return self._np

    def window(self, t0: float, t1: float) -> Tuple[int, float, float]:
        """(events, prompt tokens, output tokens) with time in [t0, t1)."""
        if not self._t:
            return 0, 0.0, 0.0
        t, cp, co = self._arrays()
        i0 = int(np.searchsorted(t, t0, side="left"))
        i1 = int(np.searchsorted(t, t1, side="left"))
        if i1 <= i0:
            return 0, 0.0, 0.0
        p0 = cp[i0 - 1] if i0 else 0.0
        o0 = co[i0 - 1] if i0 else 0.0
        return i1 - i0, float(cp[i1 - 1] - p0), float(co[i1 - 1] - o0)


class ModelObs:
    """Per-model control-plane observables: the request arrival stream
    (prompt lengths are visible at arrival; output lengths are the
    eventual commitment the estimator learns from finished requests)."""

    __slots__ = ("arrival",)

    def __init__(self):
        self.arrival = _ObsLog()


class _Span:
    """An in-flight batched stretch of decode iterations.

    ``segs`` is the piecewise-constant schedule: (off, k, b, dt, lat,
    ok) — k iterations at batch size b starting after ``off`` earlier
    span iterations.  ``bounds`` holds every iteration boundary,
    sequentially accumulated.  ``single`` marks a constant-batch span
    created with a non-empty admission queue (resident set pinned at
    capacity): each finisher is virtually backfilled from the queue
    (``adm`` logs the admission boundaries for the settle replay), so
    neither finishers nor joins — which can only queue — invalidate the
    schedule (``join_times`` feeds the EWMA replay).
    """
    __slots__ = ("gen", "start", "bounds", "segs", "single", "q0",
                 "adm", "join_times", "ecache")

    def __init__(self, gen, start, bounds, segs, single, q0, adm):
        self.gen = gen
        self.start = start
        self.bounds = bounds
        self.segs = segs
        self.single = single
        self.q0 = q0
        self.adm = adm          # sorted admission boundaries (iterations)
        self.join_times: List[float] = []
        # incremental EWMA replay state: (updates applied, value,
        # join_times index, admission index, current queue depth)
        self.ecache = (0, None, 0, 0, q0)

    def ok_upto(self, n: int) -> int:
        """SLO-meeting iterations among the span's first n."""
        good = 0
        for off, k_j, _b, _dt, _lat, ok in self.segs:
            c = min(n - off, k_j)
            if c <= 0:
                break
            if ok:
                good += c
        return good


class SimInstance:
    """One Serving Instance (prefill or decode role).

    Decode residents live in ``resident`` sorted by absolute finish
    iteration: entries are (finish_iter, req, join_iters, join_ok) with
    ``res_keys`` the parallel finish_iter list for bisecting.  The
    request's emitted count is ``iters - join_iters``; its token/SLO
    counters materialize from the cumulative ``iters``/``ok_iters``
    when it finishes or is re-routed.
    """

    def __init__(self, iid: int, region: str, template: ServingTemplate,
                 model: ServedModel, cm: InstanceCostModel, ready_at: float):
        self.iid = iid
        self.region = region
        self.template = template
        self.model = model
        self.cm = cm
        self.ready_at = ready_at
        self.draining = False
        self.dead = False
        self.failed = False     # crashed but not yet health-check detected
        self.slow_factor = 1.0  # straggler: iteration times scale by this
        self._degrade_gen = 0   # cancels stale straggler-recovery events
        self.busy = False
        self.queue: Deque[Request] = deque()    # prefill / decode admission
        self.resident: List[Tuple[int, Request, int, int]] = []
        self.res_keys: List[int] = []           # finish iters, sorted
        self.iters = 0                          # settled decode iterations
        self.ok_iters = 0                       # ... of which met the SLO
        self.ewma_load = 0.0
        self.tokens_out = 0                     # generated tokens served here
        self.span: Optional[_Span] = None       # batched decode state
        self._gen = 0                           # span generation counter
        self._spanlen = 8                       # adaptive span budget
        self._kavg = 8.0                        # EWMA of settled span length
        self._quiet = 0                         # join-free iteration streak
        self._joined = False                    # a join landed mid-iteration
        self._dtc: Dict[int, Tuple[float, float]] = {}  # b -> (iter, lat)

    @property
    def phase(self) -> str:
        return self.template.phase

    def idle(self) -> bool:
        return not self.queue and not self.resident and not self.busy


class Simulator:
    def __init__(self, models: Dict[str, ServedModel],
                 config_by_name: Dict[str, NodeConfig],
                 workloads: Dict[str, "WorkloadStats"],
                 batched: bool = True,
                 reqlog: bool = True):
        self.models = models
        self.configs = config_by_name
        self.workloads = workloads
        self.batched = batched
        self.ev = EventQueue()
        self.now = 0.0
        self.horizon = float("inf")
        self._iid = itertools.count()
        self.instances: Dict[int, SimInstance] = {}
        self._by_pool: Dict[Tuple[str, str], List[SimInstance]] = {}
        self.tokens: Dict[str, TokenRuns] = {m: TokenRuns() for m in models}
        self.obs: Dict[str, ModelObs] = {m: ModelObs() for m in models}
        # per-request lifecycle records (observation-only; on by
        # default, the sim_loop bench gates its overhead below 5%)
        self.reqlog: Optional[RequestLog] = \
            RequestLog(models) if reqlog else None
        self.finished: List[Request] = []
        self.dropped: int = 0
        self.shed_policy: Optional[ShedPolicy] = None
        self.shed: int = 0                      # cumulative shed arrivals
        self.shed_by_model: Dict[str, int] = {m: 0 for m in models}
        self.dropped_by_model: Dict[str, int] = {m: 0 for m in models}
        # CORAL_SANITIZE=1: runtime invariant checks (repro.debug)
        self._san = _inv.SimSanitizer() if _inv.sanitize_enabled() else None
        # router knows per-node degradation (health telemetry); the
        # naive runtime of benchmarks/fault_bench.py turns this off
        self.straggler_aware = True

    # ------------------------------------------------------------ cluster
    def add_instance(self, region: str, template: ServingTemplate,
                     ready_delay: float = INIT_DELAY_S,
                     cm: Optional[object] = None) -> SimInstance:
        """cm: override the cost model (e.g. a profiling-fitted one for the
        simulator-fidelity study, §6.2)."""
        model = self.models[template.model]
        if cm is None:
            cm = InstanceCostModel(model, template.phase, template.placement,
                                   self.configs,
                                   self.workloads[template.model])
        inst = SimInstance(next(self._iid), region, template, model, cm,
                           self.now + ready_delay)
        self.instances[inst.iid] = inst
        self._by_pool.setdefault((template.model, template.phase),
                                 []).append(inst)
        return inst

    def drain_instance(self, inst: SimInstance):
        inst.draining = True

    def kill_instance(self, inst: SimInstance):
        """Node failure: settle any in-flight batched accounting up to
        ``now`` (the in-flight partial iteration yields nothing, as in
        the per-iteration loop where the cleared resident set makes its
        pending event a no-op), mark the instance dead and re-route its
        work — decode requests (already prefilled) via ``_join_decode``
        whether resident *or* queued for admission, prefill requests
        back through ``_on_arrival``.
        """
        if inst.dead:
            return
        sp = inst.span
        if sp is not None:
            n = min(bisect_right(sp.bounds, self.now), len(sp.bounds) - 1)
            self._settle_runs(inst, sp, n)
            inst._gen += 1
            inst.span = None
        inst.dead = True        # a prefill batch in flight is cancelled
        # by the dead-check in _prefill_done and re-routed there
        self._pool_remove(inst)
        res, q = inst.resident, inst.queue
        inst.resident = []
        inst.res_keys = []
        inst.queue = deque()
        if inst.phase == "decode":
            for _f, req, j_it, j_ok in res:
                # partial credit for tokens generated here before the
                # failure (the per-iteration loop counted them live)
                req.decode_tokens_ok += inst.iters - j_it
                req.decode_slo_ok += inst.ok_iters - j_ok
                self.ev.push(self.now, self._join_decode, inst, req)
            for req in q:
                self.ev.push(self.now, self._join_decode, inst, req)
        else:
            for req in q:
                self.ev.push(self.now, self._on_arrival, req)

    def crash_instance(self, inst: SimInstance,
                       detect_s: float = 0.0) -> float:
        """Node failure with health-check detection latency.  The node
        stops serving immediately — in-flight batched accounting is
        settled exactly as in ``kill_instance`` — but the coordinator
        does not know yet: the instance stays in its routing pool,
        black-holing routed requests into its queue, until the health
        probe fires ``detect_s`` later and ``kill_instance`` re-routes
        everything it accumulated.  ``detect_s <= 0`` is instant
        detection (identical to ``kill_instance``).  Returns the
        detection time."""
        if inst.dead or inst.failed:
            return self.now
        if detect_s <= 0.0:
            self.kill_instance(inst)
            return self.now
        sp = inst.span
        if sp is not None:
            # settle like _interrupt_span, but the in-flight iteration
            # is lost instead of converted: the crashed node never
            # completes it (the oracle's pending event no-ops on the
            # failed flag).  The EWMA still advances through its start,
            # since the per-iteration loop updated it there.
            n = min(bisect_right(sp.bounds, self.now), len(sp.bounds) - 1)
            inst.ewma_load = self._ewma_replay(inst, sp, n + 1)
            self._settle_runs(inst, sp, n)
            inst._gen += 1
            inst.span = None
        inst.failed = True
        inst.busy = False
        t = self.now + detect_s
        self.ev.push(t, self.kill_instance, inst)
        return t

    def degrade_instance(self, inst: SimInstance, factor: float,
                         duration_s: Optional[float] = None):
        """Straggler injection: scale the instance's iteration and
        pipeline times by ``factor`` (>= 1) starting with the *next*
        iteration — the in-flight one keeps the timing it was started
        with, in both the batched and the per-iteration loop.  With
        ``duration_s`` the node recovers to full speed that much later;
        ``factor=1.0`` restores it immediately."""
        if inst.dead or inst.failed:
            return
        factor = max(float(factor), 1.0)
        if factor != inst.slow_factor and inst.span is not None:
            self._interrupt_span(inst)
        inst.slow_factor = factor
        inst._dtc.clear()
        inst._degrade_gen += 1
        if duration_s is not None and factor != 1.0:
            self.ev.push(self.now + duration_s, self._restore_speed,
                         inst, inst._degrade_gen)

    def _restore_speed(self, inst: SimInstance, gen: int):
        if gen != inst._degrade_gen or inst.dead:
            return          # superseded by a newer degrade, or gone
        self.degrade_instance(inst, 1.0)

    def _pool_remove(self, inst: SimInstance):
        """Evict a dead instance from its routing pool so the router's
        per-request scan stays proportional to live instances."""
        pool = self._by_pool.get((inst.template.model, inst.phase))
        if pool is not None and inst in pool:
            pool.remove(inst)

    def _earliest_ready(self, model: str, phase: str) -> Optional[float]:
        """Earliest ready_at among still-initializing pool members."""
        cut = self.now + 1e-9
        best = None
        for i in self._by_pool.get((model, phase), ()):
            if not i.draining and not i.dead and not i.failed \
                    and i.ready_at > cut:
                if best is None or i.ready_at < best:
                    best = i.ready_at
        return best

    # ------------------------------------------------------------- router
    def _ewma_at(self, inst: SimInstance) -> float:
        """EWMA load as the per-iteration loop would see it *now*: a
        batched span applies its updates lazily, one per iteration
        started (n completed boundaries => n+1 started iterations), with
        queue-depth changes from logged joins replayed in order."""
        sp = inst.span
        if sp is None:
            return inst.ewma_load
        n = min(bisect_right(sp.bounds, self.now) + 1, len(sp.bounds))
        return self._ewma_replay(inst, sp, n)

    def _ewma_replay(self, inst: SimInstance, sp: _Span, n: int) -> float:
        """Value of the EWMA after the first ``n`` iteration starts of
        the span, replayed incrementally (update j at time ``start`` for
        j=0 else ``bounds[j-1]`` sees the queue depth at that instant:
        logged joins grow it, virtual admissions shrink it)."""
        done, e, ji, ai, q = sp.ecache
        if e is None:
            e = inst.ewma_load
        if n == done:
            return e
        if n < done:            # unreachable (n is monotone in time);
            done, e, ji, ai, q = 0, inst.ewma_load, 0, 0, sp.q0
        jt = sp.join_times
        adm = sp.adm
        if not jt and not adm and q == 0.0 and e == 0.0:
            sp.ecache = (n, 0.0, 0, 0, 0.0)
            return 0.0
        if not jt:
            # no logged joins: q is piecewise-constant between
            # admission boundaries — run tight constant-q stretches
            la = len(adm)
            j = done
            while j < n:
                while ai < la and adm[ai] <= j:
                    q -= 1.0
                    ai += 1
                nxt = adm[ai] if ai < la and adm[ai] < n else n
                for _ in range(j, nxt):
                    e = 0.9 * e + 0.1 * q
                j = nxt
        else:
            for j in range(done, n):
                t = sp.start if j == 0 else sp.bounds[j - 1]
                while ji < len(jt) and jt[ji] <= t:
                    q += 1.0
                    ji += 1
                while ai < len(adm) and adm[ai] <= j:
                    q -= 1.0
                    ai += 1
                e = 0.9 * e + 0.1 * q
        sp.ecache = (n, e, ji, ai, q)
        return e

    def _depth_at(self, inst: SimInstance) -> int:
        """Queue + resident depth as the per-iteration loop would see it
        now: residents whose finish boundary already passed inside an
        unsettled span no longer count."""
        d = len(inst.queue) + len(inst.resident)
        sp = inst.span
        if sp is not None:
            n = bisect_right(sp.bounds, self.now)
            if n:
                if sp.single:
                    # every mid-span finisher was backfilled: departures
                    # so far == admissions so far
                    d -= bisect_right(sp.adm, n)
                else:
                    d -= bisect_right(inst.res_keys, inst.iters + n)
        return d

    def route(self, model: str, phase: str) -> Optional[SimInstance]:
        # weighted selection: least (queue depth / weight) — weighted-RR
        # with EWMA straggler correction (DESIGN.md §8).  Inlined hot
        # loop: routing runs twice per request, so skip the pool-list
        # allocation and take the span-free fast path when possible.
        cut = self.now + 1e-9
        best = None
        best_load = 0.0
        for i in self._by_pool.get((model, phase), ()):
            if i.draining or i.dead or i.ready_at > cut:
                continue
            if i.span is None:
                depth = len(i.queue) + len(i.resident)
                e = i.ewma_load
            else:
                depth = self._depth_at(i)
                e = self._ewma_at(i)
            w = i.template.throughput / (1.0 + e)
            if i.slow_factor != 1.0 and self.straggler_aware:
                # node health telemetry: a straggler's effective
                # throughput is scaled down before the EWMA correction
                # even notices the queues growing
                w /= i.slow_factor
            ld = (depth + 1.0) / (w if w > 1e-9 else 1e-9)
            if best is None or ld < best_load:
                best, best_load = i, ld
        return best

    # ------------------------------------------------------------ arrival
    def submit(self, req: Request):
        ob = self.obs.get(req.model)
        if ob is not None:
            ob.arrival.add(req.arrival, req.prompt_len, req.output_len)
        self.ev.push(req.arrival, self._on_arrival, req)

    def _on_arrival(self, req: Request):
        # admission control applies to *fresh* arrivals only: a request
        # re-entering here (a cold-start hold flushed at ready_at, or a
        # killed prefill instance's re-routed queue) was admitted once
        # already and its arrival time lies in the past
        if self.shed_policy is not None \
                and req.arrival >= self.now - 1e-9 \
                and self._should_shed(req.model):
            self.shed += 1
            self.shed_by_model[req.model] += 1
            if self.reqlog is not None:
                self.reqlog.note_shed(req)
            return
        inst = self.route(req.model, "prefill")
        if inst is None:
            # cold start / pool re-initialization: hold the request and
            # flush it when an instance becomes ready instead of
            # dropping it (requests are lost only when no instance is
            # even initializing)
            t = self._earliest_ready(req.model, "prefill")
            if t is None:
                self.dropped += 1
                self.dropped_by_model[req.model] = \
                    self.dropped_by_model.get(req.model, 0) + 1
                if self.reqlog is not None:
                    self.reqlog.note_dropped(req)
            else:
                self.ev.push(t, self._on_arrival, req)
            return
        inst.queue.append(req)
        self._maybe_start(inst)

    def _should_shed(self, model: str) -> bool:
        bound = self.shed_policy.max_queue_per_instance
        cut = self.now + 1e-9
        n_live = backlog = 0
        for i in self._by_pool.get((model, "prefill"), ()):
            if i.dead or i.draining or i.ready_at > cut:
                # a still-initializing instance is cold start, not
                # overload: its held arrivals will be flushed at
                # ready_at, so they must not count against the drain
                # bound (nor the instance toward capacity)
                continue
            n_live += 1         # failed-but-undetected counts as live:
            backlog += len(i.queue)     # its stuck queue IS the backlog
        return n_live > 0 and backlog > bound * n_live

    # ------------------------------------------------------------ prefill
    def _maybe_start(self, inst: SimInstance):
        if inst.busy or inst.dead or inst.failed \
                or self.now < inst.ready_at:
            if not inst.busy and not inst.dead and not inst.failed \
                    and self.now < inst.ready_at \
                    and (inst.queue or inst.resident):
                self.ev.push(inst.ready_at, self._maybe_start, inst)
            return
        if inst.phase == "prefill" and inst.queue:
            batch, tokens = [], 0
            chunk = inst.cm.prefill_chunk
            while inst.queue and tokens < chunk:
                r = inst.queue.popleft()
                batch.append(r)
                tokens += r.prompt_len
            # successive iterations pipeline across stages: the instance
            # re-admits after the bottleneck-stage time, while the batch
            # completes after the full pipeline traversal.
            free = inst.cm.prefill_iter_time(tokens)
            done = inst.cm.prefill_pipeline_latency(tokens)
            if inst.slow_factor != 1.0:
                free *= inst.slow_factor
                done *= inst.slow_factor
            inst.busy = True
            inst.ewma_load = 0.9 * inst.ewma_load + 0.1 * len(inst.queue)
            self.ev.push(self.now + free, self._free, inst)
            self.ev.push(self.now + done, self._prefill_done, inst, batch)
        elif inst.phase == "decode" and (inst.resident or inst.queue):
            self._start_decode(inst)

    def _free(self, inst: SimInstance):
        inst.busy = False
        self._maybe_start(inst)

    def _prefill_done(self, inst: SimInstance, batch: List[Request]):
        if inst.dead:
            # the node failed mid-batch: nothing was produced — the
            # batch re-enters the router (prefill runs again elsewhere;
            # no latency was recorded for the lost pass)
            for r in batch:
                self.ev.push(self.now, self._on_arrival, r)
            return
        if inst.failed:
            # crashed but not yet detected: the batch is lost in place.
            # Its requests rejoin the stuck queue until the health
            # probe fires and kill_instance re-routes them.
            inst.queue.extendleft(reversed(batch))
            return
        rl = self.reqlog
        for r in batch:
            r.prefill_done = self.now
            if rl is not None:
                # first token lands at prefill completion (TTFT)
                rl.note_first(r.model, r.rid, r.arrival, self.now)
            # KV transfer to a decode instance
            dst = self.route(r.model, "decode")
            delay = inst.cm.kv_transfer_time(r.prompt_len)
            if dst is None:
                t = self._earliest_ready(r.model, "decode")
                if t is None:
                    self.dropped += 1
                    self.dropped_by_model[r.model] = \
                        self.dropped_by_model.get(r.model, 0) + 1
                    if rl is not None:
                        rl.note_dropped(r)
                else:           # decode pool still initializing: hold
                    self.ev.push(max(t, self.now + delay),
                                 self._dispatch_decode, r)
                continue
            self.ev.push(self.now + delay, self._join_decode, dst, r)

    # ------------------------------------------------------------- decode
    def _decode_times(self, inst: SimInstance, b: int) -> Tuple[float, float]:
        """(iteration time, pipeline latency) for batch b, memoized per
        instance; tolerates duck-typed cost models without the combined
        ``decode_times`` API (e.g. the fitted model of fig6)."""
        c = inst._dtc.get(b)
        if c is None:
            cm = inst.cm
            if hasattr(cm, "decode_times"):
                c = cm.decode_times(b)
            else:
                c = (cm.decode_iter_time(b), cm.decode_pipeline_latency(b))
            if inst.slow_factor != 1.0:
                # straggler: both the iteration time and the perceived
                # latency inflate, so a degraded node can fall out of
                # SLO (the memo is cleared whenever the factor changes)
                c = (c[0] * inst.slow_factor, c[1] * inst.slow_factor)
            inst._dtc[b] = c
        return c

    def _res_add(self, inst: SimInstance, req: Request):
        """Insert a request into the finish-iteration-sorted residents."""
        f = inst.iters + req.output_len
        i = bisect_right(inst.res_keys, f)
        inst.res_keys.insert(i, f)
        inst.resident.insert(i, (f, req, inst.iters, inst.ok_iters))

    def _start_decode(self, inst: SimInstance):
        cap = inst.cm.decode_capacity
        while inst.queue and len(inst.resident) < cap:
            self._res_add(inst, inst.queue.popleft())
        b = len(inst.resident)
        if b == 0:
            return
        # Per-iteration scheduling: always in oracle mode, and in
        # batched mode when the instance's queue is empty (no
        # join-proof constant-batch span possible) AND recent history
        # says a join lands every couple of iterations — there a span
        # would be built only to be interrupted, costing more than the
        # heap events it removes.  A streak of join-free iterations
        # (or a risen settle average) re-enters span mode.
        if not self.batched or \
                (not inst.queue and inst._kavg < 3.0 and inst._quiet < 4):
            dt, lat = self._decode_times(inst, b)
            inst.busy = True
            # EWMA straggler feedback on *decode* iterations too (the
            # seed only updated it for prefill, leaving the router's
            # correction dead for decode pools)
            inst.ewma_load = 0.9 * inst.ewma_load + 0.1 * len(inst.queue)
            self.ev.push(self.now + dt, self._decode_done, inst, lat,
                         self.now, dt)
            return
        self._build_span(inst)

    def _build_span(self, inst: SimInstance):
        """Schedule a batched span from the current resident set.

        Queue empty: the resident set evolves deterministically until
        the instance drains — segment the schedule at each distinct
        finish iteration (batch size steps down as requests finish), up
        to the adaptive span budget (a KV join would invalidate the
        schedule, so interrupt-heavy instances keep spans short).
        Queue non-empty (resident set pinned at capacity): a
        constant-batch span — every finisher is backfilled from the
        queue at its boundary, so the batch size, iteration time and
        SLO verdict never change; the walk below merges resident and
        admitted finish offsets to find where the queue runs dry (the
        first unfilled departure ends the span).  Joins cannot break a
        constant-batch span: they land in the queue, only extending its
        validity.  Either way the span is capped at the run horizon so
        epoch metrics never miss settled tokens.
        """
        keys = inst.res_keys
        n_res = len(keys)
        iters0 = inst.iters
        single = bool(inst.queue)
        slo = inst.model.decode_slo_ms / 1e3
        horizon = self.horizon
        bounds: List[float] = []
        segs: List[Tuple[int, int, int, float, float, bool]] = []
        t = self.now
        adm: List[int] = []
        if single:
            # constant-batch walk over merged finish offsets
            dt, lat = self._decode_times(inst, n_res)
            ok = lat <= slo
            queue = inst.queue
            m0 = len(queue)
            adm_fins: List[int] = []            # admitted finish offsets
            ri = qi = 0
            while True:
                o = keys[ri] - iters0 if ri < n_res else None
                if adm_fins and (o is None or adm_fins[0] < o):
                    o = heapq.heappop(adm_fins)
                else:
                    ri += 1
                if o >= SPAN_MAX:
                    k_end = SPAN_MAX
                    break
                if qi >= m0:
                    k_end = o           # departure with a dry queue:
                    break               # the batch shrinks after this
                adm.append(o)
                heapq.heappush(adm_fins, o + queue[qi].output_len)
                qi += 1
            # C-speed sequential accumulation — bit-identical to the
            # oracle's repeated `t += dt`
            bounds = list(islice(accumulate(repeat(dt, k_end),
                                            initial=t), 1, None))
            cut = bisect_right(bounds, horizon)
            if cut < k_end:
                del bounds[max(cut, 1):]
            segs.append((0, len(bounds), n_res, dt, lat, ok))
        else:
            # adaptive budget tracking the observed settle distance:
            # interrupt-heavy instances schedule short spans (building
            # a long schedule per KV join costs more than it saves),
            # quietly draining ones grow geometrically
            cap_iters = inst._spanlen
            # distinct finish offsets = segment ends, capped
            targets: List[int] = []
            i = 0
            while i < n_res:
                L = keys[i] - iters0
                if L >= cap_iters:
                    targets.append(cap_iters)
                    break
                targets.append(L)
                i = bisect_right(keys, keys[i])
            off = 0
            for L in targets:
                b_j = n_res - bisect_right(keys, iters0 + off)
                dt, lat = self._decode_times(inst, b_j)
                ok = lat <= slo
                seg = list(islice(accumulate(repeat(dt, L - off),
                                             initial=t), 1, None))
                cut = bisect_right(seg, horizon)
                capped = cut < len(seg)
                if capped and cut == 0 and not bounds:
                    cut = 1             # always schedule >= 1 iteration
                if cut:
                    bounds.extend(seg[:cut])
                    t = bounds[-1]
                    segs.append((off, cut, b_j, dt, lat, ok))
                    off += cut
                if capped:
                    break
        inst._gen += 1
        inst.span = _Span(inst._gen, self.now, bounds, segs, single,
                          float(len(inst.queue)), adm)
        inst.busy = True
        self.ev.push(bounds[-1], self._span_done, inst, inst._gen)

    def _settle_runs(self, inst: SimInstance, sp: _Span, n: int):
        """Account the first n iterations of a span: one TokenRuns
        record per (partially) covered segment, pop finishers off the
        sorted residents (finish stamped at the exact boundary,
        counters materialized from the cumulative iteration counters);
        everything still resident is untouched."""
        if n <= 0:
            return
        if self._san is not None:
            self._san.check_settle(self, inst, sp, n)
        bounds = sp.bounds
        runs = self.tokens[inst.template.model]
        ok_gain = 0
        for off, k_j, b_j, dt, _lat, ok in sp.segs:
            s_j = min(n - off, k_j)
            if s_j <= 0:
                break
            t0 = bounds[off - 1] if off else sp.start
            runs.add(t0, dt, s_j, b_j, ok, bounds[off + s_j - 1])
            inst.tokens_out += s_j * b_j
            if ok:
                ok_gain += s_j
        iters0 = inst.iters
        cut = iters0 + n
        for o in sp.adm:
            # replay the virtual admissions of a constant-batch span:
            # each backfills the finisher departing at boundary o
            if o > n:
                break
            req = inst.queue.popleft()
            f = iters0 + o + req.output_len
            i = bisect_right(inst.res_keys, f)
            inst.res_keys.insert(i, f)
            inst.resident.insert(
                i, (f, req, iters0 + o, inst.ok_iters + sp.ok_upto(o)))
        self._pop_finishers(
            inst, cut,
            lambda f: bounds[f - iters0 - 1],
            lambda f: inst.ok_iters + sp.ok_upto(f - iters0))
        inst.iters = cut
        inst.ok_iters += ok_gain

    def _pop_finishers(self, inst: SimInstance, cut: int, finish_at,
                       ok_at):
        """Pop residents whose finish iteration is <= ``cut`` and
        materialize their counters from the cumulative per-instance
        iteration counters — the single place both the batched settle
        and the per-iteration oracle credit finished requests, keeping
        their accounting in lockstep.  ``finish_at(f)``/``ok_at(f)``
        supply the timestamp and cumulative ok-iteration count at
        finish iteration ``f``."""
        i = bisect_right(inst.res_keys, cut)
        if i:
            rl = self.reqlog
            fin = None if rl is None \
                else rl.finished_sink(inst.model.name)
            for f, req, j_it, j_ok in inst.resident[:i]:
                req.finish = finish_at(f)
                req.decode_tokens_ok += f - j_it
                req.decode_slo_ok += ok_at(f) - j_ok
                self.finished.append(req)
                if fin is not None:
                    fin.append(req)
            del inst.resident[:i]
            del inst.res_keys[:i]

    def _interrupt_span(self, inst: SimInstance):
        """A join arrived mid-span and changes the schedule: settle the
        boundaries that already passed and convert the in-flight
        iteration into a per-iteration event (same batch/latency it was
        started with), exactly as the reference loop would run it."""
        sp = inst.span
        n = min(bisect_right(sp.bounds, self.now), len(sp.bounds) - 1)
        inst.ewma_load = self._ewma_replay(inst, sp, n + 1)
        self._settle_runs(inst, sp, n)
        # locate the in-flight iteration's segment for its batch timing
        lat = dt = None
        for off, k_j, _b, dt_j, lat_j, _ok in sp.segs:
            if off <= n < off + k_j:
                dt, lat = dt_j, lat_j
                break
        start = sp.bounds[n - 1] if n > 0 else sp.start
        inst._gen += 1
        inst.span = None
        self._adapt_spanlen(inst, n)
        self.ev.push(sp.bounds[n], self._decode_done, inst, lat, start, dt)
        # inst.busy stays True until that event fires

    @staticmethod
    def _adapt_spanlen(inst: SimInstance, settled: int):
        """Track the observed settle distance so the next span buys
        about as many iterations as interrupts allow it to keep."""
        inst._kavg = a = 0.7 * inst._kavg + 0.3 * settled
        s = int(1.5 * a) + 1
        inst._spanlen = s if s < SPAN_MAX else SPAN_MAX

    def _span_done(self, inst: SimInstance, gen: int):
        sp = inst.span
        if inst.dead or sp is None or sp.gen != gen:
            return                              # superseded / failed
        inst.ewma_load = self._ewma_replay(inst, sp, len(sp.bounds))
        self._settle_runs(inst, sp, len(sp.bounds))
        inst.span = None
        inst.busy = False
        if not sp.single:       # constant-batch spans are join-proof;
            # only queue-empty spans inform the interrupt-risk budget
            self._adapt_spanlen(inst, len(sp.bounds))
        self._after_decode_iter(inst)

    def _dispatch_decode(self, req: Request):
        """Route a prefilled request into the decode pool, holding it
        while the pool is (re-)initializing."""
        dst = self.route(req.model, "decode")
        if dst is not None:
            self._join_decode(dst, req)
            return
        t = self._earliest_ready(req.model, "decode")
        if t is None:
            self.dropped += 1
            self.dropped_by_model[req.model] = \
                self.dropped_by_model.get(req.model, 0) + 1
            if self.reqlog is not None:
                self.reqlog.note_dropped(req)
        else:
            self.ev.push(t, self._dispatch_decode, req)

    def _join_decode(self, inst: SimInstance, req: Request):
        if inst.dead:
            self._dispatch_decode(req)
            return
        if inst.failed:
            # the router still believes this node is alive: the request
            # is stuck in its queue until the health probe fires and
            # kill_instance re-routes it
            inst.queue.append(req)
            return
        inst._joined = True
        sp = inst.span
        if sp is not None:
            if sp.single and len(inst.resident) >= inst.cm.decode_capacity:
                # resident set is pinned at capacity until the span's
                # finisher: queueing cannot change the running batch, so
                # the span stays valid — just log the depth change for
                # the EWMA replay
                inst.queue.append(req)
                sp.join_times.append(self.now)
                return
            self._interrupt_span(inst)
        if len(inst.resident) < inst.cm.decode_capacity:
            self._res_add(inst, req)
        else:
            inst.queue.append(req)      # SLO-aware admission control
        self._maybe_start(inst)

    def _decode_done(self, inst: SimInstance, lat: float, start: float,
                     dt: float):
        if inst.failed:
            return      # crashed mid-iteration: the work is lost
        inst.busy = False
        slo = inst.model.decode_slo_ms / 1e3
        ok = lat <= slo
        b = len(inst.resident)
        if b:
            self.tokens[inst.template.model].add(start, dt, 1, b, ok,
                                                 self.now)
            inst.tokens_out += b
            inst.iters += 1
            if ok:
                inst.ok_iters += 1
            now = self.now
            self._pop_finishers(inst, inst.iters,
                                lambda _f: now,
                                lambda _f: inst.ok_iters)
        if inst._joined:
            inst._quiet = 0
            inst._joined = False
        else:
            inst._quiet += 1
        self._after_decode_iter(inst)

    def _after_decode_iter(self, inst: SimInstance):
        # admit pending requests up to the SLO/memory cap
        cap = inst.cm.decode_capacity
        while inst.queue and len(inst.resident) < cap:
            self._res_add(inst, inst.queue.popleft())
        if inst.draining and not inst.resident and not inst.queue:
            inst.dead = True
            self._pool_remove(inst)
        self._maybe_start(inst)

    # ---------------------------------------------------------------- run
    def run_until(self, t_end: float):
        self.horizon = t_end
        san = self._san
        while self.ev and self.ev._q[0][0] <= t_end:
            t, _, fn, args = self.ev.pop()
            if san is not None:
                san.note_pop(t, self.now)
            self.now = max(self.now, t)
            fn(*args)
        self.now = t_end
        if san is not None:
            san.check_sim(self)

    def pool_backlog(self, model: str, phase: str) -> Tuple[int, int]:
        """Queue snapshot over a pool's live instances: (queued requests,
        queued prompt tokens).  Resident decode requests are in-flight
        work, not backlog, and are excluded."""
        n = ptok = 0
        for i in self._by_pool.get((model, phase), ()):
            if i.dead or i.draining:
                continue
            n += len(i.queue)
            for r in i.queue:
                ptok += r.prompt_len
        return n, ptok

    # ------------------------------------------------------------ metrics
    def goodput(self, model: str, t0: float, t1: float) -> float:
        """Generated tokens/s within [t0, t1) meeting the decode SLO."""
        return self.tokens[model].count(t0, t1, ok_only=True) \
            / max(t1 - t0, 1e-9)

    def throughput(self, model: str, t0: float, t1: float) -> float:
        return self.tokens[model].count(t0, t1) / max(t1 - t0, 1e-9)
