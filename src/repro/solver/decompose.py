"""Per-model price-coordinated decomposition of the online allocation
ILP (paper §4.3; the "lossless two-stage" claim made operational).

The monolithic epoch model couples (model, phase) demand rows only
through the shared per-(region, config) availability rows and the
per-model shortfall fraction.  Relaxing availability with a price
vector λ ≥ 0 (Lagrangian) makes the epoch problem separable per model,
and each model's subproblem decomposes further — once the shortfall
fraction is pinned (see below) — into independent *single-row bounded
knapsack-cover* problems:

    min  Σ_j c̃_j v_j     s.t.  Σ_j t_j v_j >= T,   0 <= v_j <= u_j int

solved *exactly* by ``cover_bb``: a dependency-free branch-and-bound
whose node relaxation is the fractional greedy cover (a cumsum over
efficiency-sorted columns), with two structural accelerations:

* **Pareto column dominance** — a column is dropped when a
  cheaper-or-equal, faster-or-equal column has enough capacity to fully
  substitute for it (``u * t >= T``); this cuts the ~10^3 columns of a
  paper-scale row to a few dozen;
* **incumbent pruning** — a feasible warm start (the previous epoch's
  solution) bounds the search from node zero.

Initialization penalty (``I = K c (v - cur)+``) is folded exactly by
*column splitting*: each column with running instances becomes a
cheap slice (ub = cur, cost c) and a full-price slice
(ub = u - cur, cost c (1 + K)); the split model's optimum equals the
true model's because the cheap slice strictly dominates.

Shortfall handling is where discreteness bites: the per-model slack
``s_m`` (penalty ≈ 100x the worst $/tok/s) couples the model's rows,
and the provably-optimal *continuous* choice ``s̄ = max_d
(1 - cap_d/T_d)+`` can be beaten by up to ~1% of one instance's
coverage when shaving the last sliver of a row saves a whole instance.
``_solve_model`` therefore brackets the flex: rows are solved at
``s = s̄`` (the primal candidate) and once more at the window edge
``s_hi = s̄ + Z/pen`` (any larger s is dominated because the penalty
alone exceeds the total cover cost Z), giving a *valid* per-model dual
bound — tight whenever no row drops an instance inside the <=1% target
window, which is the common case.

The coordination loop (``solve_decomposed``):

  1. solve every model at λ = 0 — a pure relaxation, so Σ duals is a
     valid lower bound on the monolithic optimum;
  2. if the combined solution violates no availability row, the primal
     is feasible; certify when (primal - dual)/|dual| <= accept_gap;
  3. otherwise repair greedily (un-assign the lowest-value violators,
     most expensive first — the same discipline as the allocator's
     incumbent repair), take a subgradient step
     λ <- max(0, λ + θ (z_UB - L)/||g||² g) on the violated rows, and
     re-solve with priced costs c̃ = c + Aᵀλ;
  4. give up after ``max_iters`` (or on a node/time budget hit) and
     return the best feasible primal *uncertified* — the caller
     escalates (LP-round, then the monolithic MIP) with this solution
     as its warm start, so non-convergence costs time, never quality.

Everything here is plain numpy — no scipy dependency — so the
decomposed path works wherever the numpy branch-and-bound backend does.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

# per-row branch-and-bound budget: measured paper-scale rows close in
# <= ~1.5k nodes after Pareto reduction; the budget is a runaway guard,
# and a hit voids the certificate (never the correctness of the primal)
MAX_NODES_PER_ROW = 20000


# --------------------------------------------------------------- problem
@dataclass
class RowSpec:
    """One (model, phase) demand row, region-major over its columns."""
    cols: np.ndarray               # (n,) global v-var indices
    cost: np.ndarray               # (n,) base per-instance $/h
    thr: np.ndarray                # (n,) tokens/s per instance
    ub: np.ndarray                 # (n,) availability/demand cap
    cur: np.ndarray                # (n,) currently running instances
    target: float                  # demanded tokens/s


@dataclass
class ModelSpec:
    """A model's rows plus its shortfall penalty coefficient."""
    index: int                     # slack index m
    rows: List[RowSpec]
    pen: float                     # objective coeff of s_m


@dataclass
class DecomposeProblem:
    """Arrays mirroring one ``AllocatorState`` epoch (see
    ``AllocatorState._decompose_problem``)."""
    n_vars: int
    models: List[ModelSpec]
    init_k: float
    # availability rows as COO over the v-vars + RHS
    av_data: np.ndarray
    av_rows: np.ndarray
    av_cols: np.ndarray
    b: np.ndarray

    def __post_init__(self):
        # CSR-ish layout for usage folds and per-row repair scans
        order = np.argsort(self.av_rows, kind="stable")
        self._od = self.av_data[order]
        self._or = self.av_rows[order]
        self._oc = self.av_cols[order]
        self._indptr = np.searchsorted(self._or, np.arange(len(self.b) + 1))

    def usage(self, v: np.ndarray) -> np.ndarray:
        out = np.zeros(len(self.b))
        np.add.at(out, self.av_rows, self.av_data * v[self.av_cols])
        return out

    def priced_costs(self, lam: np.ndarray) -> np.ndarray:
        """Per-v-var cost increment Aᵀλ."""
        out = np.zeros(self.n_vars)
        np.add.at(out, self.av_cols, self.av_data * lam[self.av_rows])
        return out


@dataclass
class DecomposeResult:
    ok: bool                       # a feasible primal exists
    certified: bool                # primal within accept_gap of the dual
    v: Optional[np.ndarray]        # (n_vars,) integer counts
    s: Optional[np.ndarray]        # per-model shortfall fractions
    objective: float = np.inf      # honest primal objective
    dual_bound: float = -np.inf    # best valid Lagrangian bound
    gap: float = np.inf
    iters: int = 0
    nodes: int = 0
    seconds: float = 0.0
    reason: str = ""               # certified/gap/budget/deadline/infeasible


# ----------------------------------------------------- single-row solver
def pareto_keep(cost: np.ndarray, thr: np.ndarray, ub: np.ndarray,
                target: float) -> np.ndarray:
    """Boolean mask of columns that can appear in *some* optimal cover.

    A column is dominated when a cheaper-or-equal column with >=
    throughput can substitute for every unit of it; substitution is
    only safe when the dominator alone could cover the whole target
    (``u * t >= target``), because a saturated dominator already proves
    the dominated column's units redundant.
    """
    n = len(cost)
    keep = np.ones(n, dtype=bool)
    order = np.lexsort((-thr, cost))       # cost asc, throughput desc
    best_t = -np.inf
    for j in order:
        if thr[j] <= best_t:
            keep[j] = False
        elif ub[j] * thr[j] >= target - 1e-9:
            best_t = thr[j]
    return keep


def cover_bb(cost: np.ndarray, thr: np.ndarray, ub: np.ndarray,
             target: float, incumbent: Optional[np.ndarray] = None,
             rel_gap: float = 1e-9, max_nodes: int = MAX_NODES_PER_ROW,
             deadline: Optional[float] = None
             ) -> Tuple[Optional[np.ndarray], float, float, int, bool]:
    """Exact bounded knapsack-cover:  min c·v, t·v >= target, 0<=v<=u int.

    Returns ``(v, obj, dual, nodes, complete)``.  ``dual`` is a valid
    lower bound on the row optimum (= obj - rel_gap·|obj| when the
    search completed, -inf when a budget/deadline hit voided it).  A
    target beyond total capacity is clipped to it by the caller (the
    shortfall fraction absorbs the remainder), so feasibility here
    means ``sum(t·u) >= target``.
    """
    n = len(cost)
    if target <= 1e-9:
        return np.zeros(n), 0.0, 0.0, 0, True
    live = (ub > 0) & (thr > 1e-12)
    keep = np.zeros(n, dtype=bool)
    keep[live] = pareto_keep(cost[live], thr[live], ub[live], target)
    idx = np.nonzero(keep)[0]
    if not len(idx):
        return None, np.inf, -np.inf, 0, True
    c, t, u = cost[idx], thr[idx], ub[idx].astype(float)
    order = np.argsort(c / t, kind="stable")
    cs, ts, us = c[order], t[order], u[order]
    best, best_buy = np.inf, None
    if incumbent is not None:
        xi = np.minimum(incumbent[idx][order].astype(float), us)
        if ts @ xi >= target - 1e-9:
            best = float(cs @ xi)
            best_buy = {int(j): float(q)
                        for j, q in enumerate(xi) if q > 0}
    m_cols = len(cs)
    # DFS node: (committed cost, residual target, ub overrides, buys);
    # diving the ceil child first reaches feasible leaves near the
    # greedy-ceil solution immediately, so pruning starts early
    stack = [(0.0, float(target), {}, {})]
    nodes, complete = 0, True
    while stack:
        nodes += 1
        if nodes > max_nodes or (
                # corallint: disable=D1 - node-budget deadline only
                deadline is not None and time.time() > deadline):
            complete = False
            break
        cost0, resid, ovr, buy = stack.pop()
        tol = max(rel_gap * abs(best), 1e-12) if np.isfinite(best) else 0.0
        if cost0 >= best - tol:
            continue
        if resid <= 1e-9:
            best, best_buy = cost0, buy
            continue
        eub = us if not ovr else us.copy()
        for j, q in ovr.items():
            eub[j] = q
        cap = ts * eub
        cum = np.cumsum(cap)
        k = int(np.searchsorted(cum, resid - 1e-12))
        if k >= m_cols:
            continue                       # cannot cover the residual
        prev = cum[k - 1] if k else 0.0
        x_lp = (resid - prev) / ts[k]
        ccost = np.cumsum(cs * eub)
        lp = cost0 + (ccost[k - 1] if k else 0.0) + x_lp * cs[k]
        if lp >= best - tol:
            continue
        if abs(x_lp - round(x_lp)) < 1e-9:
            nb = dict(buy)
            for j in range(k):
                if eub[j] > 0:
                    nb[j] = nb.get(j, 0.0) + float(eub[j])
            q = float(round(x_lp))
            if q > 0:
                nb[k] = nb.get(k, 0.0) + q
            best, best_buy = lp, nb
            continue
        up, dn = float(np.ceil(x_lp)), float(np.floor(x_lp))
        o2 = dict(ovr)
        o2[k] = dn
        stack.append((cost0, resid, o2, buy))        # v_k <= floor
        o1 = dict(ovr)
        o1[k] = float(eub[k]) - up
        b1 = dict(buy)
        b1[k] = b1.get(k, 0.0) + up
        stack.append((cost0 + up * cs[k], resid - up * ts[k], o1, b1))
    if best_buy is None:
        return None, np.inf, -np.inf, nodes, complete
    v = np.zeros(n)
    gidx = idx[order]
    for j, q in best_buy.items():
        v[gidx[j]] += q
    dual = best - max(rel_gap * abs(best), 1e-12) if complete else -np.inf
    return v, float(best), dual, nodes, complete


# --------------------------------------------------- per-model subproblem
def _split_row(row: RowSpec, k: float, lam_add: np.ndarray):
    """Exact init-penalty reformulation: columns with running instances
    split into a protected slice (no init charge) and a full-price
    slice; ``lam_add`` is the availability price Aᵀλ of each column."""
    lo_ub = np.minimum(row.cur, row.ub)
    add = lam_add[row.cols]
    cost = np.concatenate([row.cost + add, row.cost * (1.0 + k) + add])
    thr = np.concatenate([row.thr, row.thr])
    ub = np.concatenate([lo_ub, row.ub - lo_ub])
    return cost, thr, ub


def _merge_split(x: np.ndarray, n: int) -> np.ndarray:
    return x[:n] + x[n:]


def _solve_model(ms: ModelSpec, k: float, lam_add: np.ndarray,
                 prev_v: Optional[np.ndarray], rel_gap: float,
                 deadline: Optional[float]):
    """Exact subproblem at prices λ: returns ``(v, s, z_primal, L_m,
    nodes, complete)`` with ``L_m`` a valid lower bound on the priced
    subproblem optimum (see the module docstring's s-window argument)."""
    caps = np.array([float(r.thr @ r.ub) for r in ms.rows])
    tgts = np.array([r.target for r in ms.rows])
    with np.errstate(divide="ignore", invalid="ignore"):
        s_bar = float(np.max(np.where(
            tgts > 1e-12, np.maximum(0.0, 1.0 - caps / tgts), 0.0),
            initial=0.0))
    nodes, complete = 0, True
    covers, duals, Z = [], 0.0, 0.0
    for ri, r in enumerate(ms.rows):
        cost, thr, ub = _split_row(r, k, lam_add)
        inc = None
        if prev_v is not None:
            pv = np.minimum(prev_v[r.cols], r.ub)
            lo = np.minimum(pv, np.minimum(r.cur, r.ub))
            inc = np.concatenate([lo, pv - lo])
        # clip to capacity: s̄ makes the reduced target feasible by
        # construction, but float dust must not turn it infeasible
        x, z, dual, nd, comp = cover_bb(
            cost, thr, ub, min(r.target * (1.0 - s_bar), caps[ri]),
            incumbent=inc, rel_gap=rel_gap, deadline=deadline)
        nodes += nd
        complete &= comp and x is not None
        if x is None:
            covers.append(np.zeros(len(r.cols)))
            continue
        covers.append(_merge_split(x, len(r.cols)))
        duals += dual if comp else 0.0
        Z += z
    # s-flex window: any s above s_hi pays more penalty than the whole
    # cover costs, so re-solving each row at the window edge bounds the
    # subproblem from below across every admissible s
    L_m = ms.pen * s_bar + duals
    if complete and ms.pen > 1e-12 and Z > 1e-12:
        s_hi = min(1.0, s_bar + Z / ms.pen)
        if s_hi > s_bar + 1e-12:
            duals_lo = 0.0
            for ri, (r, cv) in enumerate(zip(ms.rows, covers)):
                cost, thr, ub = _split_row(r, k, lam_add)
                lo = np.minimum(cv, np.minimum(r.cur, r.ub))
                inc = np.concatenate([lo, cv - lo])
                _x, _z, dual, nd, comp = cover_bb(
                    cost, thr, ub,
                    min(r.target * (1.0 - s_hi), caps[ri]),
                    incumbent=inc, rel_gap=rel_gap, deadline=deadline)
                nodes += nd
                if not comp:
                    complete = False
                    break
                duals_lo += dual
            else:
                L_m = ms.pen * s_bar + duals_lo
    if not complete:
        L_m = -np.inf
    # honest primal at the (unpriced) true objective is assembled by
    # the caller; here we report the priced subproblem value
    z_primal = ms.pen * s_bar + Z
    return covers, s_bar, z_primal, L_m, nodes, complete


# ------------------------------------------------------------- repair
def _repair(dp: DecomposeProblem, v: np.ndarray,
            cost_of: np.ndarray) -> np.ndarray:
    """Greedy feasibility repair: for each violated availability row,
    un-assign the lowest-value (most expensive per instance) violators
    until holdings fit — the same discipline as ``AllocatorState``'s
    incumbent repair."""
    v = v.copy()
    usage = dp.usage(v)
    for i in np.nonzero(usage > dp.b + 1e-9)[0]:
        lo, hi = dp._indptr[i], dp._indptr[i + 1]
        cols = dp._oc[lo:hi]
        coef = dp._od[lo:hi]
        s = float(usage[i])
        for j in np.argsort(-cost_of[cols], kind="stable"):
            if s <= dp.b[i] + 1e-9:
                break
            cj = cols[j]
            if v[cj] <= 0:
                continue
            dec = min(v[cj], np.ceil((s - dp.b[i]) / coef[j]))
            v[cj] -= dec
            s -= dec * coef[j]
        usage = dp.usage(v)
    return v


def _honest(dp: DecomposeProblem, v: np.ndarray) -> Tuple[float, np.ndarray]:
    """True (unpriced) objective of integer counts ``v``: provisioning
    cost + init penalty + shortfall penalty, with each model's slack at
    its minimum feasible level for this v."""
    obj = 0.0
    s = np.zeros(len(dp.models))
    for ms in dp.models:
        worst = 0.0
        for r in ms.rows:
            x = v[r.cols]
            obj += float(r.cost @ x) \
                + dp.init_k * float(r.cost @ np.maximum(0.0, x - r.cur))
            if r.target > 1e-12:
                worst = max(worst, max(
                    0.0, 1.0 - float(r.thr @ x) / r.target))
        s[ms.index] = worst
        obj += ms.pen * worst
    return obj, s


# ------------------------------------------------------- coordination
def solve_decomposed(dp: DecomposeProblem,
                     prev_v: Optional[np.ndarray] = None,
                     accept_gap: float = 5e-4, max_iters: int = 6,
                     rel_gap: float = 1e-6, theta: float = 1.0,
                     time_limit: Optional[float] = None
                     ) -> DecomposeResult:
    """Price-coordination loop over the per-model subproblems."""
    # corallint: disable=D1 - solve deadline/telemetry only
    t0 = time.time()
    deadline = t0 + time_limit if time_limit is not None else None
    n_avail = len(dp.b)
    lam = np.zeros(n_avail)
    cost_of = np.zeros(dp.n_vars)
    for ms in dp.models:
        for r in ms.rows:
            cost_of[r.cols] = r.cost
    best_obj, best_v, best_s = np.inf, None, None
    best_dual = -np.inf
    nodes_total = 0
    reason = "gap"
    it = 0
    for it in range(1, max_iters + 1):
        # corallint: disable=D1 - solve deadline only
        if deadline is not None and time.time() > deadline:
            reason = "deadline"
            break
        lam_add = dp.priced_costs(lam) if lam.any() \
            else np.zeros(dp.n_vars)
        v = np.zeros(dp.n_vars)
        dual_it, complete_all = 0.0, True
        for ms in dp.models:
            covers, s_bar, _zp, L_m, nd, comp = _solve_model(
                ms, dp.init_k, lam_add, prev_v, rel_gap, deadline)
            nodes_total += nd
            complete_all &= comp
            for r, cv in zip(ms.rows, covers):
                v[r.cols] += cv
            if comp:
                dual_it += L_m
        if complete_all:
            dual_it -= float(lam @ dp.b)
            best_dual = max(best_dual, dual_it)
        usage = dp.usage(v)
        g = usage - dp.b
        feasible = bool(np.all(g <= 1e-9))
        v_try = v if feasible else _repair(dp, v, cost_of)
        obj, s = _honest(dp, v_try)
        if obj < best_obj:
            best_obj, best_v, best_s = obj, v_try, s
        if np.isfinite(best_obj) and best_dual > -np.inf:
            denom = max(abs(best_dual), 1e-9)
            if (best_obj - best_dual) / denom <= accept_gap:
                reason = "certified"
                break
        if feasible:
            # λ's subgradient points no further up: the dual cannot
            # improve from here, so a surviving gap is integrality —
            # escalation's job, not more iterations'
            reason = "gap" if complete_all else "budget"
            break
        step = theta * max(best_obj - dual_it, 1e-9) \
            / max(float(g @ g), 1e-12)
        lam = np.maximum(0.0, lam + step * g)
        prev_v = best_v if best_v is not None else prev_v
    certified = reason == "certified"
    gap = np.inf
    if np.isfinite(best_obj) and best_dual > -np.inf:
        gap = (best_obj - best_dual) / max(abs(best_dual), 1e-9)
    return DecomposeResult(
        ok=best_v is not None, certified=certified, v=best_v, s=best_s,
        objective=best_obj, dual_bound=best_dual, gap=gap, iters=it,
        # corallint: disable=D1 - telemetry only
        nodes=nodes_total, seconds=time.time() - t0,
        reason=reason if best_v is not None else "infeasible")
