"""Generic MILP substrate.

A tiny modeling API (variables / linear constraints / objective) with two
backends:
  * scipy.optimize.milp (HiGHS) — default, exact, scales to the online
    allocator's ~10^5-variable instances;
  * a pure-numpy branch-and-bound over a dense-simplex LP relaxation —
    dependency-free fallback for small problems, cross-checked against
    HiGHS in tests/test_solver.py.

Model construction comes in two granularities:
  * per-var (``add_var`` / ``add_constr``) — one Python call per
    variable/row; kept for the baselines and small models;
  * batched (``add_vars`` / ``add_constrs_coo``) — whole blocks of
    variables and COO constraint triplets appended at once.  ``solve``
    hands the accumulated triplets straight to ``scipy.sparse`` without
    ever materializing per-row dicts, which is what lets the columnar
    allocator (repro.core.allocator.AllocatorState) assemble
    ~10^5-variable models in milliseconds.  The numpy branch-and-bound
    backend densifies COO blocks into per-row dicts on demand, so both
    APIs solve on either backend.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

try:
    from scipy import sparse
    from scipy.optimize import Bounds, LinearConstraint, milp
    HAVE_SCIPY = True
except Exception:                                     # pragma: no cover
    HAVE_SCIPY = False


@dataclass
class MilpModel:
    """minimize c.x  s.t.  lb_i <= A_i.x <= ub_i, bounds, integrality."""
    obj: List[float] = field(default_factory=list)
    lb: List[float] = field(default_factory=list)
    ub: List[float] = field(default_factory=list)
    integer: List[bool] = field(default_factory=list)
    rows: List[Dict[int, float]] = field(default_factory=list)
    row_lb: List[float] = field(default_factory=list)
    row_ub: List[float] = field(default_factory=list)
    # COO constraint blocks: (data, global_row_idx, col_idx) triplets
    coo_blocks: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = field(
        default_factory=list)

    def add_var(self, obj: float = 0.0, lb: float = 0.0,
                ub: float = np.inf, integer: bool = False) -> int:
        self.obj.append(obj)
        self.lb.append(lb)
        self.ub.append(ub)
        self.integer.append(integer)
        return len(self.obj) - 1

    def add_vars(self, obj, lb=0.0, ub=np.inf, integer=False) -> np.ndarray:
        """Append a whole block of variables; returns their indices.

        ``obj``/``lb``/``ub``/``integer`` are scalars or 1-D arrays of a
        common length (scalars broadcast).
        """
        k = max((np.asarray(a).shape[0]
                 for a in (obj, lb, ub, integer)
                 if np.ndim(a) == 1), default=1)
        obj = np.broadcast_to(np.asarray(obj, dtype=float), (k,))
        lb = np.broadcast_to(np.asarray(lb, dtype=float), (k,))
        ub = np.broadcast_to(np.asarray(ub, dtype=float), (k,))
        integer = np.broadcast_to(np.asarray(integer, dtype=bool), (k,))
        start = len(self.obj)
        self.obj.extend(obj.tolist())
        self.lb.extend(lb.tolist())
        self.ub.extend(ub.tolist())
        self.integer.extend(integer.tolist())
        return np.arange(start, start + k)

    def add_constr(self, coeffs: Dict[int, float], lb: float = -np.inf,
                   ub: float = np.inf) -> int:
        self.rows.append(coeffs)
        self.row_lb.append(lb)
        self.row_ub.append(ub)
        return len(self.rows) - 1

    def add_constrs_coo(self, data, rows, cols, lb=-np.inf,
                        ub=np.inf) -> np.ndarray:
        """Append a block of constraint rows given as COO triplets.

        ``rows`` are 0-based *within the block*; ``lb``/``ub`` are
        scalars or arrays of length ``n_rows = max(rows) + 1`` (or the
        length of whichever of lb/ub is an array).  Returns the global
        row indices of the block.
        """
        data = np.asarray(data, dtype=float)
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        n_rows = 0
        for b in (lb, ub):
            if np.ndim(b) == 1:
                n_rows = max(n_rows, len(b))
        if n_rows == 0:
            n_rows = int(rows.max()) + 1 if rows.size else 0
        lb = np.broadcast_to(np.asarray(lb, dtype=float), (n_rows,))
        ub = np.broadcast_to(np.asarray(ub, dtype=float), (n_rows,))
        base = len(self.row_lb)
        # placeholder dicts keep per-var row indexing aligned; the COO
        # entries live in coo_blocks until _densify()/solve
        self.rows.extend({} for _ in range(n_rows))
        self.row_lb.extend(lb.tolist())
        self.row_ub.extend(ub.tolist())
        self.coo_blocks.append((data, rows + base, cols))
        return np.arange(base, base + n_rows)

    @property
    def n(self) -> int:
        return len(self.obj)

    def _matrix(self):
        data, ri, ci = [], [], []
        for i, row in enumerate(self.rows):
            for j, v in row.items():
                ri.append(i)
                ci.append(j)
                data.append(v)
        if self.coo_blocks:
            data = np.concatenate(
                [np.asarray(data, dtype=float)]
                + [b[0] for b in self.coo_blocks])
            ri = np.concatenate(
                [np.asarray(ri, dtype=np.int64)]
                + [b[1] for b in self.coo_blocks])
            ci = np.concatenate(
                [np.asarray(ci, dtype=np.int64)]
                + [b[2] for b in self.coo_blocks])
        return data, ri, ci

    def _densify(self) -> None:
        """Fold COO blocks into the per-row dicts (numpy backend)."""
        for data, ri, ci in self.coo_blocks:
            for v, i, j in zip(data.tolist(), ri.tolist(), ci.tolist()):
                row = self.rows[i]
                row[j] = row.get(j, 0.0) + v
        self.coo_blocks = []

    # ---------------------------------------------------------- backends
    def solve(self, time_limit: float = 120.0, gap: float = 1e-6,
              backend: str = "auto", incumbent: Optional[np.ndarray] = None,
              relax: bool = False):
        """Solve the model.

        ``incumbent`` is an optional warm-start point: the numpy
        branch-and-bound verifies it and, when feasible, prunes against
        its objective from node zero; the scipy/HiGHS backend has no
        warm-start API, so it is ignored there (callers still use it to
        pre-tighten bounds).  ``relax=True`` solves the LP relaxation
        (integrality dropped) on either backend — the result's
        ``dual_bound`` then equals its objective, a valid lower bound
        for the integer model.
        """
        if backend == "numpy" or (backend == "auto" and not HAVE_SCIPY):
            return self._solve_bb(time_limit, incumbent=incumbent,
                                  relax=relax)
        return self._solve_scipy(time_limit, gap, relax=relax)

    def _solve_scipy(self, time_limit: float, gap: float,
                     relax: bool = False):
        # corallint: disable=D1 - solve-seconds telemetry only
        t0 = time.time()
        data, ri, ci = self._matrix()
        A = sparse.csr_matrix((data, (ri, ci)), shape=(len(self.rows), self.n))
        cons = LinearConstraint(A, np.array(self.row_lb), np.array(self.row_ub))
        integrality = np.zeros(self.n, dtype=np.uint8) if relax \
            else np.array(self.integer, dtype=np.uint8)
        res = milp(
            c=np.array(self.obj),
            constraints=cons,
            integrality=integrality,
            bounds=Bounds(np.array(self.lb), np.array(self.ub)),
            options={"time_limit": time_limit, "mip_rel_gap": gap,
                     "presolve": True},
        )
        ok = res.status == 0 and res.x is not None
        if relax:
            dual = res.fun if ok else None
        else:
            dual = getattr(res, "mip_dual_bound", None)
        return SolveResult(ok, res.x if ok else None,
                           # corallint: disable=D1 - telemetry only
                           res.fun if ok else np.inf, time.time() - t0,
                           res.status, dual_bound=dual)

    # -------------------------------------------- numpy branch-and-bound
    def _lp_relax(self, extra_lb, extra_ub):
        """Dense LP relaxation via scipy-free projected subgradient is too
        weak; use a simple big-M simplex on the standard form. Suitable
        only for small models (tests)."""
        # convert to: min c x, A_eq x = b (with slacks), x >= 0, x <= ub
        n = self.n
        lb = np.maximum(self.lb, extra_lb)
        ub = np.minimum(self.ub, extra_ub)
        if np.any(lb > ub + 1e-12):
            return None, np.inf
        rows, rl, ru = [], [], []
        for row, l, u in zip(self.rows, self.row_lb, self.row_ub):
            dense = np.zeros(n)
            for j, v in row.items():
                dense[j] = v
            if u < np.inf:
                rows.append(dense.copy())
                rl.append(-np.inf)
                ru.append(u)
            if l > -np.inf:
                rows.append(-dense)
                rl.append(-np.inf)
                ru.append(-l)
        # shift x = y + lb, y in [0, ub-lb]
        shift = np.where(np.isfinite(lb), lb, 0.0)
        span = ub - shift
        A, b = [], []
        for dense, u in zip(rows, ru):
            A.append(dense)
            b.append(u - dense @ shift)
        for j in range(n):
            if np.isfinite(span[j]):
                e = np.zeros(n)
                e[j] = 1.0
                A.append(e)
                b.append(span[j])
        A = np.array(A) if A else np.zeros((0, n))
        b = np.array(b) if b else np.zeros((0,))
        y, obj = _simplex_min(np.array(self.obj), A, b)
        if y is None:
            return None, np.inf
        return y + shift, obj + np.dot(self.obj, shift)

    def _check_feasible(self, x: np.ndarray, tol: float = 1e-6) -> bool:
        """Bounds + rows + integrality check of a candidate point."""
        if x is None or len(x) != self.n:
            return False
        x = np.asarray(x, dtype=float)
        if np.any(x < np.array(self.lb) - tol) \
                or np.any(x > np.array(self.ub) + tol):
            return False
        for j, is_int in enumerate(self.integer):
            if is_int and abs(x[j] - round(x[j])) > tol:
                return False
        for row, l, u in zip(self.rows, self.row_lb, self.row_ub):
            a = sum(v * x[j] for j, v in row.items())
            if a < l - tol or a > u + tol:
                return False
        return True

    def _solve_bb(self, time_limit: float,
                  incumbent: Optional[np.ndarray] = None,
                  relax: bool = False):
        # corallint: disable=D1 - deadline clock, see below
        t0 = time.time()
        self._densify()
        n = self.n
        if relax:
            x, obj = self._lp_relax(np.full(n, -np.inf), np.full(n, np.inf))
            ok = x is not None
            t_s = time.time() - t0  # corallint: disable=D1 - telemetry only
            return SolveResult(ok, x, obj if ok else np.inf,
                               t_s, 0 if ok else 2,
                               dual_bound=obj if ok else None)
        best_x, best_obj = None, np.inf
        if incumbent is not None and self._check_feasible(incumbent):
            # bound pruning from node zero: the warm start's objective
            # is a valid upper bound before the first relaxation runs
            best_x = np.asarray(incumbent, dtype=float).copy()
            best_obj = float(np.dot(self.obj, best_x))
        stack = [(np.full(n, -np.inf), np.full(n, np.inf))]
        # deadline-bounded search is inherently wall-clock; callers
        # treat a timeout like a failed solve (Allocation.fallback)
        # corallint: disable=D1 - wall-clock solve deadline by design
        while stack and time.time() - t0 < time_limit:
            elb, eub = stack.pop()
            x, obj = self._lp_relax(elb, eub)
            if x is None or obj >= best_obj - 1e-9:
                continue
            frac_j, frac_v = -1, 0.0
            for j in range(n):
                if self.integer[j]:
                    f = abs(x[j] - round(x[j]))
                    if f > 1e-6 and f > frac_v:
                        frac_j, frac_v = j, f
            if frac_j < 0:
                if obj < best_obj:
                    best_obj, best_x = obj, x.copy()
                continue
            lo = np.floor(x[frac_j])
            l1, u1 = elb.copy(), eub.copy()
            u1[frac_j] = min(u1[frac_j], lo)
            l2, u2 = elb.copy(), eub.copy()
            l2[frac_j] = max(l2[frac_j], lo + 1)
            stack.append((l1, u1))
            stack.append((l2, u2))
        ok = best_x is not None
        # an exhausted stack proves optimality (within the node pruning
        # tolerance); a deadline exit leaves the bound unknown
        dual = best_obj if ok and not stack else None
        # corallint: disable=D1 - telemetry only
        return SolveResult(ok, best_x, best_obj, time.time() - t0,
                           0 if ok else 2, dual_bound=dual)


@dataclass
class SolveResult:
    ok: bool
    x: Optional[np.ndarray]
    obj: float
    seconds: float
    status: int
    # valid lower bound on the integer optimum when the backend proved
    # one (HiGHS' MIP dual bound / an exhausted numpy search / the LP
    # relaxation's own objective); None when unknown
    dual_bound: Optional[float] = None


def _simplex_min(c, A, b) -> Tuple[Optional[np.ndarray], float]:
    """min c.x s.t. A x <= b, x >= 0 — two-phase dense simplex (small)."""
    m, n = A.shape
    # add slacks
    T = np.hstack([A, np.eye(m), b.reshape(-1, 1)])
    # make b >= 0 via artificial handling: if b_i < 0, phase-1 needed;
    # for our test-scale problems all b >= 0 after shifting. Guard:
    if np.any(b < -1e-9):
        # phase 1 with artificials
        neg = b < 0
        T[neg, :] *= -1
        n_art = int(neg.sum())
        art = np.zeros((m, n_art))
        k = 0
        for i in range(m):
            if neg[i]:
                art[i, k] = 1.0
                k += 1
        T = np.hstack([T[:, :-1], art, T[:, -1:]])
        cost1 = np.zeros(T.shape[1] - 1)
        cost1[n + m:] = 1.0
        basis = []
        k = 0
        for i in range(m):
            if neg[i]:
                basis.append(n + m + k)
                k += 1
            else:
                basis.append(n + i)
        T, basis, ok = _pivot_loop(T, np.array(basis), cost1)
        if not ok or _objective(T, basis, cost1) > 1e-7:
            return None, np.inf
        # pivot remaining (zero-level) artificials out of the basis
        for i in range(m):
            if basis[i] >= n + m:
                row = T[i, :n + m]
                js = np.flatnonzero(np.abs(row) > 1e-9)
                if len(js):
                    j = int(js[0])
                    T[i, :] /= T[i, j]
                    for r in range(m):
                        if r != i and abs(T[r, j]) > 1e-12:
                            T[r, :] -= T[r, j] * T[i, :]
                    basis[i] = j
        keep = basis < n + m
        T = np.hstack([T[keep][:, :n + m], T[keep][:, -1:]])
        basis = basis[keep]
        m = T.shape[0]
    else:
        basis = np.array([n + i for i in range(m)])
    n_cols = T.shape[1] - 1
    cost = np.concatenate([c, np.zeros(n_cols - n)])
    T, basis, ok = _pivot_loop(T, basis, cost)
    if not ok:
        return None, np.inf
    x = np.zeros(n_cols)
    x[basis] = T[:, -1]
    return x[:n], float(cost @ x)


def _objective(T, basis, cost):
    x = np.zeros(T.shape[1] - 1)
    x[basis] = T[:, -1]
    return float(cost @ x)


def _pivot_loop(T, basis, cost, max_iter=2000):
    m = T.shape[0]
    for _ in range(max_iter):
        cb = cost[basis]
        red = cost[: T.shape[1] - 1] - cb @ T[:, :-1]
        j = int(np.argmin(red))
        if red[j] >= -1e-9:
            return T, basis, True
        col = T[:, j]
        pos = col > 1e-12
        if not np.any(pos):
            return T, basis, False          # unbounded
        ratios = np.where(pos, T[:, -1] / np.where(pos, col, 1.0), np.inf)
        i = int(np.argmin(ratios))
        T[i, :] /= T[i, j]
        for r in range(m):
            if r != i and abs(T[r, j]) > 1e-12:
                T[r, :] -= T[r, j] * T[i, :]
        basis[i] = j
    return T, basis, False
