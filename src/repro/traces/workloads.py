"""Synthetic request/availability traces.

Request traces mimic the three public datasets the paper evaluates on
(§6.1): Azure Code (long prompts, short outputs), Azure Conversation
(medium prompts, long outputs), BurstGPT (bursty gamma arrivals).
Availability follows an Alibaba-style bounded random walk per
(region, config). All generators are seeded and deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.hardware import NodeConfig, Region
from repro.core.profiles import WorkloadStats


@dataclass(frozen=True)
class TraceSpec:
    name: str
    prompt_mean: float
    prompt_cv: float
    output_mean: float
    output_cv: float
    burstiness: float      # CV of inter-arrival times (1 = Poisson)


TRACES: Dict[str, TraceSpec] = {
    "azure_code": TraceSpec("azure_code", 2048, 0.9, 36, 0.8, 1.0),
    "azure_conv": TraceSpec("azure_conv", 1024, 1.1, 240, 0.9, 1.0),
    "burstgpt": TraceSpec("burstgpt", 620, 1.0, 250, 0.9, 2.2),
}


def workload_stats(trace: str) -> WorkloadStats:
    t = TRACES[trace]
    return WorkloadStats(avg_prompt=t.prompt_mean, avg_output=t.output_mean)


@dataclass
class Request:
    rid: int
    model: str
    arrival: float
    prompt_len: int
    output_len: int
    # filled by the runtime/simulator:
    prefill_done: float = -1.0
    finish: float = -1.0
    decode_slo_ok: int = 0
    decode_tokens_ok: int = 0


def _lognormal(rng, mean, cv, size):
    sigma2 = np.log(1 + cv * cv)
    mu = np.log(mean) - sigma2 / 2
    return np.exp(rng.normal(mu, np.sqrt(sigma2), size))


def gen_requests(model: str, trace: str, rate: float, duration: float,
                 seed: int, rid0: int = 0) -> List[Request]:
    """Poisson/gamma arrival process at ``rate`` req/s for ``duration`` s."""
    t = TRACES[trace]
    if rate <= 0.0 or duration <= 0.0:
        return []
    rng = np.random.default_rng(seed)
    n = int(rate * duration * 1.5) + 16
    shape = 1.0 / (t.burstiness ** 2)
    scale = 1.0 / (rate * shape)
    gaps = rng.gamma(shape, scale, n)
    arr = np.cumsum(gaps)
    # bursty traces (CV > 1) can draw a gap sample whose sum falls short
    # of ``duration`` — the old fixed 1.5x buffer then silently ended
    # the trace early.  Extend the renewal process until it passes the
    # horizon, so the filter below always trims, never truncates.
    while arr[-1] < duration:
        more = rng.gamma(shape, scale, max(n // 2, 16))
        arr = np.concatenate([arr, arr[-1] + np.cumsum(more)])
    arr = arr[arr < duration]
    prompts = np.maximum(_lognormal(rng, t.prompt_mean, t.prompt_cv,
                                    len(arr)).astype(int), 8)
    outs = np.maximum(_lognormal(rng, t.output_mean, t.output_cv,
                                 len(arr)).astype(int), 4)
    return [Request(rid0 + i, model, float(a), int(p), int(o))
            for i, (a, p, o) in enumerate(zip(arr, prompts, outs))]


def gen_availability(regions: Sequence[Region], configs: Sequence[NodeConfig],
                     n_epochs: int, base: Dict[str, int], seed: int,
                     scarcity: Dict[str, float] | None = None
                     ) -> List[Dict[Tuple[str, str], int]]:
    """Alibaba-style availability walk: per (region, config), a bounded
    random walk around ``base[config]`` x regional factor, optionally
    scaled down per device type (``scarcity``, e.g. H100 constrained).

    The walk is bounded relative to the per-(region, config) *base*
    level: multiplicative steps are clipped to ``[0, 4 x base]``.  (The
    old code recomputed the ceiling from the current level each epoch,
    so the "bound" drifted with the walk and long horizons could grow
    without limit.)
    """
    rng = np.random.default_rng(seed)
    scarcity = scarcity or {}
    out = []
    level = {}
    bound = {}
    for r in regions:
        for c in configs:
            b = base.get(c.name, 0) * scarcity.get(c.device.name, 1.0)
            level[(r.name, c.name)] = b * rng.uniform(0.85, 1.15)
            bound[(r.name, c.name)] = 4.0 * max(b, 1.0)
    for _ in range(n_epochs):
        epoch = {}
        for k in level:
            level[k] = np.clip(level[k] * rng.uniform(0.88, 1.12),
                               0.0, bound[k])
            epoch[k] = int(round(level[k]))
        out.append(epoch)
    return out


def gen_requests_schedule(model: str, trace: str, rates: Sequence[float],
                          epoch_s: float, seed: int, rid0: int = 0,
                          rid_stride: int = 100_000) -> List[Request]:
    """Piecewise-constant rate schedule: one ``gen_requests`` stretch per
    epoch (rate ``rates[e]`` over ``[e*epoch_s, (e+1)*epoch_s)``), with
    per-epoch seeds so a scenario's epochs are individually
    reproducible.  Used by the control-plane scenario generators."""
    reqs: List[Request] = []
    for e, r in enumerate(rates):
        if r <= 1e-12:
            continue
        part = gen_requests(model, trace, float(r), epoch_s,
                            seed=seed * 1009 + e, rid0=rid0 + e * rid_stride)
        for q in part:
            q.arrival += e * epoch_s
        reqs += part
    return reqs


def default_base_availability(configs: Sequence[NodeConfig],
                              abundance: float = 8.0) -> Dict[str, int]:
    """Baseline node counts per config; top-tier GPUs are supply-constrained
    (paper §1: 'often supply-constrained')."""
    scarce = {"H100": 0.35, "A100": 0.6}
    out = {}
    for c in configs:
        per = abundance * scarce.get(c.device.name, 1.0)
        out[c.name] = max(int(round(per / max(c.n_devices // 2, 1))), 1)
    return out
