"""Fault-tolerance substrate: checkpoint/restore of training state.

Layout: one ``.npz`` per host process (sharded save: each host stores the
addressable shards of its devices) plus a JSON manifest with step, config
fingerprint and tree structure. Saves run on a background thread so the
training loop never blocks (async checkpointing); ``wait()`` joins before
the next save or on exit. Restore validates the manifest and rebuilds the
pytree.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Dict[str, Any], blocking: bool = False):
        """state: pytree of arrays (params/opt_state/...)."""
        self.wait()
        flat = _flatten(state)          # device_get on caller thread
        treedef = jax.tree_util.tree_structure(state)

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            pid = jax.process_index()
            np.savez(os.path.join(tmp, f"shard_{pid:05d}.npz"), **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "n_processes": jax.process_count(),
                "treedef": str(treedef),
                "keys": sorted(flat),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, path)       # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            p = os.path.join(self.dir, f"step_{s:08d}")
            for fn in os.listdir(p):
                os.unlink(os.path.join(p, fn))
            os.rmdir(p)

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any], step: Optional[int] = None):
        """Restore into the structure of ``like`` (shapes validated)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        pid = jax.process_index()
        data = np.load(os.path.join(path, f"shard_{pid:05d}.npz"))
        flat_like = _flatten(like)
        assert set(data.files) == set(flat_like), "checkpoint/tree mismatch"
        leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
        out_leaves = []
        for pth, leaf in leaves_with_path[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in pth)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            out_leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(leaves_with_path[1], out_leaves), \
            step
