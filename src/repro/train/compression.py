"""int8 gradient compression with error feedback (DESIGN.md §8).

Halves (vs bf16) / quarters (vs f32) the gradient reduce-scatter volume
across the data/pod axes. Per-tensor symmetric scaling; the
quantization residual is carried in an error-feedback buffer so the
compression bias vanishes over steps (Seide et al. / EF-SGD style).

Usage in a train step:
    grads_q, scales = compress(grads, ef)           # before all-reduce
    grads_q = jax.lax.psum(grads_q, axis)           # int32-safe psum
    grads, ef = decompress(grads_q, scales, ef)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _q(x, ef):
    xf = x.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return q, scale, err


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress(grads, ef) -> Tuple:
    """-> (int8 grads, f32 scales, new error-feedback residuals)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    qs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, err = _q(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(err)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            jax.tree.unflatten(treedef, errs))


def decompress(grads_q, scales, dtype=jnp.float32):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s.astype(jnp.float32),
        grads_q, scales)


def compressed_roundtrip(grads, ef):
    """Single-host helper: quantize+dequantize with error feedback;
    returns (approx_grads, new_ef). The distributed launcher inserts the
    psum between compress and decompress."""
    q, s, new_ef = compress(grads, ef)
    return decompress(q, s), new_ef
