"""Deterministic synthetic LM data pipeline.

Generates a zipf-distributed token stream with local bigram structure
(so loss actually decreases during the example training runs), sharded
by (process, data-parallel rank) and double-buffered via a background
prefetch thread — the shape of a real pipeline without external data.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, shard: int = 0, num_shards: int = 1,
                 prefetch: int = 2):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed * num_shards + shard + 1)
        # fixed random bigram table: each token has 8 likely successors
        g = np.random.default_rng(seed)
        self.succ = g.integers(0, vocab_size, size=(vocab_size, 8))
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _sample_batch(self) -> np.ndarray:
        B, S = self.batch, self.seq
        toks = np.empty((B, S), np.int32)
        zipf = np.minimum(self.rng.zipf(1.3, size=(B,)), self.vocab - 1)
        toks[:, 0] = zipf
        follow = self.rng.random((B, S)) < 0.8
        choice = self.rng.integers(0, 8, size=(B, S))
        rand = self.rng.integers(0, self.vocab, size=(B, S))
        for t in range(1, S):
            nxt = self.succ[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(follow[:, t], nxt, rand[:, t])
        return toks

    def _fill(self):
        while not self._stop.is_set():
            try:
                self._q.put(self._sample_batch(), timeout=0.5)
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        toks = self._q.get()
        return {"tokens": toks, "labels": toks.copy()}

    def close(self):
        self._stop.set()
