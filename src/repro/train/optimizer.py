"""Pure-JAX AdamW with global-norm clipping and LR schedules
(cosine default; WSD warmup-stable-decay for minicpm-2b per its paper).
Optimizer moments inherit the parameter PartitionSpecs (so FSDP archs
get ZeRO-style sharded optimizer state for free)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | wsd
    wsd_decay_frac: float = 0.1


def lr_at(oc: OptConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "wsd":
        decay_start = oc.total_steps * (1.0 - oc.wsd_decay_frac)
        frac = jnp.clip((step - decay_start)
                        / jnp.maximum(oc.total_steps - decay_start, 1), 0, 1)
        return oc.lr * warm * (1.0 - frac * (1.0 - 0.1))
    prog = jnp.clip(step / jnp.maximum(oc.total_steps, 1), 0, 1)
    return oc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs):
    from jax.sharding import PartitionSpec as P
    return {"m": param_specs, "v": param_specs, "step": P()}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(oc: OptConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / (gnorm + 1e-9))
    b1, b2 = oc.betas
    lr = lr_at(oc, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** step.astype(jnp.float32))
        vh = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
