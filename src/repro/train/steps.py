"""train_step / prefill_step / serve_step factories.

These are the programs the multi-pod dry-run lowers and the launchers
execute. All three are pure functions of (params/opt_state, inputs) and
jit-able under any mesh.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api as model_api
from repro.models import common as cm
from repro.train import optimizer as opt


def loss_fn(params, cfg: ModelConfig, batch: Dict):
    model = model_api.get_model(cfg)
    logits, aux = model.forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # logits cover [vision tokens | text]; loss only on text targets
        V = batch["vision_embeds"].shape[1]
        logits = logits[:, V:]
    # next-token prediction: logits[:, :-1] predict labels[:, 1:]
    loss = cm.cross_entropy(logits[:, :-1], labels[:, 1:], cfg.vocab_size)
    if cfg.family == "moe":
        loss = loss + 0.01 * aux
    return loss, {"lm_loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, oc: opt.OptConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt_state, om = opt.adamw_update(oc, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **om)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        model = model_api.get_model(cfg)
        logits, cache = model.prefill(params, cfg, batch)
        return logits, cache
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, tokens):
        model = model_api.get_model(cfg)
        logits, cache = model.decode_step(params, cfg, cache, tokens)
        return logits, cache
    return serve_step
