"""Minimal stand-in for ``hypothesis`` when it is not installed.

This container cannot pip-install anything, so the property-based test
modules fall back to deterministic example-based sampling: ``@given``
draws ``max_examples`` pseudo-random examples from the declared
strategies with a fixed seed and runs the test body once per example.
Coverage is narrower than real hypothesis (no shrinking, no edge-case
heuristics, no failure database) but every property still executes.

Usage, at the top of a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

Only the API surface the test suite uses is provided: ``given``,
``settings(max_examples=, deadline=)`` and the strategies ``integers``,
``floats``, ``booleans`` and ``composite``.
"""
from __future__ import annotations

import random

DEFAULT_MAX_EXAMPLES = 100          # hypothesis' own default profile


class _Strategy:
    def __init__(self, sample_fn):
        self._sample_fn = sample_fn

    def sample(self, rng: random.Random):
        return self._sample_fn(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def composite(fn):
        def make(*args, **kwargs):
            def sample(rng):
                return fn(lambda strat: strat.sample(rng), *args, **kwargs)
            return _Strategy(sample)
        return make


st = strategies


def given(*strats: _Strategy):
    def deco(fn):
        # NOTE: the wrapper must expose a zero-argument signature —
        # pytest would otherwise read the wrapped function's parameters
        # as fixture requests (hence no functools.wraps here).
        def wrapper():
            # honor @settings whether applied above @given (sets the
            # attribute on this wrapper) or below it (sets it on fn)
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_MAX_EXAMPLES))
            rng = random.Random(0xC0FFEE)
            for i in range(n):
                example = [s.sample(rng) for s in strats]
                try:
                    fn(*example)
                except Exception as exc:          # noqa: BLE001
                    raise AssertionError(
                        f"falsifying example #{i}: {example!r}") from exc
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hypothesis_compat = True
        return wrapper
    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
