"""Disk-cached Serving-Template libraries for the test suite.

``test_runtime`` / ``test_allocator`` need small template libraries but
used to rebuild them from scratch at every module import, dominating
tier-1 wall time.  This helper pickles each library under
``artifacts/lib_test_*.pkl`` (next to the benchmark suite's
``lib_*.pkl`` caches) and reuses it on subsequent runs.  Coral
libraries go through ``build_library(reuse=...)`` so every (model,
phase) pair is fingerprint-checked (config universe, n_max, rho, SLO,
workload) and regenerated if its inputs changed; homogeneous baseline
libraries store the same per-(model, phase, config) fingerprints
alongside the pickle and rebuild whenever any of them drifts.
"""
from __future__ import annotations

import os
import pickle

from repro.core.baselines import homo_library
from repro.core.templates import build_library, generation_fingerprint

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def _homo_fingerprint(models, configs, wls, n_max, rho):
    """Everything a homo_library build depends on: one per-config
    generation fingerprint per (model, phase)."""
    return tuple(
        generation_fingerprint(m, phase, [c], wls[m.name], n_max, rho,
                               True, "fast", None)
        for m in models for phase in ("prefill", "decode")
        for c in sorted(configs, key=lambda c: c.name))


def cached_test_library(tag: str, models, configs, wls,
                        n_max: int, rho: float, homo: bool = False):
    os.makedirs(ART, exist_ok=True)
    kind = "homo" if homo else "coral"
    path = os.path.join(ART, f"lib_test_{tag}_{kind}_{n_max}_{rho}.pkl")
    reuse = None
    if os.path.exists(path):
        try:
            with open(path, "rb") as f:
                reuse = pickle.load(f)
        except Exception:                               # noqa: BLE001
            reuse = None
    if homo:
        fp = _homo_fingerprint(models, configs, wls, n_max, rho)
        if isinstance(reuse, dict) and reuse.get("fp") == fp:
            return reuse["lib"]
        lib = homo_library(models, configs, wls, n_max=n_max, rho=rho)
        blob = {"fp": fp, "lib": lib}
    else:
        lib = build_library(models, configs, wls, n_max=n_max, rho=rho,
                            reuse=reuse)
        if reuse is not None and all(
                s.get("reused") for s in lib.stats.values()):
            return reuse                # nothing changed: keep mtime
        blob = lib
    with open(path, "wb") as f:
        pickle.dump(blob, f)
    return lib
