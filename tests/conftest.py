"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only launch/dryrun.py forces 512 host devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def phi4_runtime_library():
    """Session-scoped template library for the epoch-runtime tests,
    served from the ``artifacts/lib_test_*.pkl`` disk cache (see
    tests/_libcache.py) instead of being rebuilt per run."""
    from _libcache import cached_test_library
    from repro.core.hardware import make_node_configs
    from repro.core.modelspec import PAPER_MODELS
    from repro.traces.workloads import workload_stats

    model = PAPER_MODELS["phi4-14b"]
    configs = make_node_configs(["L40S", "L4"], sizes=(1, 2))
    wls = {model.name: workload_stats(model.trace)}
    return cached_test_library("runtime", [model], configs, wls,
                               n_max=3, rho=8.0)
