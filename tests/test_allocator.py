"""Online allocator invariants (property-based where cheap)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from _libcache import cached_test_library

from repro.core.allocator import (AllocProblem, AllocatorState, Demand,
                                  allocate, allocate_reference)
from repro.core.baselines import homo_allocate, cauchy_allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.traces.workloads import workload_stats

CONFIGS = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
MODELS = [PAPER_MODELS["phi4-14b"], PAPER_MODELS["gpt-oss-20b"]]
WLS = {m.name: workload_stats(m.trace) for m in MODELS}
# module-level (not a fixture): the hypothesis-shimmed @given tests
# cannot take fixture arguments, so the libraries are pulled from the
# artifacts/lib_test_*.pkl disk cache at import instead of rebuilt
LIB = cached_test_library("alloc", MODELS, CONFIGS, WLS, n_max=3, rho=8.0)
HLIB = cached_test_library("alloc", MODELS, CONFIGS, WLS, n_max=3, rho=8.0,
                           homo=True)


def _check_alloc(alloc, avail, demands):
    # availability respected
    used = {}
    for (region, key), n in alloc.instances.items():
        t = alloc.templates[key]
        for c, k in t.counts:
            used[(region, c)] = used.get((region, c), 0) + k * n
    for k, v in used.items():
        assert v <= avail.get(k, 0), (k, v, avail.get(k, 0))
    # demand met or shortfall declared
    for d in demands:
        served = alloc.served(d.model, d.phase)
        short = alloc.unmet.get((d.model, d.phase), 0.0)
        assert served + short >= d.tokens_per_s - 1e-3


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 30), st.floats(100, 3000))
def test_allocation_invariants(seed, abundance, dec_demand):
    rng = np.random.default_rng(seed)
    avail = {(r.name, c.name): int(rng.integers(0, abundance))
             for r in CORE_REGIONS for c in CONFIGS}
    demands = []
    for m in MODELS:
        wl = WLS[m.name]
        demands.append(Demand(m.name, "prefill",
                              dec_demand * wl.avg_prompt / wl.avg_output))
        demands.append(Demand(m.name, "decode", dec_demand))
    alloc = allocate(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands,
                                  LIB, time_limit=30))
    assert alloc.ok
    _check_alloc(alloc, avail, demands)
    for fn, lib in ((homo_allocate, HLIB), (cauchy_allocate, HLIB)):
        a = fn(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            lib, time_limit=30), lib)
        _check_alloc(a, avail, demands)


def test_coral_never_worse_than_homo():
    """With the richer (superset) library and exact ILP, Coral's cost is
    <= the greedy homogeneous baseline whenever both meet demand."""
    avail = {(r.name, c.name): 40 for r in CORE_REGIONS for c in CONFIGS}
    demands = []
    for m in MODELS:
        wl = WLS[m.name]
        demands.append(Demand(m.name, "prefill", 10 * wl.avg_prompt))
        demands.append(Demand(m.name, "decode", 10 * wl.avg_output))
    coral = allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                  demands, LIB, time_limit=60))
    homo = homo_allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                      demands, HLIB), HLIB)
    assert coral.ok and not coral.unmet
    if not homo.unmet:
        assert coral.cost_per_hour <= homo.cost_per_hour + 1e-6


def test_init_penalty_prefers_stability():
    """Between equal-cost compositions, the solver keeps what runs."""
    avail = {(r.name, c.name): 40 for r in CORE_REGIONS for c in CONFIGS}
    demands = [Demand(MODELS[0].name, "decode", 500.0)]
    prob = AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
                        init_penalty_k=0.2, time_limit=30)
    a1 = allocate(prob)
    # re-solve declaring a1 as current: result should not add instances
    prob2 = AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
                         current=dict(a1.instances), init_penalty_k=0.2,
                         time_limit=30)
    a2 = allocate(prob2)
    assert a2.init_penalty <= 1e-6
    assert a2.instances == a1.instances


def _demands(dec_demand=800.0):
    out = []
    for m in MODELS:
        wl = WLS[m.name]
        out.append(Demand(m.name, "prefill",
                          dec_demand * wl.avg_prompt / wl.avg_output))
        out.append(Demand(m.name, "decode", dec_demand))
    return out


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000), st.integers(0, 30), st.floats(100, 3000))
def test_columnar_matches_reference_objective(seed, abundance, dec_demand):
    """Tentpole equivalence: the columnar assembly lands on the same
    MILP objective as the seed per-var path (within the MIP gap), on
    abundant and scarce availability alike."""
    rng = np.random.default_rng(seed)
    avail = {(r.name, c.name): int(rng.integers(0, abundance + 1))
             for r in CORE_REGIONS for c in CONFIGS}
    demands = _demands(dec_demand)
    ref = allocate_reference(AllocProblem(
        CORE_REGIONS, CONFIGS, dict(avail), demands, LIB, time_limit=30))
    col = allocate(AllocProblem(
        CORE_REGIONS, CONFIGS, dict(avail), demands, LIB, time_limit=30))
    assert ref.ok and col.ok
    rel = abs(ref.objective - col.objective) \
        / max(abs(ref.objective), 1e-9)
    assert rel <= 5e-4, (ref.objective, col.objective)
    _check_alloc(col, avail, demands)


def test_allocator_state_reuses_structure_across_epochs():
    """Epoch re-solves rewrite bounds/RHS in the assembled structure —
    no full rebuild — and stay valid under changed availability,
    demand and current counts."""
    state = AllocatorState()
    builds = []
    orig_build = state._build
    state._build = lambda p: (builds.append(1), orig_build(p))[1]
    rng = np.random.default_rng(7)
    prev = {}
    coo_id = None
    for epoch in range(4):
        avail = {(r.name, c.name): int(rng.integers(2, 30))
                 for r in CORE_REGIONS for c in CONFIGS}
        demands = _demands(400.0 + 300.0 * epoch)
        alloc = state(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands,
                                   LIB, current=prev, time_limit=30))
        assert alloc.ok
        _check_alloc(alloc, avail, demands)
        prev = dict(alloc.instances)
        if coo_id is None:
            coo_id = id(state._coo_data)
        else:                       # same assembled arrays, epoch over epoch
            assert id(state._coo_data) == coo_id
    assert len(builds) == 1, "re-solves must not rebuild the structure"
    # changing the demand-key shape rebuilds transparently
    alloc = state(AllocProblem(CORE_REGIONS, CONFIGS, avail,
                               demands[:2], LIB, time_limit=30))
    assert alloc.ok and len(builds) == 2


def test_warm_started_epochs_match_reference():
    """Incumbent pruning must be lossless: epoch 2+ solves (where the
    previous solution tightens v_ub and the shortfall big-M) land on
    the same objective as a cold reference solve of the same epoch."""
    state = AllocatorState()
    rng = np.random.default_rng(21)
    cur = {}
    for epoch in range(3):
        avail = {(r.name, c.name): int(rng.integers(1, 25))
                 for r in CORE_REGIONS for c in CONFIGS}
        demands = _demands(float(rng.uniform(200, 2500)))
        warm = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                  demands, LIB, current=dict(cur),
                                  time_limit=30))
        cold = allocate_reference(AllocProblem(
            CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
            current=dict(cur), time_limit=30))
        assert warm.ok and cold.ok
        rel = abs(warm.objective - cold.objective) \
            / max(abs(cold.objective), 1e-9)
        assert rel <= 5e-4, (epoch, warm.objective, cold.objective)
        cur = dict(warm.instances)


def test_state_rebuilds_when_empty_pair_fills():
    """A (model, phase) that had zero templates at build time must be
    re-checked on later solves — lib.add may have filled it since."""
    from repro.core.templates import TemplateLibrary
    m = MODELS[0].name
    lib2 = TemplateLibrary(config_by_name=dict(LIB.config_by_name))
    lib2.add((m, "decode"), [], {})
    demands = [Demand(m, "decode", 500.0)]
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    state = AllocatorState()
    a1 = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            lib2, time_limit=30))
    assert a1.ok and not a1.instances and a1.unmet
    lib2.add((m, "decode"), LIB.get(m, "decode"), {})
    a2 = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            lib2, time_limit=30))
    assert a2.ok and a2.instances and not a2.unmet


def test_incumbent_fallback_on_solver_failure(monkeypatch):
    """When HiGHS fails/times out mid-run, the state returns the
    previous epoch's solution clamped to the new availability instead
    of an empty allocation.  Forced to the monolithic tier: in auto
    mode the decomposed tier would succeed without ever touching
    ``MilpModel`` (that resilience has its own ladder test below)."""
    from repro.solver.milp import MilpModel, SolveResult
    state = AllocatorState()
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    demands = _demands(600.0)
    a1 = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            LIB, time_limit=30, solve_mode="monolithic"))
    assert a1.ok and a1.instances and not a1.fallback
    assert a1.solve_path == "monolithic"

    def fail(self, **kw):
        return SolveResult(False, None, np.inf, 0.0, 2)
    monkeypatch.setattr(MilpModel, "solve", fail)
    # availability tightens: the incumbent must be clamped + repaired
    tight = {k: max(v - 15, 0) for k, v in avail.items()}
    a2 = state(AllocProblem(CORE_REGIONS, CONFIGS, tight, demands, LIB,
                            current=dict(a1.instances), time_limit=30,
                            solve_mode="monolithic"))
    assert a2.ok and a2.fallback and a2.solve_path == "fallback"
    _check_alloc(a2, tight, demands)    # clamped incumbent is feasible
    # a fresh state has no incumbent: failure surfaces as ok=False
    a3 = AllocatorState()(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                       demands, LIB, time_limit=30,
                                       solve_mode="monolithic"))
    assert not a3.ok and not a3.instances


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 30), st.floats(150, 2500))
def test_solve_tiers_agree_randomized(seed, abundance, dec_demand):
    """Optimality-equivalence harness across the three solve tiers.

    The auto ladder must land within the accept gap of the forced
    monolithic optimum (it only returns a fast tier when *certified*);
    the forced fast tiers must stay feasible and, being feasible, can
    never beat the exact optimum by more than the solver gap."""
    rng = np.random.default_rng(seed)
    avail = {(r.name, c.name): int(rng.integers(0, abundance))
             for r in CORE_REGIONS for c in CONFIGS}
    demands = _demands(dec_demand)

    def run(mode):
        return allocate(AllocProblem(
            CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
            time_limit=30, solve_mode=mode))

    mono = run("monolithic")
    assert mono.ok and mono.solve_path == "monolithic"
    auto = run("auto")
    assert auto.ok and not auto.fallback
    rel = abs(auto.objective - mono.objective) \
        / max(abs(mono.objective), 1e-9)
    assert rel <= 5e-4, (auto.solve_path, auto.objective, mono.objective)
    _check_alloc(auto, avail, demands)
    for mode in ("decomposed", "rounded_lp"):
        a = run(mode)
        if not a.ok:          # forced tier may fail where auto escalates
            continue
        assert a.solve_path in (mode, "fallback")
        _check_alloc(a, avail, demands)
        assert a.objective >= mono.objective - 5e-4 * abs(mono.objective) \
            - 1e-6, (mode, a.objective, mono.objective)


def test_degradation_ladder(monkeypatch):
    """Price-loop non-convergence (or a crash) must escalate to the
    monolithic solve; with *every* solver broken the state falls back
    to the incumbent, then to a not-ok Allocation — never raising."""
    from repro.solver import decompose
    from repro.solver.milp import MilpModel
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    demands = _demands(700.0)

    def prob(mode="auto", current=None):
        return AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            LIB, current=dict(current or {}),
                            time_limit=30, solve_mode=mode)

    mono_obj = allocate(prob("monolithic")).objective

    def boom(*a, **kw):
        raise RuntimeError("decomposition blew up")
    # rung 1: decomposed tier crashes -> auto escalates, same optimum
    monkeypatch.setattr(decompose, "solve_decomposed", boom)
    state = AllocatorState()
    a1 = state(prob())
    assert a1.ok and not a1.fallback
    assert a1.solve_path in ("rounded_lp", "monolithic")
    rel = abs(a1.objective - mono_obj) / max(abs(mono_obj), 1e-9)
    assert rel <= 5e-4
    # rung 2: every solver broken, warm state -> incumbent fallback
    monkeypatch.setattr(MilpModel, "solve", boom)
    a2 = state(prob(current=a1.instances))
    assert a2.ok and a2.fallback and a2.solve_path == "fallback"
    _check_alloc(a2, avail, demands)
    # rung 3: every solver broken, cold state -> not-ok, no exception
    a3 = AllocatorState()(prob())
    assert not a3.ok and not a3.instances and a3.solve_path == "fallback"


def test_solve_time_breakdown_reported():
    """Every successful solve stamps the path + time breakdown the
    runtime's EpochMetrics aggregates."""
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    a = allocate(AllocProblem(CORE_REGIONS, CONFIGS, avail, _demands(500.0),
                              LIB, time_limit=30))
    assert a.solve_path in ("decomposed", "rounded_lp", "monolithic")
    assert a.solver_seconds >= 0.0 and a.extract_seconds >= 0.0
    assert a.solve_seconds >= a.solver_seconds + a.extract_seconds - 1e-6


def test_scarce_availability_reports_unmet():
    avail = {(r.name, c.name): 0 for r in CORE_REGIONS for c in CONFIGS}
    avail[(CORE_REGIONS[0].name, CONFIGS[0].name)] = 1
    demands = [Demand(MODELS[0].name, "decode", 1e5)]
    alloc = allocate(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands,
                                  LIB, time_limit=30))
    assert alloc.ok
    assert alloc.unmet.get((MODELS[0].name, "decode"), 0) > 0
