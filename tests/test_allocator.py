"""Online allocator invariants (property-based where cheap)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from _libcache import cached_test_library

from repro.core.allocator import AllocProblem, Demand, allocate
from repro.core.baselines import homo_allocate, cauchy_allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.traces.workloads import workload_stats

CONFIGS = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
MODELS = [PAPER_MODELS["phi4-14b"], PAPER_MODELS["gpt-oss-20b"]]
WLS = {m.name: workload_stats(m.trace) for m in MODELS}
# module-level (not a fixture): the hypothesis-shimmed @given tests
# cannot take fixture arguments, so the libraries are pulled from the
# artifacts/lib_test_*.pkl disk cache at import instead of rebuilt
LIB = cached_test_library("alloc", MODELS, CONFIGS, WLS, n_max=3, rho=8.0)
HLIB = cached_test_library("alloc", MODELS, CONFIGS, WLS, n_max=3, rho=8.0,
                           homo=True)


def _check_alloc(alloc, avail, demands):
    # availability respected
    used = {}
    for (region, key), n in alloc.instances.items():
        t = alloc.templates[key]
        for c, k in t.counts:
            used[(region, c)] = used.get((region, c), 0) + k * n
    for k, v in used.items():
        assert v <= avail.get(k, 0), (k, v, avail.get(k, 0))
    # demand met or shortfall declared
    for d in demands:
        served = alloc.served(d.model, d.phase)
        short = alloc.unmet.get((d.model, d.phase), 0.0)
        assert served + short >= d.tokens_per_s - 1e-3


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(2, 30), st.floats(100, 3000))
def test_allocation_invariants(seed, abundance, dec_demand):
    rng = np.random.default_rng(seed)
    avail = {(r.name, c.name): int(rng.integers(0, abundance))
             for r in CORE_REGIONS for c in CONFIGS}
    demands = []
    for m in MODELS:
        wl = WLS[m.name]
        demands.append(Demand(m.name, "prefill",
                              dec_demand * wl.avg_prompt / wl.avg_output))
        demands.append(Demand(m.name, "decode", dec_demand))
    alloc = allocate(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands,
                                  LIB, time_limit=30))
    assert alloc.ok
    _check_alloc(alloc, avail, demands)
    for fn, lib in ((homo_allocate, HLIB), (cauchy_allocate, HLIB)):
        a = fn(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                            lib, time_limit=30), lib)
        _check_alloc(a, avail, demands)


def test_coral_never_worse_than_homo():
    """With the richer (superset) library and exact ILP, Coral's cost is
    <= the greedy homogeneous baseline whenever both meet demand."""
    avail = {(r.name, c.name): 40 for r in CORE_REGIONS for c in CONFIGS}
    demands = []
    for m in MODELS:
        wl = WLS[m.name]
        demands.append(Demand(m.name, "prefill", 10 * wl.avg_prompt))
        demands.append(Demand(m.name, "decode", 10 * wl.avg_output))
    coral = allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                  demands, LIB, time_limit=60))
    homo = homo_allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                      demands, HLIB), HLIB)
    assert coral.ok and not coral.unmet
    if not homo.unmet:
        assert coral.cost_per_hour <= homo.cost_per_hour + 1e-6


def test_init_penalty_prefers_stability():
    """Between equal-cost compositions, the solver keeps what runs."""
    avail = {(r.name, c.name): 40 for r in CORE_REGIONS for c in CONFIGS}
    demands = [Demand(MODELS[0].name, "decode", 500.0)]
    prob = AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
                        init_penalty_k=0.2, time_limit=30)
    a1 = allocate(prob)
    # re-solve declaring a1 as current: result should not add instances
    prob2 = AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands, LIB,
                         current=dict(a1.instances), init_penalty_k=0.2,
                         time_limit=30)
    a2 = allocate(prob2)
    assert a2.init_penalty <= 1e-6
    assert a2.instances == a1.instances


def test_scarce_availability_reports_unmet():
    avail = {(r.name, c.name): 0 for r in CORE_REGIONS for c in CONFIGS}
    avail[(CORE_REGIONS[0].name, CONFIGS[0].name)] = 1
    demands = [Demand(MODELS[0].name, "decode", 1e5)]
    alloc = allocate(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands,
                                  LIB, time_limit=30))
    assert alloc.ok
    assert alloc.unmet.get((MODELS[0].name, "decode"), 0) > 0
