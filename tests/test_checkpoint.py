"""Checkpoint/restore roundtrip + retention + async save."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import optimizer as opt
from repro.train.checkpoint import Checkpointer


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"layer": {"w": jax.random.normal(k, (8, 8)),
                        "b": jnp.zeros((8,))}}
    return {"p": params, "o": opt.init_opt_state(params)}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state()
    ck.save(10, st, blocking=True)
    restored, step = ck.restore(_state(seed=1))
    assert step == 10
    np.testing.assert_allclose(restored["p"]["layer"]["w"],
                               st["p"]["layer"]["w"])
    np.testing.assert_allclose(restored["o"]["m"]["layer"]["w"],
                               st["o"]["m"]["layer"]["w"])


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state()
    ck.save(5, st)             # async
    ck.wait()
    restored, step = ck.restore(_state(seed=2))
    assert step == 5
    np.testing.assert_allclose(restored["p"]["layer"]["w"],
                               st["p"]["layer"]["w"])


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_state())


def test_train_resume_equivalence(tmp_path):
    """Training N steps == training k, restoring, training N-k (modulo
    data stream position — we feed identical batches)."""
    from repro.configs.registry import get_smoke_config
    from repro.models import api as mapi
    from repro.train import steps as steps_mod

    cfg = get_smoke_config("qwen2-1.5b")
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    oc = opt.OptConfig(total_steps=6, warmup_steps=1)
    ts = jax.jit(steps_mod.make_train_step(cfg, oc))
    batches = []
    for i in range(4):
        t = jax.random.randint(jax.random.PRNGKey(i), (2, 16), 0,
                               cfg.vocab_size)
        batches.append({"tokens": t, "labels": t})

    p, o = params, opt.init_opt_state(params)
    for b in batches:
        p, o, _ = ts(p, o, b)
    direct_loss = float(ts(p, o, batches[0])[2]["loss"])

    ck = Checkpointer(str(tmp_path))
    p2, o2 = params, opt.init_opt_state(params)
    for b in batches[:2]:
        p2, o2, _ = ts(p2, o2, b)
    ck.save(2, {"p": p2, "o": o2}, blocking=True)
    restored, _ = ck.restore({"p": p2, "o": o2})
    p3, o3 = restored["p"], restored["o"]
    for b in batches[2:]:
        p3, o3, _ = ts(p3, o3, b)
    resumed_loss = float(ts(p3, o3, batches[0])[2]["loss"])
    assert abs(direct_loss - resumed_loss) < 1e-4
