"""int8 gradient compression: bounded error, error-feedback convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.compression import (compress, compressed_roundtrip,
                                     decompress, init_error_feedback)


def _grads(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (32, 32)) * 0.01,
            "b": jax.random.normal(jax.random.fold_in(k, 1), (32,)) * 0.1}


def test_roundtrip_error_bounded():
    g = _grads()
    ef = init_error_feedback(g)
    q, s, _ = compress(g, ef)
    approx = decompress(q, s)
    for k in g:
        scale = float(jnp.max(jnp.abs(g[k]))) / 127.0
        assert float(jnp.max(jnp.abs(approx[k] - g[k]))) <= scale * 0.51 + 1e-9


def test_int8_range():
    g = _grads(1)
    q, _, _ = compress(g, init_error_feedback(g))
    for leaf in jax.tree.leaves(q):
        assert leaf.dtype == jnp.int8


def test_error_feedback_removes_bias():
    """Accumulated compressed gradient converges to the true sum."""
    g = _grads(2)
    ef = init_error_feedback(g)
    total_true = jax.tree.map(lambda x: x * 0.0, g)
    total_comp = jax.tree.map(lambda x: x * 0.0, g)
    steps = 50
    for _ in range(steps):
        approx, ef = compressed_roundtrip(g, ef)
        total_true = jax.tree.map(jnp.add, total_true, g)
        total_comp = jax.tree.map(jnp.add, total_comp, approx)
    for k in g:
        rel = float(jnp.linalg.norm(total_comp[k] - total_true[k])
                    / jnp.linalg.norm(total_true[k]))
        assert rel < 0.01, (k, rel)   # bias vanishes with error feedback
