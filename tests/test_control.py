"""Control plane: demand estimator, re-solve controller, transition
planner, scenario generators, and the estimator-driven epoch loop.

Scenario/runtime tests reuse the session-scoped ``phi4_runtime_library``
fixture (tests/conftest.py) — the same small L40S/L4 library the epoch
runtime tests run on."""
import numpy as np
import pytest

from repro.control import (ControllerConfig, DemandEstimator,
                           EstimatorConfig, ReSolveController,
                           SCENARIO_NAMES, TransitionPlanner, make_scenario)
from repro.core.allocator import AllocatorState, Demand, allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.runtime.cluster import ClusterRuntime, RunResult
from repro.traces.workloads import workload_stats

CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
MODEL = PAPER_MODELS["phi4-14b"]
WLS = {MODEL.name: workload_stats(MODEL.trace)}
M = MODEL.name


# ---------------------------------------------------------- estimator
def _fed_estimator(rate, n_windows=40, dt=60.0, noise=None, seed=0,
                   cfg=None):
    est = DemandEstimator([M], WLS, cfg)
    rng = np.random.default_rng(seed)
    wl = WLS[M]
    for _ in range(n_windows):
        r = rate if noise is None else rate * (1 + noise * rng.uniform(-1, 1))
        n = max(int(round(r * dt)), 0)
        est.ingest_window(M, dt, n, n * wl.avg_prompt)
    return est


def test_estimator_converges_on_stationary_rate():
    rate = 4.0
    est = _fed_estimator(rate)
    assert abs(est.rate(M) - rate) / rate < 0.1
    dem = {(d.model, d.phase): d.tokens_per_s for d in est.estimate()}
    wl = WLS[M]
    assert abs(dem[(M, "prefill")] - rate * wl.avg_prompt) \
        / (rate * wl.avg_prompt) < 0.15
    assert abs(dem[(M, "decode")] - rate * wl.avg_output) \
        / (rate * wl.avg_output) < 0.15


def test_estimator_prior_before_any_observation():
    est = DemandEstimator([M], WLS)
    assert est.rate(M) == pytest.approx(est.cfg.prior_rate)
    dem = est.estimate()
    assert {(d.model, d.phase) for d in dem} \
        == {(M, "prefill"), (M, "decode")}
    assert all(d.tokens_per_s > 0 for d in dem)


def test_estimator_demand_order_is_stable():
    est = _fed_estimator(2.0)
    first = [(d.model, d.phase) for d in est.estimate()]
    est.ingest_window(M, 60.0, 500, 500 * 100.0)
    assert [(d.model, d.phase) for d in est.estimate()] == first


def test_headroom_quantile_is_monotone():
    est = _fed_estimator(3.0, noise=0.6, seed=7)
    qs = [0.5, 0.6, 0.7, 0.8, 0.9, 0.99]
    rates = [est.rate(M, q=q) for q in qs]
    assert all(b >= a - 1e-12 for a, b in zip(rates, rates[1:]))
    # and headroom actually adds over the noisy mean at high quantiles
    assert est.rate(M, q=0.99) > 3.0


def test_estimator_tracks_ramp_with_trend():
    est = DemandEstimator([M], WLS)
    wl = WLS[M]
    for i in range(20):
        r = 1.0 + 0.25 * i                 # ramping arrivals
        n = int(round(r * 60.0))
        est.ingest_window(M, 60.0, n, n * wl.avg_prompt)
    # extrapolating one epoch ahead exceeds the trailing EWMA level
    assert est.rate(M, horizon_s=240.0) > est.rate(M, horizon_s=0.0)


# --------------------------------------------------------- controller
def _demands(tps):
    return [Demand(M, "prefill", tps), Demand(M, "decode", tps * 0.1)]


AVAIL = {("r0", "1xL40S"): 20, ("r0", "1xL4"): 20}


def test_controller_resolves_initially_then_cadence():
    c = ReSolveController(ControllerConfig(max_interval_epochs=4))
    d0 = c.decide(0, _demands(100.0), AVAIL)
    assert d0.resolve and d0.reason == "initial"
    c.notify_solved(_demands(100.0), AVAIL)
    reasons = []
    for e in range(1, 9):
        dec = c.decide(e, _demands(100.0), AVAIL)
        reasons.append(dec.reason)
        if dec.resolve:
            c.notify_solved(_demands(100.0), AVAIL)
    # perfectly steady: only the cadence fallback fires, every 4 epochs
    assert reasons.count("cadence") == 2
    assert all(r in ("steady", "cadence") for r in reasons)


def test_controller_hysteresis_prevents_thrash_on_noise():
    """Noisy-but-stationary demand (+/-20%, below the 30% trigger) must
    not re-solve more often than the cadence fallback."""
    cfg = ControllerConfig(max_interval_epochs=4)
    c = ReSolveController(cfg)
    rng = np.random.default_rng(3)
    n_resolves = 0
    ref = 100.0
    c.decide(0, _demands(ref), AVAIL)
    c.notify_solved(_demands(ref), AVAIL)
    n_epochs = 16
    for e in range(1, n_epochs):
        tps = ref * (1 + 0.2 * rng.uniform(-1, 1))
        dec = c.decide(e, _demands(tps), AVAIL)
        if dec.resolve:
            assert dec.reason == "cadence"
            n_resolves += 1
            c.notify_solved(_demands(tps), AVAIL)
    assert n_resolves <= n_epochs // cfg.max_interval_epochs


def test_controller_fires_on_demand_drift():
    c = ReSolveController()
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)
    c.decide(1, _demands(105.0), AVAIL)         # cooldown epoch, quiet
    dec = c.decide(2, _demands(250.0), AVAIL)   # 2.5x surge
    assert dec.resolve and dec.reason == "demand_drift"


def test_controller_cooldown_defers_moderate_drift():
    c = ReSolveController(ControllerConfig(cooldown_epochs=2))
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)
    # +50% drift (symmetric: 50/150 = 0.33): above the 0.3 trigger,
    # below the 0.6 emergency level
    dec = c.decide(1, _demands(150.0), AVAIL)
    assert not dec.resolve and dec.reason == "cooldown"
    dec = c.decide(2, _demands(150.0), AVAIL)
    assert not dec.resolve and dec.reason == "cooldown"
    dec = c.decide(3, _demands(150.0), AVAIL)
    assert dec.resolve and dec.reason == "demand_drift"


def test_controller_emergency_bypasses_cooldown():
    c = ReSolveController()
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)
    # a preemption always overrides the gate
    dec = c.decide(1, _demands(100.0), AVAIL, n_preempted=2)
    assert dec.resolve and dec.reason == "preempted"
    c.notify_solved(_demands(100.0), AVAIL)
    # availability collapse (>= 2x the trigger level) fires mid-cooldown
    gone = {k: 0 for k in AVAIL}
    dec = c.decide(2, _demands(100.0), gone)
    assert dec.resolve and dec.reason == "avail_delta"


def test_controller_fires_on_availability_delta():
    c = ReSolveController()
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)
    c.decide(1, _demands(100.0), AVAIL)
    half = {k: v // 2 for k, v in AVAIL.items()}
    dec = c.decide(2, _demands(100.0), half)
    assert dec.resolve and dec.reason == "avail_delta"


def test_controller_decide_event_fires_on_accumulated_losses():
    """Sub-epoch hook: losses accumulate across events and fire once
    they reach the configured fraction of the held fleet."""
    cfg = ControllerConfig(event_loss_frac=0.25, max_mid_resolves=2,
                           min_event_gap_s=30.0)
    c = ReSolveController(cfg)
    # no standing solve yet: the epoch loop owns the first solve
    assert not c.decide_event(10.0, 5, 10).resolve
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)     # absorbs prior losses
    d = c.decide_event(100.0, 1, 10)            # 1 < 2.5 of 10 held
    assert not d.resolve and d.reason == "steady"
    d = c.decide_event(105.0, 2, 10)            # 3 >= 2.5: fire
    assert d.resolve and d.reason == "event"
    c.notify_solved(_demands(100.0), AVAIL)


def test_controller_decide_event_throttles():
    """The mid-epoch path is rate-limited: min spacing in simulated
    time, and a per-epoch re-solve budget reset by ``decide``."""
    cfg = ControllerConfig(event_loss_frac=0.1, max_mid_resolves=2,
                           min_event_gap_s=30.0)
    c = ReSolveController(cfg)
    c.decide(0, _demands(100.0), AVAIL)
    c.notify_solved(_demands(100.0), AVAIL)
    assert c.decide_event(100.0, 5, 10).resolve
    # too close to the last mid-epoch solve
    d = c.decide_event(110.0, 5, 10)
    assert not d.resolve and d.reason == "cooldown"
    assert c.decide_event(140.0, 0, 10).resolve
    # per-epoch budget exhausted
    d = c.decide_event(200.0, 9, 10)
    assert not d.resolve and d.reason == "cooldown"
    # the next epoch's decide() refreshes the budget
    c.decide(1, _demands(100.0), AVAIL)
    assert c.decide_event(300.0, 0, 10).resolve


# ---------------------------------------------------------- planner
def test_transition_planner_prefers_cheapest_transition(
        phi4_runtime_library):
    lib = phi4_runtime_library
    state = AllocatorState()
    wl = WLS[M]
    demands = [Demand(M, "prefill", 3.0 * wl.avg_prompt),
               Demand(M, "decode", 3.0 * wl.avg_output)]
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    from repro.core.allocator import AllocProblem
    alloc = state(AllocProblem(CORE_REGIONS, CONFIGS, avail, demands, lib))
    assert alloc.ok and alloc.instances
    planner = TransitionPlanner(lib, CORE_REGIONS, init_k=0.025)
    planner.record(alloc)
    cur = dict(alloc.instances)
    assert planner.churn_cost(cur, cur) == 0.0
    # reaching an empty cluster from the allocation costs drains only;
    # reaching the allocation from empty costs full init — more churn
    assert 0.0 < planner.churn_cost({}, cur) \
        < planner.churn_cost(cur, {})
    assert planner.choose_incumbent(cur) == cur


def test_allocator_accepts_external_incumbent(phi4_runtime_library):
    lib = phi4_runtime_library
    wl = WLS[M]
    demands = [Demand(M, "prefill", 2.0 * wl.avg_prompt),
               Demand(M, "decode", 2.0 * wl.avg_output)]
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    from repro.core.allocator import AllocProblem
    prob = AllocProblem(CORE_REGIONS, CONFIGS, avail, demands, lib)
    state = AllocatorState()
    base = state(prob)
    assert base.ok
    state.set_incumbent(base.instances)
    warm = state(prob)
    assert warm.ok
    assert state._pending_inc is None           # consumed by the solve
    # the warm-started solve reaches the same optimum
    assert warm.objective == pytest.approx(base.objective, rel=1e-4)


# ------------------------------------------------- scenarios + runtime
def test_scenarios_are_deterministic_and_consistent():
    models = {M: MODEL}
    for name in SCENARIO_NAMES:
        a = make_scenario(name, models, CORE_REGIONS, CONFIGS, WLS,
                          n_epochs=5, epoch_s=120.0, seed=4)
        b = make_scenario(name, models, CORE_REGIONS, CONFIGS, WLS,
                          n_epochs=5, epoch_s=120.0, seed=4)
        assert [(r.arrival, r.prompt_len) for r in a.requests] \
            == [(r.arrival, r.prompt_len) for r in b.requests]
        assert a.availability == b.availability
        assert len(a.availability) == 5 and len(a.truth_demands) == 5
        wl = WLS[M]
        for e, row in enumerate(a.truth_demands):
            dec = next(d for d in row if d.phase == "decode")
            assert dec.tokens_per_s \
                == pytest.approx(a.rates[M][e] * wl.avg_output)


def test_unknown_scenario_raises():
    with pytest.raises(ValueError):
        make_scenario("nope", {M: MODEL}, CORE_REGIONS, CONFIGS, WLS)


def _run_scenario(lib, name, *, oracle=False, n_epochs=5, base_rate=1.2,
                  seed=2):
    models = {M: MODEL}
    sc = make_scenario(name, models, CORE_REGIONS, CONFIGS, WLS,
                       n_epochs=n_epochs, epoch_s=180.0,
                       base_rate=base_rate, seed=seed)
    rt = ClusterRuntime(models, CORE_REGIONS, CONFIGS, lib,
                        AllocatorState(), WLS, epoch_s=sc.epoch_s,
                        spot_market=sc.spot_market)
    if oracle:
        res = rt.run(sc.requests, sc.availability, sc.truth_demands)
    else:
        res = rt.run(
            sc.requests, sc.availability,
            estimator=DemandEstimator([M], WLS),
            controller=ReSolveController(),
            planner=TransitionPlanner(lib, CORE_REGIONS, rt.init_k))
    return rt, res, sc


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_estimator_driven_runtime_on_all_scenarios(phi4_runtime_library,
                                                   name):
    """The closed loop runs end-to-end on every named scenario with NO
    oracle demands: the cluster bootstraps from the estimator prior,
    serves traffic, and the controller's decisions are observable."""
    rt, res, sc = _run_scenario(phi4_runtime_library, name)
    assert len(res.epochs) == sc.n_epochs
    assert res.epochs[0].trigger_reason == "initial"
    assert all(e.resolve_triggered == (e.trigger_reason not in
                                       ("steady", "cooldown"))
               for e in res.epochs)
    # the loop converges onto the workload: post-warmup epochs serve
    assert all(e.goodput[M] > 0 for e in res.epochs[2:])
    if not sc.spot_market:
        # demand-side scenarios: trigger-gating skips solves somewhere
        # (supply-side storms can legitimately fire every epoch)
        assert res.n_resolves() < sc.n_epochs
        assert rt.sim.dropped == 0


def test_spot_preemption_reclaims_and_recovers(phi4_runtime_library):
    rt, res, sc = _run_scenario(phi4_runtime_library, "spot_preemption",
                                n_epochs=8)
    assert sum(e.n_preempted for e in res.epochs) > 0
    # a preemption epoch is followed by a re-solve (never silently
    # absorbed by cadence-skipping)
    for e in res.epochs:
        if e.n_preempted:
            assert e.resolve_triggered
    assert res.epochs[-1].goodput[M] > 0


def test_flash_crowd_estimated_tracks_oracle(phi4_runtime_library):
    """Estimator-driven goodput stays within tolerance of the
    oracle-demand run on the flash-crowd scenario (the benchmark gates
    the tighter 15% envelope at core scale)."""
    _, res_o, sc = _run_scenario(phi4_runtime_library, "flash_crowd",
                                 oracle=True, n_epochs=8)
    _, res_e, _ = _run_scenario(phi4_runtime_library, "flash_crowd",
                                n_epochs=8)
    def cov(res):
        tot = c = 0.0
        for e in res.epochs[2:]:
            dem = sum(d.tokens_per_s for d in sc.truth_demands[e.epoch]
                      if d.phase == "decode")
            c += min(e.goodput[M], dem)
            tot += dem
        return c / tot
    assert cov(res_e) >= 0.75 * cov(res_o)


def test_fallback_solve_does_not_advance_controller(phi4_runtime_library):
    """A fallback (failed-HiGHS, incumbent-returned) solve is a usable
    target but NOT a solve: the controller's drift references must stay
    put so the trigger keeps firing until a real re-solve lands."""
    from repro.traces.workloads import gen_requests
    lib = phi4_runtime_library
    state = AllocatorState()
    calls = {"n": 0}

    def flaky(prob):
        calls["n"] += 1
        alloc = state(prob)
        if calls["n"] >= 2:
            alloc.fallback = True           # simulate a HiGHS failure
        return alloc

    notes = []

    class SpyController(ReSolveController):
        def notify_solved(self, demands, availability):
            notes.append(True)
            super().notify_solved(demands, availability)

    rt = ClusterRuntime({M: MODEL}, CORE_REGIONS, CONFIGS, lib, flaky,
                        WLS, epoch_s=180.0)
    wl = WLS[M]
    n = 3
    reqs = gen_requests(M, MODEL.trace, 1.5, n * 180.0, seed=0)
    avail = [{(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
             for _ in range(n)]
    ctrl = SpyController(ControllerConfig(max_interval_epochs=1,
                                          cooldown_epochs=0))
    res = rt.run(reqs, avail, estimator=DemandEstimator([M], WLS),
                 controller=ctrl)
    # every epoch re-solved (cadence 1), but only the first (healthy)
    # solve advanced the controller's references
    assert all(e.resolve_triggered for e in res.epochs)
    assert [e.solver_failed for e in res.epochs] == [False, True, True]
    assert len(notes) == 1


def test_mid_epoch_event_resolve(phi4_runtime_library):
    """A mid-epoch availability event (node failure) triggers an
    event-driven re-solve *inside* the epoch, visible as
    ``EpochMetrics.n_mid_resolves``, with the solve-time breakdown
    populated on every solved epoch."""
    from repro.traces.workloads import gen_requests
    lib = phi4_runtime_library
    rt = ClusterRuntime({M: MODEL}, CORE_REGIONS, CONFIGS, lib,
                        AllocatorState(), WLS, epoch_s=180.0)
    n = 4
    reqs = gen_requests(M, MODEL.trace, 1.5, n * 180.0, seed=0)
    avail = [{(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
             for _ in range(n)]
    ctrl = ReSolveController(ControllerConfig(
        event_loss_frac=0.0, min_event_gap_s=0.0))
    res = rt.run(reqs, avail, estimator=DemandEstimator([M], WLS),
                 controller=ctrl, fail_rate_per_epoch=1.0, seed=3)
    assert res.total_mid_resolves() > 0
    assert any(e.n_mid_resolves > 0 for e in res.epochs)
    for e in res.epochs:
        if e.resolve_triggered and not e.solver_failed:
            assert e.solve_path in ("decomposed", "rounded_lp",
                                    "monolithic")
            assert e.solve_ms >= 0.0 and e.assembly_ms >= 0.0
    p50, p95 = res.solve_ms_percentiles()
    assert 0.0 <= p50 <= p95
    assert sum(res.solve_path_counts().values()) \
        == sum(1 for e in res.epochs if e.solve_path)


def test_runresult_guards_empty_and_counts_resolves():
    empty = RunResult()
    assert empty.avg_cost() == 0.0
    assert empty.avg_goodput(M) == 0.0
    assert empty.n_resolves() == 0


def test_classic_oracle_path_reports_every_epoch_resolved(
        phi4_runtime_library):
    """The legacy oracle-demand path is unchanged: every epoch solves,
    tagged with the fixed-cadence reason."""
    from repro.traces.workloads import gen_requests
    lib = phi4_runtime_library
    rt = ClusterRuntime({M: MODEL}, CORE_REGIONS, CONFIGS, lib, allocate,
                        WLS, epoch_s=180.0)
    wl = WLS[M]
    reqs = gen_requests(M, MODEL.trace, 1.5, 2 * 180.0, seed=0)
    avail = [{(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
             for _ in range(2)]
    demands = [[Demand(M, "prefill", 1.5 * wl.avg_prompt),
                Demand(M, "decode", 1.5 * wl.avg_output)]] * 2
    res = rt.run(reqs, avail, demands)
    assert all(e.resolve_triggered and e.trigger_reason == "epoch"
               for e in res.epochs)
