"""corallint unit tests: positive/negative fixtures per rule,
suppression semantics, and the baseline round-trip (tools/corallint)."""
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.corallint import (ALL_CHECKERS, AccountingChecker,  # noqa: E402
                             DeterminismChecker, HygieneChecker,
                             LifecycleChecker, SolverChecker, lint_source,
                             load_baseline, save_baseline,
                             split_by_baseline)

SIM_PATH = "src/repro/simulator/sim.py"         # D1-critical, L1 home
CTRL_PATH = "src/repro/control/controller.py"   # D1- and S1-critical


def _rules(src, path, checkers=ALL_CHECKERS):
    return [f.rule for f in lint_source(src, path, checkers)]


# ------------------------------------------------------------------- D1
def test_d1_flags_wallclock_in_critical_dirs():
    src = "import time\nt = time.time()\n"
    assert _rules(src, SIM_PATH, [DeterminismChecker]) == ["D1"]


def test_d1_ignores_wallclock_outside_critical_dirs():
    src = "import time\nt = time.time()\n"
    assert _rules(src, "benchmarks/run.py", [DeterminismChecker]) == []


def test_d1_flags_unseeded_rng_and_set_iteration():
    src = ("import numpy as np\n"
           "rng = np.random.default_rng()\n"
           "for x in {1, 2, 3}:\n"
           "    heappush(q, x)\n")
    rules = _rules(src, CTRL_PATH, [DeterminismChecker])
    assert rules.count("D1") == 2


def test_d1_sorted_set_iteration_is_clean():
    src = ("for x in sorted({1, 2, 3}):\n"
           "    heappush(q, x)\n")
    assert _rules(src, CTRL_PATH, [DeterminismChecker]) == []


# ------------------------------------------------------------------- L1
def test_l1_flags_state_write_outside_sanctioned_methods():
    src = ("class Router:\n"
           "    def reroute(self, inst):\n"
           "        inst.dead = True\n")
    assert _rules(src, CTRL_PATH, [LifecycleChecker]) == ["L1"]


def test_l1_allows_sanctioned_transitions_in_sim():
    src = ("class Simulator:\n"
           "    def kill_instance(self, inst):\n"
           "        inst.dead = True\n"
           "    def __init__(self):\n"
           "        self.dead = False\n")
    assert _rules(src, SIM_PATH, [LifecycleChecker]) == []


# ------------------------------------------------------------------- A1
def test_a1_flags_float_accumulation_into_counter():
    src = "tokens_total += 0.5\n"
    assert _rules(src, SIM_PATH, [AccountingChecker]) == ["A1"]


def test_a1_flags_float_initialized_class_counter():
    src = ("class Log:\n"
           "    def __init__(self):\n"
           "        self.n_total = 0.0\n"
           "    def add(self):\n"
           "        self.n_total += 1\n")
    assert _rules(src, SIM_PATH, [AccountingChecker]) == ["A1"]


def test_a1_flags_rate_total_mixing():
    src = "x = tokens_per_s + tokens_out\n"
    assert _rules(src, SIM_PATH, [AccountingChecker]) == ["A1"]


def test_a1_ignores_float_cost_totals():
    src = ("total_cost += 0.25\n"
           "class M:\n"
           "    def __init__(self):\n"
           "        self.solve_seconds_total = 0.0\n"
           "    def add(self, s):\n"
           "        self.solve_seconds_total += s\n")
    assert _rules(src, SIM_PATH, [AccountingChecker]) == []


# ------------------------------------------------------------------- S1
def test_s1_flags_per_var_api_in_loop_on_epoch_paths():
    src = ("for d in demands:\n"
           "    mdl.add_constr([1.0], lb=0.0)\n")
    assert _rules(src, "src/repro/core/allocator.py",
                  [SolverChecker]) == ["S1"]


def test_s1_allows_per_var_api_off_epoch_paths():
    src = ("for d in demands:\n"
           "    mdl.add_constr([1.0], lb=0.0)\n")
    assert _rules(src, "src/repro/core/placement.py", [SolverChecker]) == []


def test_s1_flags_static_coo_shape_mismatch():
    src = "mdl.add_constrs_coo([1.0, 2.0], [0, 0, 1], [0, 1])\n"
    assert _rules(src, "tests/test_solver.py", [SolverChecker]) == ["S1"]


def test_s1_flags_unbounded_solve_on_epoch_paths():
    src = ("mdl = MilpModel()\n"
           "res = mdl.solve(gap=1e-4)\n")
    assert _rules(src, "src/repro/core/allocator.py",
                  [SolverChecker]) == ["S1"]


def test_s1_flags_unbounded_chained_solve():
    src = "res = MilpModel().solve()\n"
    assert _rules(src, "src/repro/control/controller.py",
                  [SolverChecker]) == ["S1"]


def test_s1_allows_solve_with_time_limit_and_off_epoch_paths():
    src = ("mdl = MilpModel()\n"
           "res = mdl.solve(time_limit=rem, gap=1e-4)\n")
    assert _rules(src, "src/repro/core/allocator.py", [SolverChecker]) == []
    # outside S1 scope an unbounded solve is fine (unit tests, offline)
    src2 = "res = MilpModel().solve()\n"
    assert _rules(src2, "tests/test_solver.py", [SolverChecker]) == []


def test_s1_solve_check_ignores_non_milp_objects():
    src = ("cache = PlacementCache()\n"
           "res = cache.solve(names)\n"
           "mdl = MilpModel()\n"
           "mdl = other_thing()\n"
           "res = mdl.solve()\n")        # rebound away from MilpModel
    assert _rules(src, "src/repro/core/allocator.py", [SolverChecker]) == []


def test_s1_decompose_module_is_in_scope():
    src = ("for r in rows:\n"
           "    mdl.add_var(0.0)\n")
    assert _rules(src, "src/repro/solver/decompose.py",
                  [SolverChecker]) == ["S1"]


# ------------------------------------------------------------------- P1
def test_p1_flags_mutable_defaults():
    src = ("def f(xs=[]):\n"
           "    return xs\n"
           "@dataclass\n"
           "class C:\n"
           "    ys: list = []\n")
    rules = _rules(src, "src/repro/core/templates.py", [HygieneChecker])
    assert rules.count("P1") == 2


def test_p1_clean_defaults_pass():
    src = ("def f(xs=None, n=3, s='a'):\n"
           "    return xs or []\n")
    assert _rules(src, "src/repro/core/templates.py",
                  [HygieneChecker]) == []


# ---------------------------------------------------------- suppressions
def test_trailing_suppression_covers_own_line():
    src = "import time\nt = time.time()  # corallint: disable=D1 - why\n"
    assert _rules(src, SIM_PATH, [DeterminismChecker]) == []


def test_standalone_suppression_covers_next_line_only():
    src = ("import time\n"
           "# corallint: disable=D1 - telemetry\n"
           "t = time.time()\n"
           "u = time.time()\n")
    assert _rules(src, SIM_PATH, [DeterminismChecker]) == ["D1"]


def test_suppression_is_rule_specific():
    src = "import time\nt = time.time()  # corallint: disable=A1\n"
    assert _rules(src, SIM_PATH, [DeterminismChecker]) == ["D1"]


# -------------------------------------------------------------- baseline
def test_baseline_round_trip(tmp_path):
    src = "import time\nt = time.time()\n"
    findings = lint_source(src, SIM_PATH, [DeterminismChecker])
    assert findings
    path = str(tmp_path / "baseline.json")
    save_baseline(path, findings)
    keys = load_baseline(path)
    assert keys == sorted({f.key for f in findings})
    new, accepted, stale = split_by_baseline(findings, keys)
    assert new == [] and accepted == findings and stale == []
    # an empty fresh run leaves the old keys stale
    new, accepted, stale = split_by_baseline([], keys)
    assert new == [] and accepted == [] and stale == keys


def test_repo_lints_clean_against_committed_baseline():
    """The acceptance criterion: the tree has zero unsuppressed,
    un-baselined findings."""
    from tools.corallint.base import lint_paths
    baseline = load_baseline(str(ROOT / "tools" / "corallint"
                                 / "baseline.json"))
    findings = lint_paths(["src", "tests", "benchmarks"], str(ROOT),
                          ALL_CHECKERS)
    new, _accepted, _stale = split_by_baseline(findings, baseline)
    assert new == [], [f"{f.rule}:{f.path}:{f.line} {f.message}"
                       for f in new]
