"""Fault-injection subsystem and failure-domain-aware recovery:
injector determinism, detection latency, restart backoff/budget,
admission control, stale feeds, the solver degradation ladder, and
batched-vs-oracle bit-equivalence under every injected fault class."""
import random
from types import SimpleNamespace

import numpy as np
import pytest

from repro.control import (FaultConfig, FaultInjector, ReSolveController,
                           RestartPolicy, goodput_lost, make_scenario,
                           time_to_recover)
from repro.control.controller import ControllerConfig
from repro.core.allocator import AllocatorState, AllocProblem, Demand, allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.runtime.cluster import ClusterRuntime, RunResult
from repro.simulator.sim import INIT_DELAY_S, ShedPolicy, Simulator
from repro.traces.workloads import gen_requests, workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
WLS = {MODEL.name: WL}
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
CFG_BY_NAME = {c.name: c for c in CONFIGS}

PRE, _ = generate_templates(MODEL, "prefill", CONFIGS, WL, n_max=2, rho=8.0)
DEC, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2, rho=8.0)
PRE.sort(key=lambda t: -t.throughput)
DEC.sort(key=lambda t: -t.throughput)


# ---------------------------------------------------- injector planning
def _stub(iid, region="r0", family="1xL40S"):
    return SimpleNamespace(
        iid=iid, region=region, dead=False, draining=False, failed=False,
        template=SimpleNamespace(counts=((family, 1),), key=(family,)))


def _plan_sig(inj, epoch, insts, epoch_s=240.0):
    return [(f.t, f.kind, f.inst.iid, f.factor, f.duration_s)
            for f in inj.plan_epoch(epoch, epoch * epoch_s, epoch_s, insts)]


def test_injector_is_deterministic_and_streams_are_independent():
    cfg = FaultConfig(seed=11, crash_rate=0.3, burst_rate=0.5,
                      straggler_rate=0.2, restart_flake_p=0.5,
                      feed_lag_epochs=1)
    insts = [_stub(i, family="1xL40S" if i % 2 else "1xL4")
             for i in range(8)]
    a, b, c = FaultInjector(cfg), FaultInjector(cfg), FaultInjector(cfg)
    for e in range(4):
        sa = _plan_sig(a, e, insts)
        # b interleaves restart draws and feed reads — the plan stream
        # must not notice (independent RNGs per fault class)
        for _ in range(3):
            b.restart_outcome()
        b.observed_availability(e, {("r0", "1xL40S"): e})
        assert sa == _plan_sig(b, e, insts) == _plan_sig(c, e, insts)
    assert a.events == c.events
    assert a.first_fault_t == c.first_fault_t


def test_injector_window_and_liveness_filters():
    cfg = FaultConfig(seed=0, crash_rate=1.0, start_epoch=2, stop_epoch=3)
    inj = FaultInjector(cfg)
    insts = [_stub(i) for i in range(4)]
    # corallint: disable=L1 - stub topology setup on SimpleNamespace
    insts[1].dead = True
    insts[2].draining = True    # corallint: disable=L1 - stub setup
    insts[3].failed = True      # corallint: disable=L1 - stub setup
    assert inj.plan_epoch(0, 0.0, 240.0, insts) == []
    assert inj.plan_epoch(1, 240.0, 240.0, insts) == []
    ev = inj.plan_epoch(2, 480.0, 240.0, insts)
    # only the live instance crashes, inside the epoch window
    assert [f.inst.iid for f in ev] == [0]
    assert 480.0 <= ev[0].t <= 720.0
    assert inj.plan_epoch(3, 720.0, 240.0, insts) == []
    assert inj.first_fault_t == ev[0].t


def test_burst_hits_one_failure_domain_at_one_instant():
    cfg = FaultConfig(seed=5, burst_rate=1.0, burst_frac=1.0)
    inj = FaultInjector(cfg)
    insts = ([_stub(i, region="r0", family="1xL40S") for i in range(3)]
             + [_stub(i + 3, region="r1", family="1xL4") for i in range(3)])
    ev = inj.plan_epoch(0, 0.0, 240.0, insts)
    doms = {(f.inst.region, f.inst.template.counts[0][0]) for f in ev}
    assert len(doms) == 1, "a burst stays inside one (region, family)"
    assert len({f.t for f in ev}) == 1, "a burst is a single instant"
    assert len(ev) == 3                 # burst_frac=1.0: whole domain


def test_stale_feed_lags_and_sticks_without_mutating_truth():
    truth = [{("r0", "1xL40S"): e} for e in range(5)]
    lag = FaultInjector(FaultConfig(seed=0, feed_lag_epochs=2,
                                    start_epoch=1))
    assert lag.observed_availability(0, truth[0]) == truth[0]
    assert lag.observed_availability(1, truth[1]) == truth[0]
    assert lag.observed_availability(2, truth[2]) == truth[0]
    assert lag.observed_availability(3, truth[3]) == truth[1]
    stuck = FaultInjector(FaultConfig(seed=0, feed_stale_p=1.0,
                                      start_epoch=1))
    assert stuck.observed_availability(0, truth[0]) == truth[0]
    for e in range(1, 5):   # the feed never refreshes again
        assert stuck.observed_availability(e, truth[e]) == truth[0]
        assert truth[e] == {("r0", "1xL40S"): e}, "truth never mutated"


# ------------------------------------------------------- restart policy
def test_restart_policy_backoff_budget_and_streak_reset():
    pol = RestartPolicy(backoff_base_s=10.0, backoff_mult=2.0,
                        backoff_max_s=35.0, budget_per_epoch=2)
    k = ("r0", ("dec",))
    assert pol.delay(k) == 10.0
    pol.note_restart(k)
    assert pol.delay(k) == 20.0
    pol.note_restart(k)
    assert pol.delay(k) == 35.0         # capped below 10 * 2**2
    # budget: two restarts per epoch, then denial until the epoch edge
    assert pol.allow() and pol.allow() and not pol.allow()
    pol.begin_epoch(failed_keys=[k])    # still failing: streak survives
    assert pol.allow()
    assert pol.delay(k) == 35.0
    pol.begin_epoch(failed_keys=[])     # a clean epoch clears the streak
    assert pol.delay(k) == 10.0


def test_restart_policy_defaults_are_immediate():
    pol = RestartPolicy()
    assert pol.delay(("r0", ("x",))) == 0.0
    assert all(pol.allow() for _ in range(1000))


# ----------------------------------------------------- recovery metrics
def test_time_to_recover_and_goodput_lost():
    times = [10.0, 20.0, 30.0, 40.0]
    vals = [0.95, 0.5, 0.7, 0.93]
    # outage onset at t=20: the pre-dip sample at t=10 does not count
    assert time_to_recover(times, vals, 0.0, 0.9) == 40.0
    assert time_to_recover(times, vals, 15.0, 0.9) == 25.0
    assert time_to_recover(times, vals, 15.0, 0.99) == float("inf")
    assert time_to_recover(times, vals, 35.0, 0.9) == 0.0, "never dips"
    # sustained recovery: a lone good sample inside the outage does not
    # close it; a terminal good run shorter than `sustain` does
    t2 = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0]
    v2 = [0.95, 0.5, 0.92, 0.5, 0.93, 0.94]
    assert time_to_recover(t2, v2, 0.0, 0.9, sustain=2) == 50.0
    assert time_to_recover(t2, v2, 0.0, 0.9, sustain=3) == 50.0
    assert time_to_recover(t2, v2, 0.0, 0.9, sustain=1) == 30.0
    lost = goodput_lost(times, vals, 0.9, 15.0, 10.0)
    assert lost == pytest.approx((0.4 + 0.2) * 10.0)
    assert goodput_lost(times, vals, 0.0, 0.0, 10.0) == 0.0


# ------------------------------------------------- simulator: detection
def _sim(batched=True):
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, WLS, batched=batched)
    sim.add_instance("r0", PRE[0], ready_delay=0.0)
    return sim


def test_crash_black_holes_until_probe_fires():
    """A crashed-but-undetected decode node keeps receiving routed
    requests and serves nothing; the probe's kill_instance re-routes the
    accumulated queue and the run still finishes everything."""
    sim = _sim()
    victim = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    other = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    reqs = gen_requests(MODEL.name, MODEL.trace, 2.0, 120, seed=9)
    for r in reqs:
        sim.submit(r)
    sim.run_until(60.0)
    tokens_at_crash = victim.tokens_out
    t_det = sim.crash_instance(victim, detect_s=90.0)
    assert t_det == pytest.approx(150.0)
    assert victim.failed and not victim.dead
    # double crash is a no-op (overlapping fault processes compose)
    assert sim.crash_instance(victim, detect_s=10.0) == sim.now
    sim.run_until(t_det - 1e-6)
    assert not victim.dead, "undetected until the probe"
    assert victim.tokens_out == tokens_at_crash, "black hole serves nothing"
    sim.run_until(t_det + 1e-6)
    assert victim.dead
    sim.run_until(7200.0)
    assert sim.dropped == 0
    assert {r.rid for r in sim.finished} == {r.rid for r in reqs}
    assert other.tokens_out > 0


def test_crash_with_zero_detect_is_kill():
    sim = _sim()
    inst = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    assert sim.crash_instance(inst, detect_s=0.0) == sim.now
    assert inst.dead and not inst.failed


# ------------------------------------------------ simulator: stragglers
def test_straggler_degrades_and_recovers():
    sim = _sim()
    slow = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    fast = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    for r in gen_requests(MODEL.name, MODEL.trace, 4.0, 240, seed=4):
        sim.submit(r)
    sim.run_until(30.0)
    sim.degrade_instance(slow, 8.0, duration_s=120.0)
    assert slow.slow_factor == 8.0
    # straggler-aware router steers toward the healthy instance
    assert sim.route(MODEL.name, "decode") is fast
    sim.run_until(200.0)                # past now+duration: recovered
    assert slow.slow_factor == 1.0
    sim.run_until(7200.0)
    assert sim.dropped == 0
    assert fast.tokens_out > slow.tokens_out


def test_degrade_noops_on_failed_and_dead():
    sim = _sim()
    inst = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    sim.crash_instance(inst, detect_s=50.0)
    sim.degrade_instance(inst, 4.0)
    assert inst.slow_factor == 1.0
    sim.run_until(100.0)                # probe fired: dead now
    sim.degrade_instance(inst, 4.0)
    assert inst.slow_factor == 1.0


# ------------------------------------------- simulator: admission shed
def test_shed_policy_bounds_prefill_backlog():
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, WLS)
    sim.shed_policy = ShedPolicy(max_queue_per_instance=2.0)
    sim.add_instance("r0", PRE[1], ready_delay=0.0)     # weakest prefill
    sim.add_instance("r0", DEC[0], ready_delay=0.0)
    reqs = gen_requests(MODEL.name, MODEL.trace, 20.0, 60, seed=6)
    for r in reqs:
        sim.submit(r)
    sim.run_until(3600.0)
    assert sim.shed > 0
    assert sim.shed_by_model[MODEL.name] == sim.shed
    # shed arrivals are counted, not silently dropped
    assert sim.dropped == 0
    assert len(sim.finished) + sim.shed == len(reqs)


def test_shed_policy_off_by_default():
    sim = _sim()
    assert sim.shed_policy is None and sim.shed == 0


# ------------------------------------- batched vs oracle, faults active
def _fault_gauntlet(batched):
    """Crash-with-latency, straggler, shed, and a replacement — the
    full fault surface in one seeded run."""
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, WLS, batched=batched)
    sim.shed_policy = ShedPolicy(max_queue_per_instance=24.0)
    sim.add_instance("r0", PRE[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", PRE[1], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[1], ready_delay=INIT_DELAY_S)
    reqs = gen_requests(MODEL.name, MODEL.trace, 3.0, 300, seed=13)
    for r in reqs:
        sim.submit(r)
    sim.run_until(120.0)
    sim.crash_instance(sim.instances[2], detect_s=90.0)   # decode crash
    sim.run_until(150.0)
    sim.degrade_instance(sim.instances[3], 3.0, duration_s=120.0)
    sim.run_until(200.0)
    sim.crash_instance(sim.instances[0], detect_s=60.0)   # prefill crash
    sim.run_until(240.0)
    sim.add_instance("r0", DEC[0])          # replacement pays INIT_DELAY
    for t in (360.0, 480.0, 3600.0):
        sim.run_until(t)
    return sim, reqs


def test_batched_oracle_equivalence_under_faults():
    """The batched loop stays bit-identical with the per-iteration
    oracle under every injected fault class at once: same finished set,
    same sheds, same per-request counters, same goodput windows."""
    s1, r1 = _fault_gauntlet(batched=False)
    s2, r2 = _fault_gauntlet(batched=True)
    m = MODEL.name
    assert s1.dropped == s2.dropped
    assert s1.shed == s2.shed > 0
    assert {r.rid for r in s1.finished} == {r.rid for r in s2.finished}
    assert len(s1.tokens[m]) == len(s2.tokens[m])
    fin = {r.rid for r in s1.finished}
    d1 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r1 if r.rid in fin}
    d2 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r2 if r.rid in fin}
    assert d1 == d2
    for t0 in range(0, 3600, 60):
        assert s1.goodput(m, t0, t0 + 60) == s2.goodput(m, t0, t0 + 60)
        assert s1.throughput(m, t0, t0 + 60) == \
            s2.throughput(m, t0, t0 + 60)


# ------------------------------------------------- runtime: restarts
def test_spot_fail_instance_respects_reclaimed_supply(
        phi4_runtime_library):
    """Regression: under spot_market=True, fail_instance used to start
    a replacement unconditionally — conjuring capacity on a fully
    reclaimed (region, config) that the provider no longer sells."""
    rt = ClusterRuntime({MODEL.name: MODEL}, CORE_REGIONS, CONFIGS,
                        phi4_runtime_library, allocate, WLS,
                        epoch_s=240.0, spot_market=True)
    region = CORE_REGIONS[0].name
    inst = rt.sim.add_instance(region, DEC[0])
    rt.running[(region, DEC[0].key)] = [inst]
    rt.sim.run_until(INIT_DELAY_S + 1.0)
    rt._epoch_avail = {}                # the supply is fully reclaimed
    rng = random.Random(0)
    assert rt.fail_instance(rng) is inst and inst.dead
    assert not [i for i in rt.sim.instances.values() if not i.dead], \
        "no replacement may be conjured out of reclaimed supply"
    # with supply back, the same failure path restarts a replacement
    inst2 = rt.sim.add_instance(region, DEC[0])
    rt.running[(region, DEC[0].key)].append(inst2)
    rt.sim.run_until(rt.sim.now + INIT_DELAY_S + 1.0)
    rt._epoch_avail = {(region, c.name): 99 for c in CONFIGS}
    assert rt.fail_instance(rng) is inst2
    live = [i for i in rt.sim.instances.values() if not i.dead]
    assert len(live) == 1 and live[0].template is inst2.template


def test_restart_budget_and_backoff_defer_replacements(
        phi4_runtime_library):
    """A zero-budget policy leaves detected failures unhealed mid-epoch;
    a backoff policy restarts them later, not instantly."""
    region = CORE_REGIONS[0].name

    def make_rt(policy):
        rt = ClusterRuntime({MODEL.name: MODEL}, CORE_REGIONS, CONFIGS,
                            phi4_runtime_library, allocate, WLS,
                            epoch_s=240.0, health_check_s=10.0,
                            restart_policy=policy)
        inst = rt.sim.add_instance(region, DEC[0])
        rt.running[(region, DEC[0].key)] = [inst]
        rt.sim.run_until(INIT_DELAY_S + 1.0)
        return rt, inst

    rt, inst = make_rt(RestartPolicy(budget_per_epoch=0))
    rt._crash(inst)
    rt.sim.run_until(rt.sim.now + 3600.0)
    assert inst.dead and rt._epoch_failed == 1
    assert rt._epoch_restarted == 0, "budget 0 must block the restart"

    rt, inst = make_rt(RestartPolicy(backoff_base_s=200.0))
    t_crash = rt.sim.now
    rt._crash(inst)
    rt.sim.run_until(t_crash + 100.0)   # probe (10s) fired, backoff not
    assert inst.dead and rt._epoch_restarted == 0
    rt.sim.run_until(t_crash + 400.0)
    assert rt._epoch_restarted == 1
    repl = [i for i in rt.sim.instances.values() if not i.dead]
    assert len(repl) == 1
    assert repl[0].ready_at >= t_crash + 10.0 + 200.0


def test_runtime_crash_storm_recovers(phi4_runtime_library):
    """End-to-end: the hardened runtime detects a correlated burst,
    restarts within policy, surfaces the recovery in EpochMetrics, and
    the failure-triggered controller re-solve fires."""
    n_epochs = 6
    sc = make_scenario("crash_storm", {MODEL.name: MODEL}, CORE_REGIONS,
                       CONFIGS, WLS, n_epochs=n_epochs, epoch_s=240.0,
                       base_rate=1.5, seed=3)
    rt = ClusterRuntime({MODEL.name: MODEL}, CORE_REGIONS, CONFIGS,
                        phi4_runtime_library, AllocatorState(), WLS,
                        epoch_s=sc.epoch_s, health_check_s=15.0,
                        restart_policy=RestartPolicy(backoff_base_s=20.0,
                                                     budget_per_epoch=4),
                        shed_policy=ShedPolicy(32.0))
    ctrl = ReSolveController(ControllerConfig())
    res = rt.run(sc.requests, sc.availability, sc.truth_demands,
                 controller=ctrl, fault_injector=FaultInjector(sc.faults))
    assert len(res.epochs) == n_epochs
    assert res.total_failed() > 0
    assert res.total_restarted() > 0
    assert res.recovery_epochs() >= 1
    storm = sc.faults.start_epoch
    detected = [e.epoch for e in res.epochs if e.n_failed > 0]
    # detection happens in the storm epoch, or one later if the burst
    # landed within health_check_s of the epoch edge
    assert detected and storm <= detected[0] <= storm + 1
    assert res.epochs[detected[0]].recovering
    # detection feeds the controller: the epoch after it re-solves with
    # the dedicated failure trigger
    assert res.epochs[detected[0] + 1].trigger_reason == "failure"
    # the cluster comes back: the final epoch serves and is not
    # flagged as still recovering
    assert res.epochs[-1].goodput[MODEL.name] > 0
    assert not res.epochs[-1].recovering
    assert all(e.alloc_source in ("solved", "fallback", "last_good",
                                  "kept", "none") for e in res.epochs)


def test_runresult_fault_aggregates_guard_empty():
    r = RunResult()
    assert r.total_failed() == 0
    assert r.total_restarted() == 0
    assert r.total_shed() == 0
    assert r.recovery_epochs() == 0


# --------------------------------------------- solver degradation ladder
def test_solver_timeout_returns_incumbent_fallback(phi4_runtime_library):
    """The middle rung of the degradation ladder: a deadline-bounded
    solve that expires returns the incumbent (Allocation.fallback),
    preserves AllocatorState for the next epoch, and never raises."""
    lib = phi4_runtime_library
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    demands = [Demand(MODEL.name, "prefill", 2.0 * WL.avg_prompt),
               Demand(MODEL.name, "decode", 2.0 * WL.avg_output)]
    state = AllocatorState()
    good = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                              lib, time_limit=60.0))
    assert good.ok and not good.fallback
    x_before = state._prev_x.copy()
    # pathologically small deadline: HiGHS expires before any solution
    tiny = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                              lib, time_limit=1e-9))
    assert tiny.ok and tiny.fallback
    assert tiny.instances == good.instances, \
        "the fallback is the repaired incumbent, not a fresh solve"
    assert np.array_equal(state._prev_x, x_before), \
        "state survives the timeout for the next epoch's warm start"
    # and the ladder's bottom rung: no incumbent at all -> not-ok
    # allocation with the full demand declared unmet, still no raise
    fresh = AllocatorState()
    dead = fresh(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                              lib, time_limit=1e-9))
    assert not dead.ok and not dead.instances
    assert set(dead.unmet) == {(MODEL.name, "prefill"),
                               (MODEL.name, "decode")}


def test_solver_crash_is_treated_as_timeout(phi4_runtime_library,
                                            monkeypatch):
    """A raising solver backend walks the same ladder as a timeout
    instead of propagating into the epoch loop.  Forced monolithic: in
    auto mode the decomposed tier (which never touches ``MilpModel``)
    would simply absorb the crash — that resilience is covered by
    tests/test_allocator.py::test_degradation_ladder."""
    from repro.solver.milp import MilpModel
    lib = phi4_runtime_library
    avail = {(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
    demands = [Demand(MODEL.name, "prefill", 2.0 * WL.avg_prompt),
               Demand(MODEL.name, "decode", 2.0 * WL.avg_output)]
    state = AllocatorState()
    good = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands,
                              lib, time_limit=60.0,
                              solve_mode="monolithic"))
    assert good.ok

    def boom(self, **kw):
        raise RuntimeError("backend crashed")

    monkeypatch.setattr(MilpModel, "solve", boom)
    alloc = state(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                               demands, lib, time_limit=60.0,
                               solve_mode="monolithic"))
    assert alloc.ok and alloc.fallback
    assert alloc.instances == good.instances
