"""Loop-aware HLO cost analysis: trip-count scaling + dot accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyse_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = analyse_hlo(_compile(lambda x, y: x @ y, a, b).as_text())
    assert c.flops == 2 * 64 * 128 * 32


def test_scan_trip_count_scaling():
    """flops must scale ~linearly with lax.scan length."""
    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    w2 = jax.ShapeDtypeStruct((32, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)

    def run(p, x0):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x0, p)
        return h

    f1 = analyse_hlo(_compile(run, w, x).as_text()).flops
    f2 = analyse_hlo(_compile(run, w2, x).as_text()).flops
    assert f2 / f1 == pytest.approx(4.0, rel=0.2), (f1, f2)
    assert f1 >= 8 * 2 * 4 * 64 * 64          # at least the 8 matmuls


def test_model_forward_matches_2nd():
    """Dense LM forward ~ 2*N*D within 30% (attention/logits excess)."""
    from repro.configs.registry import get_smoke_config
    from repro.models import api as mapi
    cfg = get_smoke_config("qwen2-1.5b").with_(n_layers=4, remat=False)
    model = mapi.get_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg)[0],
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
    compiled = _compile(lambda p, b: model.forward(p, cfg, b)[0],
                        shapes, batch)
    c = analyse_hlo(compiled.as_text())
    expect = 2 * cfg.param_count() * 2 * 64
    assert c.flops == pytest.approx(expect, rel=0.3)
    assert c.traffic > 0


def test_traffic_counts_operands_and_results():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = analyse_hlo(_compile(lambda x: x + 1.0, a).as_text())
    # at least read + write of the 256KB tensor
    assert c.traffic >= 2 * 256 * 256 * 4
