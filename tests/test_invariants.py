"""CORAL_SANITIZE=1 invariant sanitizer (repro.debug.invariants): a
clean run stays silent; broken conservation laws, forbidden lifecycle
transitions, and out-of-budget allocations raise InvariantViolation."""
import pytest

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.debug import invariants as inv
from repro.debug.invariants import InvariantViolation
from repro.simulator.sim import Simulator
from repro.traces.workloads import gen_requests, workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
CFG_BY_NAME = {c.name: c for c in CONFIGS}

PRE, _ = generate_templates(MODEL, "prefill", CONFIGS, WL, n_max=2, rho=8.0)
DEC, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2, rho=8.0)
PRE.sort(key=lambda t: -t.throughput)
DEC.sort(key=lambda t: -t.throughput)


@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("CORAL_SANITIZE", "1")


def _run_sim(duration=60.0, rate=1.0, seed=0):
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                    batched=True)
    sim.add_instance("r0", PRE[0], ready_delay=0.0)
    sim.add_instance("r0", DEC[0], ready_delay=0.0)
    for r in gen_requests(MODEL.name, MODEL.trace, rate=rate,
                          duration=duration, seed=seed):
        sim.submit(r)
    sim.run_until(3600.0)
    return sim


def test_flag_gates_the_sanitizer(monkeypatch):
    monkeypatch.delenv("CORAL_SANITIZE", raising=False)
    assert not inv.sanitize_enabled()
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL})
    assert sim._san is None
    monkeypatch.setenv("CORAL_SANITIZE", "0")
    assert not inv.sanitize_enabled()
    monkeypatch.setenv("CORAL_SANITIZE", "1")
    assert inv.sanitize_enabled()


def test_clean_run_is_silent(sanitized):
    sim = _run_sim()
    assert sim._san is not None
    assert len(sim.finished) > 0          # the run did real work
    sim._san.check_sim(sim)               # and re-auditing it is silent


def test_catches_broken_token_conservation(sanitized):
    sim = _run_sim()
    inst = next(i for i in sim.instances.values()
                if i.phase == "decode")
    inst.tokens_out += 5                  # cook the books
    with pytest.raises(InvariantViolation, match="token conservation"):
        sim._san.check_sim(sim)


def test_catches_broken_request_conservation(sanitized):
    sim = _run_sim()
    sim.finished.pop()                    # lose a finished request
    with pytest.raises(InvariantViolation, match="request conservation"):
        sim._san.check_sim(sim)


def test_catches_resurrected_instance(sanitized):
    sim = _run_sim(duration=20.0)
    victim = next(i for i in sim.instances.values()
                  if i.phase == "decode")
    sim.kill_instance(victim)
    sim.run_until(3700.0)                 # records the death
    # forbidden transition: dead instances never come back
    victim.dead = False     # corallint: disable=L1 - deliberate breakage
    with pytest.raises(InvariantViolation, match="resurrected"):
        sim._san.check_sim(sim)


def test_catches_dead_instance_left_routable(sanitized):
    sim = _run_sim(duration=20.0)
    inst = next(i for i in sim.instances.values()
                if i.phase == "prefill")
    inst.dead = True        # corallint: disable=L1 - deliberate breakage
    with pytest.raises(InvariantViolation, match="routable"):
        sim._san.check_sim(sim)


def test_catches_occupancy_overflow(sanitized):
    sim = _run_sim(duration=20.0)
    inst = next(i for i in sim.instances.values()
                if i.phase == "decode")
    cap = inst.cm.decode_capacity
    pad = cap + 1 - len(inst.resident)
    inst.resident += [(10**9, None, 0, 0)] * pad
    inst.res_keys += [10**9] * pad
    with pytest.raises(InvariantViolation, match="decode_capacity"):
        sim._san.check_sim(sim)


def test_heap_time_monotonicity():
    san = inv.SimSanitizer()
    san.note_pop(5.0, 4.0)                # future event: fine
    with pytest.raises(InvariantViolation, match="went backwards"):
        san.note_pop(3.0, 4.0)            # behind the clock: not fine


# ------------------------------------------------------- control plane
class _Demand:
    def __init__(self, tps):
        self.model, self.phase, self.tokens_per_s = "m", "decode", tps


def test_check_demands():
    inv.check_demands([_Demand(0.0), _Demand(123.4)])
    with pytest.raises(InvariantViolation):
        inv.check_demands([_Demand(-1.0)])
    with pytest.raises(InvariantViolation):
        inv.check_demands([_Demand(float("nan"))])


class _Tmpl:
    def __init__(self, counts):
        self.counts = counts


class _Alloc:
    def __init__(self, instances, templates):
        self.instances, self.templates = instances, templates


def test_check_allocation_against_availability():
    alloc = _Alloc({("r0", "k"): 2}, {"k": _Tmpl((("L4x1", 2),))})
    inv.check_allocation(alloc, {("r0", "L4x1"): 4})
    with pytest.raises(InvariantViolation, match="available"):
        inv.check_allocation(alloc, {("r0", "L4x1"): 3})
    with pytest.raises(InvariantViolation, match="non-negative"):
        inv.check_allocation(_Alloc({("r0", "k"): -1}, {}), {})


def test_check_holdings():
    inv.check_holdings({("r0", "L4x1"): 2}, {("r0", "L4x1"): 2})
    with pytest.raises(InvariantViolation, match="physical supply"):
        inv.check_holdings({("r0", "L4x1"): 3}, {("r0", "L4x1"): 2})


class _Metrics:
    epoch = 0
    cost_per_hour = 1.0
    init_cost = 0.0
    solve_seconds = 0.1
    assembly_ms = solve_ms = extract_ms = 0.0
    solve_path = "decomposed"
    n_instances = n_new = n_drained = 0
    n_preempted = n_failed = n_restarted = n_shed = 0
    n_mid_resolves = 0
    goodput = {"m": 5.0}
    throughput = {"m": 6.0}
    unmet = {}


def test_check_epoch_metrics():
    inv.check_epoch_metrics(_Metrics())
    bad = _Metrics()
    bad.goodput = {"m": 7.0}              # goodput above throughput
    with pytest.raises(InvariantViolation, match="exceeds throughput"):
        inv.check_epoch_metrics(bad)
    worse = _Metrics()
    worse.n_shed = -1
    with pytest.raises(InvariantViolation, match="n_shed"):
        inv.check_epoch_metrics(worse)
