"""Per-kernel correctness sweeps: Pallas (interpret mode) vs ref oracle
across shapes and dtypes, plus gradient checks through the custom vjps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.mamba_scan import ops as ms_ops
from repro.kernels.mamba_scan import ref as ms_ref
from repro.kernels.moe_gmm import ops as gmm_ops


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------------ flash
@pytest.mark.parametrize("B,S,H,KH,D", [
    (1, 128, 1, 1, 64), (2, 256, 4, 2, 64), (1, 256, 8, 8, 128),
    (2, 128, 6, 2, 32),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, KH, D, causal, window, dtype):
    rng = np.random.default_rng(hash((B, S, H, KH, D, causal, window)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    ref = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 impl="ref")
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad_matches_ref():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 32)), jnp.float32)

    def loss(impl):
        return lambda q_, k_, v_: fa_ops.flash_attention(
            q_, k_, v_, impl=impl).sum()

    g1 = jax.grad(loss("pallas_interpret"), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss("ref"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_chunked_mha_matches_full():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 256, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 16)), jnp.float32)
    for window in (0, 48):
        full = fa_ref.mha_reference(q, k, v, causal=True, window=window)
        chk = fa_ref.mha_chunked(q, k, v, causal=True, window=window,
                                 chunk=64)
        np.testing.assert_allclose(chk, full, atol=1e-5, rtol=1e-5)


# ----------------------------------------------------------------- decode
@pytest.mark.parametrize("B,H,KH,D,S", [
    (2, 4, 2, 64, 512), (1, 8, 1, 128, 256), (3, 6, 6, 32, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, H, KH, D, S, dtype):
    rng = np.random.default_rng(hash((B, H, KH, D, S)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, H, D)), dtype)
    kc = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    vc = jnp.asarray(rng.normal(size=(B, S, KH, D)), dtype)
    lens = jnp.asarray(rng.integers(1, S + 1, size=(B,)), jnp.int32)
    for window in (0, 64):
        ref = da_ops.decode_attention(q, kc, vc, lens, window=window,
                                      impl="ref")
        out = da_ops.decode_attention(q, kc, vc, lens, window=window,
                                      impl="pallas_interpret")
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), **_tol(dtype))


# -------------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,H,P,N", [
    (1, 64, 2, 16, 16), (2, 128, 3, 16, 32), (1, 128, 1, 64, 64),
])
def test_ssd_sweep(B, S, H, P, N):
    rng = np.random.default_rng(hash((B, S, H, P, N)) % 2**31)
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y_ref, s_ref = ms_ref.ssd_reference(x, dt, A, Bm, Cm, D)
    y_chk, s_chk = ms_ref.ssd_chunked_reference(x, dt, A, Bm, Cm, D, chunk=32)
    y_pl, s_pl = ms_ops.ssd_scan(x, dt, A, Bm, Cm, D,
                                 impl="pallas_interpret", with_state=True)
    np.testing.assert_allclose(y_chk, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_pl, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s_pl, s_ref, atol=1e-4, rtol=1e-4)


def test_ssd_decode_continuation():
    """prefill final state + decode step == full-sequence scan."""
    rng = np.random.default_rng(5)
    B, S, H, P, N = 1, 33, 2, 8, 8
    x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    y_all, _ = ms_ref.ssd_reference(x, dt, A, Bm, Cm, D)
    _, s_pre = ms_ref.ssd_reference(x[:, :-1], dt[:, :-1], A, Bm[:, :-1],
                                    Cm[:, :-1], D)
    y_step, _ = ms_ref.ssd_decode_step(s_pre, x[:, -1], dt[:, -1], A,
                                       Bm[:, -1], Cm[:, -1], D)
    np.testing.assert_allclose(y_step, y_all[:, -1], atol=1e-5, rtol=1e-5)


# -------------------------------------------------------------------- gmm
@pytest.mark.parametrize("E,C,d,f", [(2, 32, 16, 16), (4, 64, 96, 160),
                                     (8, 128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, C, d, f, dtype):
    rng = np.random.default_rng(hash((E, C, d, f)) % 2**31)
    x = jnp.asarray(rng.normal(size=(E, C, d)), dtype)
    w = jnp.asarray(rng.normal(size=(E, d, f)), dtype)
    ref = gmm_ops.grouped_matmul(x, w, impl="ref")
    out = gmm_ops.grouped_matmul(x, w, impl="pallas_interpret")
    tol = dict(atol=1e-1, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


def test_gmm_grad():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 16, 8)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(2, 8, 12)), jnp.float32)
    g1 = jax.grad(lambda a, b: gmm_ops.grouped_matmul(
        a, b, impl="pallas_interpret").sum(), argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda a, b: gmm_ops.grouped_matmul(
        a, b, impl="ref").sum(), argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=1e-5)
