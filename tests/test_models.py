"""Per-architecture smoke tests (reduced configs, deliverable f) and
family-level prefill/decode consistency properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.configs.base import SHAPES, cell_is_runnable
from repro.models import api as mapi
from repro.train import optimizer as opt
from repro.train import steps


def _batch_for(cfg, B, S, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.n_vision_tokens,
                                           cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = mapi.get_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)
    logits, aux = model.forward(params, cfg, batch)
    S_total = S + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape[0] == B and logits.shape[1] == S_total
    assert logits.shape[2] >= cfg.vocab_size
    assert not bool(jnp.isnan(logits).any())

    batch["labels"] = batch["tokens"]
    oc = opt.OptConfig(total_steps=4, warmup_steps=1)
    ts = steps.make_train_step(cfg, oc)
    p2, o2, m = ts(params, opt.init_opt_state(params), batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_matches_forward(arch):
    """decode_step(prefill(prompt)) must agree with teacher forcing."""
    cfg = get_smoke_config(arch)
    if cfg.family == "vlm":
        pytest.skip("vlm decode positions differ from fused fwd (M-RoPE)")
    if cfg.family == "moe":
        # capacity-dropping differs between teacher-forcing and decode by
        # construction; disable drops for the consistency check
        cfg = cfg.with_(moe_capacity_factor=100.0)
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    batch = _batch_for(cfg, B, S)
    lp, cache = model.prefill(params, cfg, batch)
    nxt = jnp.argmax(lp[:, :cfg.vocab_size], -1)
    if "k" in cache and cache["k"].ndim == 5:
        pad = ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0))
        cache = dict(cache, k=jnp.pad(cache["k"], pad),
                     v=jnp.pad(cache["v"], pad))
    ld, cache = model.decode_step(params, cfg, cache, nxt)
    batch2 = dict(batch, tokens=jnp.concatenate(
        [batch["tokens"], nxt[:, None]], 1))
    logits2, _ = model.forward(params, cfg, batch2)
    np.testing.assert_allclose(ld, logits2[:, -1], atol=2e-4, rtol=2e-3)


def test_full_configs_match_assignment():
    """The full (dry-run) configs carry the exact assigned shapes."""
    spec = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }
    for arch, (L, d, H, KV, f, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, KV, f, V), arch
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").top_k == 8
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4


def test_long_context_skip_policy():
    """long_500k runs only for recurrent (SSM/hybrid) archs."""
    runnable = {}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shape = [s for s in SHAPES if s.name == "long_500k"][0]
        runnable[arch] = cell_is_runnable(cfg, shape)[0]
    assert runnable["zamba2-1.2b"] and runnable["xlstm-350m"]
    for arch in ("qwen2-1.5b", "glm4-9b", "dbrx-132b", "whisper-base"):
        assert not runnable[arch]


def test_input_specs_no_allocation():
    """input_specs must yield ShapeDtypeStructs only (no device arrays)."""
    for arch in ("qwen2-1.5b", "zamba2-1.2b", "whisper-base"):
        cfg = get_config(arch)
        for shape in SHAPES:
            if not cell_is_runnable(cfg, shape)[0]:
                continue
            inputs, specs = mapi.input_specs(cfg, shape)
            for leaf in jax.tree.leaves(inputs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), type(leaf)


def test_mlstm_chunk_boundary_property():
    """Chunked mLSTM == token recurrence across chunk boundaries."""
    from repro.models.xlstm import _mlstm_chunked, CHUNK
    rng = np.random.default_rng(3)
    B, S, H, dh = 1, 2 * CHUNK, 2, 8
    qh = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    kh = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    vh = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32)
    lf = jnp.asarray(
        jax.nn.log_sigmoid(jnp.asarray(rng.normal(size=(B, S, H)),
                                       jnp.float32) + 2))
    h1, _ = _mlstm_chunked(qh, kh, vh, li, lf)
    C = jnp.zeros((B, H, dh, dh))
    n = jnp.zeros((B, H, dh))
    m = jnp.full((B, H), -1e30)
    outs = []
    for t in range(S):
        m_new = jnp.maximum(lf[:, t] + m, li[:, t])
        fs = jnp.exp(lf[:, t] + m - m_new)
        i_s = jnp.exp(li[:, t] - m_new)
        C = fs[..., None, None] * C + i_s[..., None, None] \
            * jnp.einsum("bhd,bhe->bhde", vh[:, t], kh[:, t])
        n = fs[..., None] * n + i_s[..., None] * kh[:, t]
        b = jnp.einsum("bhd,bhd->bh", n, qh[:, t])
        den = jnp.maximum(jnp.abs(b), jnp.exp(-m_new))
        outs.append(jnp.einsum("bhde,bhe->bhd", C, qh[:, t]) / den[..., None])
        m = m_new
    np.testing.assert_allclose(h1, jnp.stack(outs, 1), atol=5e-4, rtol=5e-4)
