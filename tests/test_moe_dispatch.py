"""MoE dispatch equivalence: sorted (linear-memory) vs one-hot (GShard
reference) vs a naive per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.granite_moe_3b_a800m import smoke_config
from repro.models import mlp


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config().with_(moe_capacity_factor=100.0)   # no drops
    p, _ = mlp.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    return cfg, p, x


def _naive(p, cfg, x):
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = 0
        for k in range(cfg.top_k):
            e = int(gi[t, k])
            h = jax.nn.silu(xt[t] @ p["wg"][e]) * (xt[t] @ p["wu"][e])
            acc = acc + gv[t, k] * (h @ p["wd"][e])
        y = y.at[t].set(acc)
    return y.reshape(B, S, d)


def test_sorted_equals_onehot_no_drops(setup):
    cfg, p, x = setup
    y1, _ = mlp.moe_forward_onehot(p, cfg, x)
    y2, _ = mlp.moe_forward_sorted(p, cfg, x)
    np.testing.assert_allclose(y2, y1, atol=1e-5, rtol=1e-5)


def test_both_match_naive_oracle(setup):
    cfg, p, x = setup
    yo = _naive(p, cfg, x)
    for fn in (mlp.moe_forward_onehot, mlp.moe_forward_sorted):
        y, _ = fn(p, cfg, x)
        np.testing.assert_allclose(y, yo, atol=1e-5, rtol=1e-5)


def test_sorted_grads_match_onehot(setup):
    cfg, p, x = setup
    g1 = jax.grad(lambda xx: mlp.moe_forward_onehot(p, cfg, xx)[0].sum())(x)
    g2 = jax.grad(lambda xx: mlp.moe_forward_sorted(p, cfg, xx)[0].sum())(x)
    np.testing.assert_allclose(g2, g1, atol=1e-4, rtol=1e-4)


def test_sorted_capacity_drops_bounded():
    """With a tight capacity, outputs stay finite and dropped tokens get
    partial (or zero) expert contributions — never NaN."""
    cfg = smoke_config().with_(moe_capacity_factor=0.25)
    p, _ = mlp.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    y, aux = mlp.moe_forward_sorted(p, cfg, x)
    assert bool(jnp.isfinite(y).all())
    assert bool(jnp.isfinite(aux))
    # with generous capacity the output norm is larger (fewer drops)
    cfg2 = cfg.with_(moe_capacity_factor=100.0)
    y2, _ = mlp.moe_forward_sorted(p, cfg2, x)
    assert float(jnp.linalg.norm(y2)) >= float(jnp.linalg.norm(y)) - 1e-6
