"""Observability-layer tests: shared percentile semantics, RequestLog
bit-identity across the batched/oracle loops, conservation against the
simulator's own counters under faults and shedding, SLOReport shape
invariants, and the TraceLog emit/validate/write/read round trip with
its causal-ordering audit."""
import os
import sys

import numpy as np
import pytest

# tools/ lives at the repo root, outside src/ (same bootstrap as
# tests/test_corallint.py)
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.obs import (QS, RequestLog, SLOReport, SLOTargets, TraceError,
                       TraceLog, percentile, percentiles,
                       weighted_percentiles)
from repro.simulator.sim import INIT_DELAY_S, ShedPolicy, Simulator
from repro.traces.workloads import gen_requests, workload_stats
from tools.trace_tools import assert_causal, read_trace, summarize

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
CFG_BY_NAME = {c.name: c for c in CONFIGS}

PRE, _ = generate_templates(MODEL, "prefill", CONFIGS, WL, n_max=2, rho=8.0)
DEC, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2, rho=8.0)
PRE.sort(key=lambda t: -t.throughput)
DEC.sort(key=lambda t: -t.throughput)


# ------------------------------------------------------------ percentiles
def test_percentile_nearest_rank_and_monotone():
    rng = np.random.default_rng(3)
    for n in (1, 2, 7, 100, 1001):
        xs = rng.exponential(2.0, n)
        qs = np.linspace(0.0, 1.0, 21)
        vals = percentiles(xs, qs)
        srt = np.sort(xs)
        for q, v in zip(qs, vals):
            assert v == srt[int(round(q * (n - 1)))]
        # monotone in q (nearest-rank on a sorted array)
        assert all(a <= b for a, b in zip(vals, vals[1:]))
    assert percentile([], 0.5) == 0.0
    assert percentiles((), QS) == (0.0, 0.0, 0.0)


def test_weighted_percentiles_match_repeat_expansion():
    rng = np.random.default_rng(4)
    for n in (1, 5, 60):
        vals = rng.exponential(0.05, n)
        wts = rng.integers(1, 9, n).astype(np.int64)
        qs = (0.1, 0.5, 0.9, 0.95, 0.99)
        got = weighted_percentiles(vals, wts, qs)
        want = percentiles(np.repeat(vals, wts), qs)
        assert got == want      # exact nearest-rank, not approximate
    assert weighted_percentiles(np.zeros(0), np.zeros(0, np.int64),
                                QS) == (0.0, 0.0, 0.0)


# ------------------------------------------------------------- gauntlet
def _gauntlet(batched, reqlog=True):
    """Same shape as test_sim's equivalence gauntlet: cold start, kills
    mid-flight (decode and prefill), drain, scale-up, long horizons —
    now with the RequestLog's records under test."""
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                    batched=batched, reqlog=reqlog)
    sim.add_instance("r0", PRE[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[1], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", PRE[1], ready_delay=INIT_DELAY_S)
    reqs = gen_requests(MODEL.name, MODEL.trace, 3.0, 300, seed=7)
    for r in reqs:
        sim.submit(r)
    sim.run_until(120.0)
    sim.kill_instance(sim.instances[1])
    sim.run_until(200.0)
    sim.kill_instance(sim.instances[0])
    sim.run_until(240.0)
    sim.drain_instance(sim.instances[2])
    sim.add_instance("r0", DEC[0])
    for t in (360.0, 480.0, 3600.0):
        sim.run_until(t)
    return sim, reqs


def test_gauntlet_bit_identical_with_reqlog_on():
    """Instrumentation is observation-only: with the RequestLog on
    (the default), batched and oracle agree bit-for-bit on outcomes
    AND on every latency record."""
    s1, r1 = _gauntlet(batched=False)
    s2, r2 = _gauntlet(batched=True)
    m = MODEL.name
    assert s1.dropped == s2.dropped
    assert {r.rid for r in s1.finished} == {r.rid for r in s2.finished}
    # identical record tables, not just identical aggregates
    assert s1.reqlog.first_records(m) == s2.reqlog.first_records(m)
    assert s1.reqlog.terminal_records(m) == s2.reqlog.terminal_records(m)
    # and identical SLO summaries derived from them
    rep1 = SLOReport(s1.reqlog, s1.tokens,
                     SLOTargets.from_models({m: MODEL}))
    rep2 = SLOReport(s2.reqlog, s2.tokens,
                     SLOTargets.from_models({m: MODEL}))
    for t0 in range(0, 3600, 600):
        assert rep1.model_window(m, t0, t0 + 600) == \
            rep2.model_window(m, t0, t0 + 600)


def test_reqlog_off_changes_nothing():
    """Turning logging off must not move a single outcome (the log
    never feeds back into simulation decisions)."""
    s_on, r_on = _gauntlet(batched=True, reqlog=True)
    s_off, r_off = _gauntlet(batched=True, reqlog=False)
    assert s_off.reqlog is None
    assert s_on.dropped == s_off.dropped
    fin = {r.rid for r in s_on.finished}
    assert fin == {r.rid for r in s_off.finished}
    d_on = {r.rid: (r.finish, r.prefill_done, r.decode_tokens_ok)
            for r in r_on if r.rid in fin}
    d_off = {r.rid: (r.finish, r.prefill_done, r.decode_tokens_ok)
             for r in r_off if r.rid in fin}
    assert d_on == d_off


def test_reqlog_conservation_under_faults_and_shed():
    """RequestLog counters match the simulator's own accounting when
    requests are shed, dropped, and killed mid-flight."""
    m = MODEL.name
    sim = Simulator({m: MODEL}, CFG_BY_NAME, {m: WL}, batched=True)
    sim.shed_policy = ShedPolicy(max_queue_per_instance=4.0)
    sim.add_instance("r0", PRE[0], ready_delay=0.0)
    sim.add_instance("r0", DEC[0], ready_delay=0.0)
    reqs = gen_requests(m, MODEL.trace, 30.0, 120, seed=12)
    for r in reqs:
        sim.submit(r)
    sim.run_until(90.0)
    sim.kill_instance(sim.instances[1])     # lone decode pool dies
    sim.run_until(7200.0)
    rl = sim.reqlog
    assert rl.n_finished[m] == len([r for r in sim.finished
                                    if r.model == m])
    assert rl.n_dropped[m] == sim.dropped_by_model.get(m, 0)
    assert rl.n_shed[m] == sim.shed_by_model.get(m, 0)
    assert rl.n_shed[m] > 0                 # the shed path actually ran
    # every submitted request reached exactly one terminal state
    rows = rl.terminal_records(m)
    assert len(rows) == rl.n_finished[m] + rl.n_dropped[m] + rl.n_shed[m]
    assert {r[0] for r in rows} <= {r.rid for r in reqs}
    # finished requests may exceed output_len via the kill's partial
    # token credit, so only sanity-bound the counters
    for rid, status, arr, first, finish, out, tok, ok in rows:
        if status == 0:                     # FINISHED
            assert finish >= first >= arr
            assert tok >= 0 and ok >= 0
        else:                               # DROPPED / SHED
            assert (first, finish, out, tok, ok) == (-1.0, -1.0, 0, 0, 0)


def test_slo_report_shape_invariants():
    """Percentiles are monotone across QS, attainments are fractions,
    and windowed series sample counts sum to the whole-run counts."""
    sim, _ = _gauntlet(batched=True)
    m = MODEL.name
    rep = SLOReport(sim.reqlog, sim.tokens,
                    SLOTargets.from_models({m: MODEL}))
    whole = rep.model_window(m, 0.0, 3600.0)
    assert whole["n_ttft"] > 0 and whole["n_tbt_tokens"] > 0
    for d in rep.series(m, 600.0, 0.0, 3600.0) + [whole]:
        assert d["ttft_p50"] <= d["ttft_p95"] <= d["ttft_p99"]
        assert d["tbt_p50"] <= d["tbt_p95"] <= d["tbt_p99"]
        assert 0.0 <= d["ttft_attain"] <= 1.0
        assert 0.0 <= d["tbt_attain"] <= 1.0
    series = rep.series(m, 600.0, 0.0, 3600.0)
    assert sum(d["n_ttft"] for d in series) == whole["n_ttft"]
    assert sum(d["n_tbt_tokens"] for d in series) == whole["n_tbt_tokens"]


# --------------------------------------------------------------- tracing
def test_tracelog_roundtrip_and_validation(tmp_path):
    tr = TraceLog()
    tr.emit("fault_inject", 130.0, 0, fault="crash", iid=3)
    tr.emit("trigger", 0.0, 0, resolve=True, reason="epoch")
    tr.emit("solve", 0.1, 0, path="decomposed", solve_ms=12.0,
            assembly_ms=1.0, extract_ms=0.5, total_ms=14.0,
            alloc_source="fresh")
    tr.emit("reconcile", 0.2, 0, n_new=4, n_drained=0, n_kept=0)
    tr.emit("fault_detect", 145.0, 0, iid=3, detect_lag_s=15.0)
    tr.emit("restart", 146.0, 0, for_iid=3, outcome="started")
    tr.emit("preempt", 200.0, 0, iid=5)
    tr.emit("mid_resolve", 201.0, 0, reason="availability_event",
            solve_ms=9.0)
    path = tmp_path / "trace.jsonl"
    assert tr.write(path) == 8
    records = read_trace(str(path))         # full-schema validation
    assert [r["kind"] for r in records] == \
        [r["kind"] for r in tr.records]
    assert assert_causal(records) == []
    summ = summarize(records)
    assert summ["n_records"] == 8
    assert summ["faults"] == {"crash": 1}
    assert summ["trigger_reasons"] == {"epoch": 1}

    with pytest.raises(TraceError):
        tr.emit("no_such_kind", 0.0, 0)
    with pytest.raises(TraceError):
        tr.emit("solve", 0.0, 0, path="decomposed")  # missing fields


def test_trace_causal_audit_flags_violations():
    tr = TraceLog()
    # detect with no inject at all, and a restart whose only detect
    # comes later in *time* (record order is irrelevant either way)
    tr.emit("fault_detect", 50.0, 0, iid=9, detect_lag_s=15.0)
    tr.emit("fault_inject", 100.0, 0, fault="crash", iid=7)
    tr.emit("fault_detect", 400.0, 1, iid=7, detect_lag_s=15.0)
    tr.emit("restart", 300.0, 1, for_iid=7, outcome="started")
    errs = assert_causal(tr.records)
    assert len(errs) == 2
    assert any("iid=9" in e and "fault_inject" in e for e in errs)
    assert any("iid=7" in e and "fault_detect" in e for e in errs)
    # planned-future inject legitimately precedes in file, follows in t
    tr2 = TraceLog()
    tr2.emit("fault_inject", 130.0, 0, fault="crash", iid=3)
    tr2.emit("fault_detect", 145.0, 0, iid=3, detect_lag_s=15.0)
    assert assert_causal(tr2.records) == []
    # epoch-edge records must be epoch-ordered in record order
    tr3 = TraceLog()
    tr3.emit("trigger", 240.0, 1, resolve=True, reason="epoch")
    tr3.emit("trigger", 0.0, 0, resolve=True, reason="epoch")
    errs3 = assert_causal(tr3.records)
    assert len(errs3) == 1 and "epoch" in errs3[0]
