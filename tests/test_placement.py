"""Placement solvers: the exact combinatorial optimizer must equal the
paper-faithful ILP; placements must satisfy the problem invariants."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.placement import (_multiset_partitions,
                                  optimal_placement_exact,
                                  optimal_placement_ilp)


def _make_tables(names, L, seed):
    r = np.random.default_rng(seed)
    base = {n: r.uniform(10, 200) for n in set(names)}
    cache = {}

    def tables(name, S):
        key = (name, S)
        if key not in cache:
            j = np.arange(1, L + 1)
            v = base[name] / (j ** (0.7 + 0.05 * S))
            cut = r.integers(max(L // 2, 1), L + 1)
            v = np.where(j <= cut, v, 0.0)
            cache[key] = np.minimum.accumulate(v)
        return cache[key]

    return tables


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 4), st.integers(3, 8))
def test_exact_equals_ilp(seed, K, L):
    r = np.random.default_rng(seed)
    pool = ["A", "B", "C"]
    names = [pool[r.integers(0, 3)] for _ in range(K)]
    tables = _make_tables(names, L, seed)
    pe = optimal_placement_exact(names, tables, L)
    pi = optimal_placement_ilp(names, tables, L)
    te = pe.throughput if pe else 0.0
    ti = pi.throughput if pi else 0.0
    assert abs(te - ti) <= 1e-6 * max(te, ti, 1.0), (pe, pi)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 6), st.integers(3, 12))
def test_placement_invariants(seed, K, L):
    r = np.random.default_rng(seed)
    pool = ["A", "B", "C", "D"]
    names = [pool[r.integers(0, 4)] for _ in range(K)]
    tables = _make_tables(names, L, seed)
    pl = optimal_placement_exact(names, tables, L)
    if pl is None:
        return
    # layers cover the model exactly, >=1 per stage
    assert sum(pl.layer_counts) == L
    assert all(j >= 1 for j in pl.layer_counts)
    # every node used exactly once
    used = sorted(n for g in pl.stage_nodes for n in g)
    assert used == sorted(names)
    # reported throughput == min stage throughput at the chosen layers
    stage_t = []
    for j, group in zip(pl.layer_counts, pl.stage_nodes):
        stage_t.append(sum(tables(n, pl.n_stages)[j - 1] for n in group))
    assert min(stage_t) >= pl.throughput - 1e-9


def test_multiset_partitions_counts():
    # 3 identical items: integer partitions of 3 -> 3
    assert len(_multiset_partitions(("a", "a", "a"))) == 3
    # 3 distinct items: Bell(3) = 5
    assert len(_multiset_partitions(("a", "b", "c"))) == 5
    # mixed
    parts = _multiset_partitions(("a", "a", "b"))
    assert len(parts) == 4


def test_single_node_placement():
    tab = np.array([60.0, 30, 20, 15, 12, 10])      # full support

    def tables(name, S):
        return tab

    pl = optimal_placement_exact(["A"], tables, 6)
    assert pl is not None and pl.n_stages == 1
    assert pl.layer_counts == (6,)
    assert pl.throughput == tab[5]


def test_infeasible_returns_none():
    tab = np.array([60.0, 30, 0, 0, 0, 0])          # >2 layers impossible

    def tables(name, S):
        return tab

    assert optimal_placement_exact(["A"], tables, 6) is None
