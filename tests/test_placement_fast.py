"""Fast placement path: equivalence with the reference exact solver,
golden template-set equality, and PlacementCache / incremental-library
behavior."""
import numpy as np
import pytest

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.placement import (PlacementCache, _partitions_by_shape,
                                  optimal_placement_exact,
                                  optimal_placement_fast)
from repro.core.templates import build_library, generate_templates
from repro.traces.workloads import workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))


def _make_tables(names, L, seed):
    r = np.random.default_rng(seed)
    base = {n: r.uniform(10, 200) for n in set(names)}
    cache = {}

    def tables(name, S):
        key = (name, S)
        if key not in cache:
            j = np.arange(1, L + 1)
            v = base[name] / (j ** (0.7 + 0.05 * S))
            cut = r.integers(max(L // 2, 1), L + 1)
            v = np.where(j <= cut, v, 0.0)
            cache[key] = np.minimum.accumulate(v)
        return cache[key]

    return tables


def test_fast_equals_exact_randomized():
    """Same optimal throughput (bit-identical) and a valid layer split on
    randomized instances, with and without a max_stages cap."""
    for seed in range(120):
        r = np.random.default_rng(seed)
        K = int(r.integers(1, 7))
        L = int(r.integers(2, 13))
        ms = int(r.integers(1, 5)) if seed % 3 == 0 else None
        pool = ["A", "B", "C", "D"]
        names = [pool[r.integers(0, 4)] for _ in range(K)]
        tables = _make_tables(names, L, seed)
        pe = optimal_placement_exact(names, tables, L, max_stages=ms)
        pf = optimal_placement_fast(names, tables, L, max_stages=ms)
        te = pe.throughput if pe else 0.0
        tf = pf.throughput if pf else 0.0
        assert te == tf, (seed, names, L, ms, te, tf)
        if pf is None:
            continue
        assert sum(pf.layer_counts) == L
        assert all(j >= 1 for j in pf.layer_counts)
        assert sorted(n for g in pf.stage_nodes for n in g) == sorted(names)
        stage_t = [sum(tables(n, pf.n_stages)[j - 1] for n in g)
                   for j, g in zip(pf.layer_counts, pf.stage_nodes)]
        assert min(stage_t) >= pf.throughput - 1e-12


def test_cache_reuse_across_combos():
    """One shared cache must return the same results as fresh solves."""
    L = 10
    tables = _make_tables(["A", "B", "C"], L, 7)
    cache = PlacementCache(tables, L)
    combos = [["A"], ["A", "B"], ["A", "A", "B"], ["B", "C", "C"],
              ["A", "B", "C"], ["A", "A", "B", "C"], ["A", "B"], ["A"]]
    for names in combos:
        shared = cache.solve(names)
        fresh = optimal_placement_exact(names, tables, L)
        ts, tf = (shared.throughput if shared else 0.0,
                  fresh.throughput if fresh else 0.0)
        assert ts == tf, (names, ts, tf)


def test_partitions_by_shape_counts():
    # 3 identical items: integer partitions of 3
    cg, by_S = _partitions_by_shape((3,))
    assert sum(len(idx) for _, idx in by_S.values()) == 3
    # 3 distinct items: Bell(3) = 5
    cg, by_S = _partitions_by_shape((1, 1, 1))
    assert sum(len(idx) for _, idx in by_S.values()) == 5
    cg, by_S = _partitions_by_shape((2, 1))
    assert sum(len(idx) for _, idx in by_S.values()) == 4


def test_generate_templates_golden_equality():
    """prune=True template set: identical keys and throughputs between
    the fast path and the seed per-combo exact solver."""
    fast, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=4,
                                 rho=8.0, solver="fast")
    seed, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=4,
                                 rho=8.0, solver="exact")
    fd = {t.key: t.throughput for t in fast}
    sd = {t.key: t.throughput for t in seed}
    assert set(fd) == set(sd)
    for k in fd:
        assert abs(fd[k] - sd[k]) <= 1e-9, (k, fd[k], sd[k])
    # placements on the fast path are valid layer splits
    for t in fast:
        assert sum(t.placement.layer_counts) == MODEL.n_layers
        assert all(j >= 1 for j in t.placement.layer_counts)


def test_pareto_prune_high_counts_fallback():
    """Counts up to 20 (beyond the SWAR fields' 15) must produce the
    same kept set as a brute-force reference over the deterministic
    dominance-compatible order."""
    from repro.core.placement import Placement
    from repro.core.templates import (ServingTemplate, _template_order_key,
                                      pareto_prune)
    r = np.random.default_rng(0)
    names = ["a", "b", "c"]
    temps = []
    for _ in range(300):
        counts = tuple((n, int(r.integers(0, 21))) for n in names)
        counts = tuple((n, c) for n, c in counts if c > 0) or (("a", 1),)
        pl = Placement(1, (4,),
                       (tuple(n for n, c in counts for _ in range(c)),), 1.0)
        temps.append(ServingTemplate("m", "decode", 80.0, counts, pl,
                                     float(r.uniform(1, 100))))
    kept = pareto_prune(temps, names)
    order = sorted(temps, key=_template_order_key)
    ref = []
    for t in order:
        u = [t.usage().get(c, 0) for c in names]
        if any(all(ku[j] <= u[j] for j in range(3)) for ku, _ in ref):
            continue
        ref.append((u, t))
    assert [t.throughput for t in kept] == [t.throughput for _, t in ref]
    # counts > 15 overflow the SWAR fields: exercise the broadcast
    # branch of the pairwise scan directly against the same reference
    # (pareto_prune itself routes these boxes through the hash path)
    from repro.core.templates import _pareto_mask_pairwise
    usage = np.array([[t.usage().get(c, 0) for c in names] for t in order],
                     dtype=np.int64)
    assert usage.max() > 15
    mask = _pareto_mask_pairwise(usage)
    assert [t.throughput for t, k in zip(order, mask) if k] \
        == [t.throughput for _, t in ref]


def test_build_library_incremental_reuse():
    wls = {MODEL.name: WL}
    lib1 = build_library([MODEL], CONFIGS, wls, n_max=3, rho=8.0)
    # unchanged inputs: every (model, phase) pair is reused verbatim
    lib2 = build_library([MODEL], CONFIGS, wls, n_max=3, rho=8.0,
                         reuse=lib1)
    assert all(s.get("reused") for s in lib2.stats.values())
    for key in lib1.templates:
        assert [t.key for t in lib2.templates[key]] \
            == [t.key for t in lib1.templates[key]]
    # changed config universe: nothing may be reused
    bigger = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
    lib3 = build_library([MODEL], bigger, wls, n_max=3, rho=8.0,
                         reuse=lib1)
    assert not any(s.get("reused") for s in lib3.stats.values())
    assert lib3.size > 0
