"""Analytical T-hat profiles: monotonicity, phase affinity, feasibility."""
import numpy as np
import pytest

from repro.core.hardware import DEVICE_TYPES, NodeConfig
from repro.core.profiles import (ProfileTable, WorkloadStats,
                                 decode_throughput, decode_throughput_row,
                                 prefill_throughput)
from repro.core.modelspec import PAPER_MODELS

WL = WorkloadStats(avg_prompt=1024, avg_output=200)


@pytest.mark.parametrize("model", ["phi4-14b", "qwen3-32b", "gpt-oss-20b"])
@pytest.mark.parametrize("phase", ["prefill", "decode"])
def test_that_nonincreasing_in_layers(model, phase):
    m = PAPER_MODELS[model]
    pt = ProfileTable(m, phase, 2000.0 if phase == "prefill" else 100.0, WL)
    for dev in ("L40S", "A100", "L4"):
        for k in (1, 4):
            tab = pt.table(NodeConfig(DEVICE_TYPES[dev], k), 2)
            assert np.all(np.diff(tab) <= 1e-9), (dev, k)


def test_tighter_budget_never_helps():
    m = PAPER_MODELS["phi4-14b"]
    node = NodeConfig(DEVICE_TYPES["L40S"], 2)
    for j in (4, 20, 40):
        t_loose = decode_throughput(m, node, j, 0.10, WL)
        t_tight = decode_throughput(m, node, j, 0.03, WL)
        assert t_tight <= t_loose + 1e-9


def test_memory_infeasibility_zeroes():
    m = PAPER_MODELS["llama3-70b"]             # 140GB of weights
    small = NodeConfig(DEVICE_TYPES["L4"], 1)  # 24GB
    assert prefill_throughput(m, small, m.n_layers, 1.0, WL) == 0.0
    assert decode_throughput(m, small, m.n_layers, 1.0, WL) == 0.0


def test_phase_affinity_matches_paper():
    """§2.1: prefill favors FLOPs-per-cost (L40S), decode favors
    bandwidth+memory-per-cost (A100-class) — check relative ordering."""
    m = PAPER_MODELS["phi4-14b"]
    j = m.n_layers
    l40s = NodeConfig(DEVICE_TYPES["L40S"], 2)
    a100 = NodeConfig(DEVICE_TYPES["A100"], 1)

    def eff(node, phase, budget):
        fn = prefill_throughput if phase == "prefill" else decode_throughput
        return fn(m, node, j, budget, WL) / node.rel_cost

    # prefill: L40S at least as cost-efficient as A100
    assert eff(l40s, "prefill", 1.2) >= eff(a100, "prefill", 1.2) * 0.9
    # decode: A100's bandwidth advantage shows up
    assert eff(a100, "decode", 0.06) > 0


@pytest.mark.parametrize("model", ["phi4-14b",       # dense
                                   "gpt-oss-20b",    # MoE + hybrid attn
                                   "qwen3-235b"])    # MoE, many layers
def test_decode_row_bit_identical_to_scalar(model):
    """The vectorized j-sweep (incl. its 40-step batch bisection) must
    reproduce the scalar decode model bit-for-bit — ProfileTable rows
    feed template generation, so any drift would silently change every
    library fingerprinted downstream."""
    m = PAPER_MODELS[model]
    for dev, k in (("L40S", 1), ("L4", 2), ("A100", 4), ("H100", 8)):
        node = NodeConfig(DEVICE_TYPES[dev], k)
        for budget in (0.01, 0.04, 0.12):
            row = decode_throughput_row(m, node, budget, WL)
            ref = np.array([decode_throughput(m, node, j, budget, WL)
                            for j in range(1, m.n_layers + 1)])
            assert np.array_equal(row, ref), (dev, k, budget)


def test_decode_row_recurrent_branch():
    from repro.core.modelspec import from_model_config
    from repro.configs.registry import get_config
    sm = from_model_config(get_config("xlstm-350m"))
    node = NodeConfig(DEVICE_TYPES["A10G"], 1)
    row = decode_throughput_row(sm, node, 0.06, WL)
    ref = np.array([decode_throughput(sm, node, j, 0.06, WL)
                    for j in range(1, sm.n_layers + 1)])
    assert np.array_equal(row, ref)


def test_recurrent_decode_ctx_independent():
    """SSM-backed models: decode throughput ~independent of context."""
    from repro.core.modelspec import from_model_config
    from repro.configs.registry import get_config
    sm = from_model_config(get_config("xlstm-350m"))
    node = NodeConfig(DEVICE_TYPES["A10G"], 1)
    short = decode_throughput(sm, node, sm.n_layers, 0.06,
                              WorkloadStats(512, 128))
    long_ = decode_throughput(sm, node, sm.n_layers, 0.06,
                              WorkloadStats(65536, 128))
    assert short > 0
    assert abs(long_ - short) / short < 0.05
