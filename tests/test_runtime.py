"""Epoch runtime: reconcile semantics, cost accounting, failure recovery."""
import numpy as np
import pytest

from repro.core.allocator import AllocProblem, Demand, allocate
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.runtime.cluster import ClusterRuntime
from repro.traces.workloads import gen_requests, workload_stats

CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
MODEL = PAPER_MODELS["phi4-14b"]
WLS = {MODEL.name: workload_stats(MODEL.trace)}
LIB = build_library([MODEL], CONFIGS, WLS, n_max=3, rho=8.0)


def _run(fail_rate=0.0, n_epochs=3, rate=2.0, epoch_s=240.0):
    rt = ClusterRuntime({MODEL.name: MODEL}, CORE_REGIONS, CONFIGS, LIB,
                        allocate, WLS, epoch_s=epoch_s)
    reqs = gen_requests(MODEL.name, MODEL.trace, rate, n_epochs * epoch_s,
                        seed=0)
    avail = [{(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
             for _ in range(n_epochs)]
    wl = WLS[MODEL.name]
    demands = [[Demand(MODEL.name, "prefill", rate * wl.avg_prompt),
                Demand(MODEL.name, "decode", rate * wl.avg_output)]
               for _ in range(n_epochs)]
    res = rt.run(reqs, avail, demands, fail_rate_per_epoch=fail_rate)
    return rt, res


def test_epoch_run_steady_state():
    rt, res = _run()
    assert len(res.epochs) == 3
    # after the warm-up epoch the cluster composition is stable
    assert res.epochs[1].n_new == 0
    assert res.epochs[1].init_cost == 0.0
    assert res.epochs[1].cost_per_hour > 0
    # goodput approaches demand
    wl = WLS[MODEL.name]
    demand = 2.0 * wl.avg_output
    assert res.epochs[2].goodput[MODEL.name] >= 0.5 * demand


def test_failure_recovery():
    rt, res = _run(fail_rate=1.0, n_epochs=4)
    # failures occurred, yet the allocator replaced capacity: the final
    # epoch still registers new instances or sustained goodput
    assert any(e.n_new > 0 for e in res.epochs[1:])
    assert res.epochs[-1].goodput[MODEL.name] > 0


def test_cost_accounting_matches_running_instances():
    rt, res = _run()
    cfg = LIB.config_by_name
    expect = 0.0
    for (region_name, tkey), insts in rt.running.items():
        region = next(r for r in CORE_REGIONS if r.name == region_name)
        for inst in insts:
            if not inst.dead:
                expect += inst.template.cost(region, cfg)
    assert abs(res.epochs[-1].cost_per_hour - res.epochs[-1].init_cost
               - expect) < 1e-6
