"""Epoch runtime: reconcile semantics, cost accounting, failure recovery.

The template library comes from the session-scoped
``phi4_runtime_library`` fixture (tests/conftest.py), which serves the
``artifacts/lib_test_*.pkl`` disk cache instead of rebuilding at every
run."""
import numpy as np
import pytest

from repro.core.allocator import (AllocProblem, Allocation, Demand,
                                  allocate)
from repro.core.hardware import CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.runtime.cluster import ClusterRuntime
from repro.traces.workloads import gen_requests, workload_stats

CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
MODEL = PAPER_MODELS["phi4-14b"]
WLS = {MODEL.name: workload_stats(MODEL.trace)}


def _run(lib, fail_rate=0.0, n_epochs=3, rate=2.0, epoch_s=240.0,
         sim_batched=True, allocator_fn=allocate):
    rt = ClusterRuntime({MODEL.name: MODEL}, CORE_REGIONS, CONFIGS, lib,
                        allocator_fn, WLS, epoch_s=epoch_s,
                        sim_batched=sim_batched)
    reqs = gen_requests(MODEL.name, MODEL.trace, rate, n_epochs * epoch_s,
                        seed=0)
    avail = [{(r.name, c.name): 20 for r in CORE_REGIONS for c in CONFIGS}
             for _ in range(n_epochs)]
    wl = WLS[MODEL.name]
    demands = [[Demand(MODEL.name, "prefill", rate * wl.avg_prompt),
                Demand(MODEL.name, "decode", rate * wl.avg_output)]
               for _ in range(n_epochs)]
    res = rt.run(reqs, avail, demands, fail_rate_per_epoch=fail_rate)
    return rt, res, reqs


def test_epoch_run_steady_state(phi4_runtime_library):
    rt, res, _reqs = _run(phi4_runtime_library)
    assert len(res.epochs) == 3
    # after the warm-up epoch the cluster composition is stable
    assert res.epochs[1].n_new == 0
    assert res.epochs[1].init_cost == 0.0
    assert res.epochs[1].cost_per_hour > 0
    # goodput approaches demand
    wl = WLS[MODEL.name]
    demand = 2.0 * wl.avg_output
    assert res.epochs[2].goodput[MODEL.name] >= 0.5 * demand


def test_epoch0_cold_start_holds_requests(phi4_runtime_library):
    """Requests arriving during the initial INIT_DELAY_S are held for
    the warming pool, not dropped: epoch 0 serves tokens and the run
    loses nothing (the seed dropped every pre-ready arrival)."""
    rt, res, _reqs = _run(phi4_runtime_library, n_epochs=2, epoch_s=180.0)
    assert rt.sim.dropped == 0
    assert res.epochs[0].goodput[MODEL.name] > 0
    # nothing arrived before t=0, so every request eventually prefills
    lost = [r for r in rt.sim.finished if r.prefill_done < 0]
    assert not lost


def test_failure_recovery(phi4_runtime_library):
    rt, res, _reqs = _run(phi4_runtime_library, fail_rate=1.0, n_epochs=4)
    # failures occurred, yet the allocator replaced capacity: the final
    # epoch still registers new instances or sustained goodput
    assert any(e.n_new > 0 for e in res.epochs[1:])
    assert res.epochs[-1].goodput[MODEL.name] > 0


def test_failure_does_not_double_count_prefill(phi4_runtime_library):
    """fail_instance re-routes a decode victim's queue via
    _join_decode: prefill latency is recorded at most once per request
    (the seed pushed the queue back through _on_arrival, re-running
    prefill)."""
    rt, res, reqs = _run(phi4_runtime_library, fail_rate=1.0, n_epochs=4)
    sim = rt.sim
    n_prefilled = len([r for r in reqs if r.prefill_done >= 0])
    # exactly one first-token record per request that prefilled
    assert sim.reqlog.n_first[MODEL.name] == n_prefilled
    seen = {r.rid for r in sim.finished}
    assert len(seen) == len(sim.finished), "no request finishes twice"


def test_runtime_batched_matches_oracle(phi4_runtime_library):
    """End-to-end epoch metrics are bit-identical between the batched
    loop and the per-iteration oracle, failures included."""
    rt1, res1, _ = _run(phi4_runtime_library, fail_rate=1.0, n_epochs=3,
                     sim_batched=False)
    rt2, res2, _ = _run(phi4_runtime_library, fail_rate=1.0, n_epochs=3,
                     sim_batched=True)
    for e1, e2 in zip(res1.epochs, res2.epochs):
        assert e1.goodput == e2.goodput
        assert e1.throughput == e2.throughput
        assert e1.cost_per_hour == e2.cost_per_hour
        assert e1.n_new == e2.n_new and e1.n_drained == e2.n_drained
    assert rt1.sim.dropped == rt2.sim.dropped
    assert {r.rid for r in rt1.sim.finished} == \
        {r.rid for r in rt2.sim.finished}


def test_failed_solve_keeps_previous_allocation(phi4_runtime_library):
    """Regression: a failed solve (ok=False, empty instances) used to be
    treated as a scale-to-zero target, draining the whole cluster.  The
    runtime must keep the previous epoch's allocation and flag the
    epoch via EpochMetrics.solver_failed."""
    calls = {"n": 0}

    def flaky(prob):
        calls["n"] += 1
        if calls["n"] == 2:          # epoch 1 solve fails
            return Allocation({}, {}, np.inf, 0.0,
                              {(d.model, d.phase): d.tokens_per_s
                               for d in prob.demands}, 0.0, 0, False)
        return allocate(prob)

    rt, res, _reqs = _run(phi4_runtime_library, allocator_fn=flaky)
    good = res.epochs[0]
    failed = res.epochs[1]
    assert not good.solver_failed and failed.solver_failed
    # the cluster was NOT drained: same composition as the epoch before
    assert failed.n_drained == 0 and failed.n_new == 0
    assert failed.n_instances == good.n_instances > 0
    assert failed.cost_per_hour > 0
    # shortfall is reported against THIS epoch's demands: the kept
    # allocation still meets them, so no phantom (or stale) unmet
    assert failed.unmet == {}
    assert failed.solve_seconds == 0.0      # the failed solve's timing
    # and the epoch after a successful re-solve is stable again
    assert not res.epochs[2].solver_failed
    assert res.epochs[2].goodput[MODEL.name] > 0


def test_cost_accounting_matches_running_instances(phi4_runtime_library):
    rt, res, _reqs = _run(phi4_runtime_library)
    cfg = phi4_runtime_library.config_by_name
    expect = 0.0
    for (region_name, tkey), insts in rt.running.items():
        region = next(r for r in CORE_REGIONS if r.name == region_name)
        for inst in insts:
            if not inst.dead:
                expect += inst.template.cost(region, cfg)
    assert abs(res.epochs[-1].cost_per_hour - res.epochs[-1].init_cost
               - expect) < 1e-6
