"""Real JAX serving engine: continuous batching must reproduce the
model's own greedy decoding exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.models import api as mapi
from repro.serving.engine import JaxEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2-1.5b")
    model = mapi.get_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, model, params


def _direct_greedy(model, cfg, params, prompt, n_new):
    batch = {"tokens": jnp.asarray(prompt)[None, :]}
    logits, cache = model.prefill(params, cfg, batch)
    pad = ((0, 0), (0, 0), (0, n_new + 1), (0, 0), (0, 0))
    cache = dict(cache, k=jnp.pad(cache["k"], pad),
                 v=jnp.pad(cache["v"], pad))
    toks = [int(jnp.argmax(logits[0, :cfg.vocab_size]))]
    for _ in range(n_new):
        lg, cache = model.decode_step(params, cfg, cache,
                                      jnp.asarray(toks[-1:]))
        toks.append(int(jnp.argmax(lg[0, :cfg.vocab_size])))
    return toks


def test_engine_matches_direct_greedy(setup):
    """Bucket padding must be invisible: the engine's outputs equal
    greedy decoding of the exact (unpadded) prompt."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(int(n),))
               for n in (5, 9, 16)]
    n_new = 6
    eng = JaxEngine(cfg, params, max_batch=4, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(i, p, n_new)
    finished = eng.drain()
    assert set(finished) == {0, 1, 2}
    for i, p in enumerate(prompts):
        want = _direct_greedy(model, cfg, params, p, n_new)
        got = finished[i].out_tokens
        assert got == want, (i, got, want)


def test_engine_slot_reuse(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    eng = JaxEngine(cfg, params, max_batch=2, max_len=64)
    for i in range(5):                      # more requests than slots
        eng.submit(i, rng.integers(0, cfg.vocab_size, size=(6,)), 3)
    finished = eng.drain()
    assert set(finished) == set(range(5))
    for r in finished.values():
        assert len(r.out_tokens) == 4       # first + 3 generated
