"""Event-simulator invariants: token conservation, SLO accounting,
drain semantics, failure recovery."""
import numpy as np
import pytest

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.simulator.sim import Simulator
from repro.traces.workloads import gen_requests, workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
CFG_BY_NAME = {c.name: c for c in CONFIGS}


def _sim_with_instances():
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL})
    pre, _ = generate_templates(MODEL, "prefill", CONFIGS, WL, n_max=2,
                                rho=8.0)
    dec, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2,
                                rho=8.0)
    pre.sort(key=lambda t: -t.throughput)
    dec.sort(key=lambda t: -t.throughput)
    sim.add_instance("r0", pre[0], ready_delay=0.0)
    sim.add_instance("r0", dec[0], ready_delay=0.0)
    return sim


def test_token_conservation():
    sim = _sim_with_instances()
    reqs = gen_requests(MODEL.name, MODEL.trace, rate=1.0, duration=60,
                        seed=0)
    for r in reqs:
        sim.submit(r)
    sim.run_until(3600.0)
    finished = {r.rid for r in sim.finished}
    assert finished == {r.rid for r in reqs}, "all requests must finish"
    for r in sim.finished:
        assert r.decode_tokens_ok == r.output_len
        assert 0 <= r.decode_slo_ok <= r.output_len
        assert r.prefill_done >= r.arrival
        assert r.finish >= r.prefill_done
    total_tokens = sum(r.output_len for r in reqs)
    assert len(sim.tokens[MODEL.name]) == total_tokens


def test_goodput_le_throughput():
    sim = _sim_with_instances()
    for r in gen_requests(MODEL.name, MODEL.trace, 2.0, 120, seed=1):
        sim.submit(r)
    sim.run_until(3600.0)
    g = sim.goodput(MODEL.name, 0, 3600)
    t = sim.throughput(MODEL.name, 0, 3600)
    assert g <= t + 1e-9
    assert t > 0


def test_drain_completes_in_flight():
    sim = _sim_with_instances()
    reqs = gen_requests(MODEL.name, MODEL.trace, 1.0, 30, seed=2)
    for r in reqs:
        sim.submit(r)
    sim.run_until(35.0)
    for inst in list(sim.instances.values()):
        sim.drain_instance(inst)
    sim.run_until(3600.0)
    # draining instances finish their in-flight work, then die
    done = {r.rid for r in sim.finished}
    started = {r.rid for r in reqs if r.prefill_done >= 0}
    assert started <= done | {r.rid for r in reqs if r.finish < 0
                              and r.prefill_done < 0}
    for inst in sim.instances.values():
        assert inst.dead or (not inst.resident and not inst.queue)


def test_decode_capacity_respects_slo():
    from repro.simulator.costmodel import InstanceCostModel
    dec, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2,
                                rho=8.0)
    t = max(dec, key=lambda x: x.throughput)
    cm = InstanceCostModel(MODEL, "decode", t.placement, CFG_BY_NAME, WL)
    cap = cm.decode_capacity
    assert cm.decode_pipeline_latency(cap) <= MODEL.decode_slo_ms / 1e3 + 1e-9
    # template throughput should be realizable within ~2x by the sim model
    rate = cap / cm.decode_iter_time(cap)
    assert rate >= 0.4 * t.throughput
