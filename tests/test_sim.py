"""Event-simulator invariants: token conservation, SLO accounting,
drain semantics, failure recovery, cold-start holds, decode EWMA
routing, and batched-loop equivalence against the per-iteration
oracle."""
import numpy as np
import pytest

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import generate_templates
from repro.simulator.sim import INIT_DELAY_S, Simulator
from repro.traces.workloads import Request, gen_requests, workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))
CFG_BY_NAME = {c.name: c for c in CONFIGS}

PRE, _ = generate_templates(MODEL, "prefill", CONFIGS, WL, n_max=2, rho=8.0)
DEC, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=2, rho=8.0)
PRE.sort(key=lambda t: -t.throughput)
DEC.sort(key=lambda t: -t.throughput)


def _sim_with_instances(batched=True, ready_delay=0.0):
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                    batched=batched)
    sim.add_instance("r0", PRE[0], ready_delay=ready_delay)
    sim.add_instance("r0", DEC[0], ready_delay=ready_delay)
    return sim


def test_token_conservation():
    sim = _sim_with_instances()
    reqs = gen_requests(MODEL.name, MODEL.trace, rate=1.0, duration=60,
                        seed=0)
    for r in reqs:
        sim.submit(r)
    sim.run_until(3600.0)
    finished = {r.rid for r in sim.finished}
    assert finished == {r.rid for r in reqs}, "all requests must finish"
    for r in sim.finished:
        assert r.decode_tokens_ok == r.output_len
        assert 0 <= r.decode_slo_ok <= r.output_len
        assert r.prefill_done >= r.arrival
        assert r.finish >= r.prefill_done
    total_tokens = sum(r.output_len for r in reqs)
    assert len(sim.tokens[MODEL.name]) == total_tokens


def test_goodput_le_throughput():
    sim = _sim_with_instances()
    for r in gen_requests(MODEL.name, MODEL.trace, 2.0, 120, seed=1):
        sim.submit(r)
    sim.run_until(3600.0)
    g = sim.goodput(MODEL.name, 0, 3600)
    t = sim.throughput(MODEL.name, 0, 3600)
    assert g <= t + 1e-9
    assert t > 0


def test_drain_completes_in_flight():
    sim = _sim_with_instances()
    reqs = gen_requests(MODEL.name, MODEL.trace, 1.0, 30, seed=2)
    for r in reqs:
        sim.submit(r)
    sim.run_until(35.0)
    for inst in list(sim.instances.values()):
        sim.drain_instance(inst)
    sim.run_until(3600.0)
    # draining instances finish their in-flight work, then die
    done = {r.rid for r in sim.finished}
    started = {r.rid for r in reqs if r.prefill_done >= 0}
    assert started <= done | {r.rid for r in reqs if r.finish < 0
                              and r.prefill_done < 0}
    for inst in sim.instances.values():
        assert inst.dead or (not inst.resident and not inst.queue)


def test_decode_capacity_respects_slo():
    from repro.simulator.costmodel import InstanceCostModel
    t = DEC[0]
    cm = InstanceCostModel(MODEL, "decode", t.placement, CFG_BY_NAME, WL)
    cap = cm.decode_capacity
    assert cm.decode_pipeline_latency(cap) <= MODEL.decode_slo_ms / 1e3 + 1e-9
    # the combined API returns the same floats as the split calls
    it, lat = cm.decode_times(cap)
    assert it == cm.decode_iter_time(cap)
    assert lat == cm.decode_pipeline_latency(cap)
    # template throughput should be realizable within ~2x by the sim model
    rate = cap / cm.decode_iter_time(cap)
    assert rate >= 0.4 * t.throughput


# --------------------------------------------------------------- bugfixes
def test_cold_start_holds_requests():
    """Arrivals during INIT_DELAY_S are held and flushed at ready_at,
    not dropped (the seed dropped every request whose pool was still
    initializing)."""
    sim = _sim_with_instances(ready_delay=INIT_DELAY_S)
    reqs = gen_requests(MODEL.name, MODEL.trace, 1.0, 60, seed=3)
    assert all(r.arrival < INIT_DELAY_S for r in reqs)
    for r in reqs:
        sim.submit(r)
    sim.run_until(3600.0)
    assert sim.dropped == 0
    assert {r.rid for r in sim.finished} == {r.rid for r in reqs}
    for r in sim.finished:
        assert r.prefill_done >= INIT_DELAY_S - 1e-9


def test_no_pool_at_all_still_drops():
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL})
    sim.submit(Request(0, MODEL.name, 1.0, 64, 8))
    sim.run_until(100.0)
    assert sim.dropped == 1


def test_decode_ewma_updates_and_straggler_decay():
    """The decode branch feeds the router's EWMA (dead code in the
    seed), and an instance with queue pressure loses routing weight."""

    class SlowCM:
        """Cost-model wrapper slowing decode by ``factor``."""

        def __init__(self, cm, factor):
            self._cm = cm
            self._f = factor
            self.prefill_chunk = getattr(cm, "prefill_chunk", 1)

        def __getattr__(self, name):
            return getattr(self._cm, name)

        @property
        def decode_capacity(self):
            return max(self._cm.decode_capacity // 8, 1)

        def decode_times(self, b):
            it, lat = self._cm.decode_times(b)
            return it * self._f, lat * self._f

        def decode_iter_time(self, b):
            return self._cm.decode_iter_time(b) * self._f

        def decode_pipeline_latency(self, b):
            return self._cm.decode_pipeline_latency(b) * self._f

    from repro.simulator.costmodel import InstanceCostModel
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL})
    sim.add_instance("r0", PRE[0], ready_delay=0.0)
    base = InstanceCostModel(MODEL, "decode", DEC[0].placement, CFG_BY_NAME,
                             WL)
    slow = sim.add_instance("r0", DEC[0], ready_delay=0.0,
                            cm=SlowCM(base, 40.0))
    fast = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    for r in gen_requests(MODEL.name, MODEL.trace, 4.0, 120, seed=4):
        sim.submit(r)
    sim.run_until(120.0)
    # mid-load: decode iterations updated the EWMA (seed: always 0.0)
    # and the straggler's decayed weight makes the router prefer the
    # fast instance despite the tie-breaking order (slow added first)
    assert sim._ewma_at(slow) > 0.0
    assert sim.route(MODEL.name, "decode") is fast
    sim.run_until(4000.0)
    assert fast.tokens_out > slow.tokens_out


def test_failure_reroutes_decode_queue_without_prefill():
    """A dead decode instance's admission queue rejoins the decode pool
    directly: prefill latencies are recorded exactly once per request
    (the seed re-ran them through prefill, double-counting)."""
    sim = _sim_with_instances()
    d2 = sim.add_instance("r0", DEC[0], ready_delay=0.0)
    reqs = gen_requests(MODEL.name, MODEL.trace, 4.0, 60, seed=5)
    for r in reqs:
        sim.submit(r)
    sim.run_until(90.0)
    victims = [i for i in sim.instances.values()
               if i.phase == "decode" and (i.resident or i.queue)]
    assert victims, "expected in-flight decode work at t=90"
    sim.kill_instance(victims[0])
    sim.run_until(7200.0)
    n_prefilled = len([r for r in reqs if r.prefill_done >= 0])
    assert sim.reqlog.n_first[MODEL.name] == n_prefilled
    assert {r.rid for r in sim.finished} == {r.rid for r in reqs}
    assert sim.dropped == 0


# ------------------------------------------------------------ equivalence
def _gauntlet(batched):
    """Seeded workload exercising cold start, decode and prefill kills
    mid-flight, drain, scale-up, and epoch-style horizons."""
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                    batched=batched)
    sim.add_instance("r0", PRE[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[0], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", DEC[1], ready_delay=INIT_DELAY_S)
    sim.add_instance("r0", PRE[1], ready_delay=INIT_DELAY_S)
    reqs = gen_requests(MODEL.name, MODEL.trace, 3.0, 300, seed=7)
    for r in reqs:
        sim.submit(r)
    sim.run_until(120.0)
    sim.kill_instance(sim.instances[1])     # decode node failure
    sim.run_until(200.0)
    sim.kill_instance(sim.instances[0])     # prefill node failure
    sim.run_until(240.0)
    sim.drain_instance(sim.instances[2])
    sim.add_instance("r0", DEC[0])          # replacement pays INIT_DELAY
    for t in (360.0, 480.0, 3600.0):
        sim.run_until(t)
    return sim, reqs


def test_batched_oracle_equivalence():
    """The batched loop reproduces the per-iteration oracle's
    accounting bit-for-bit: same finished set, same drops, same
    per-request latencies/counters, same goodput per window."""
    s1, r1 = _gauntlet(batched=False)
    s2, r2 = _gauntlet(batched=True)
    m = MODEL.name
    assert s1.dropped == s2.dropped
    assert {r.rid for r in s1.finished} == {r.rid for r in s2.finished}
    assert len(s1.tokens[m]) == len(s2.tokens[m])
    fin = {r.rid for r in s1.finished}
    d1 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r1 if r.rid in fin}
    d2 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r2 if r.rid in fin}
    assert d1 == d2                         # bit-identical, not approx
    for t0 in range(0, 3600, 60):
        assert s1.goodput(m, t0, t0 + 60) == s2.goodput(m, t0, t0 + 60)
        assert s1.throughput(m, t0, t0 + 60) == \
            s2.throughput(m, t0, t0 + 60)
    # the batched loop actually batched: far fewer run records than
    # tokens (the oracle writes one record per iteration)
    assert s2.tokens[m].n_runs < s1.tokens[m].n_runs


def _kill_run(batched, *, t_kill, victim_idx, drain_first=False,
              rate=3.0, seed=11):
    """One seeded run killing a specific instance at a specific time,
    used to cross-check kill edge cases batched vs oracle."""
    sim = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                    batched=batched)
    sim.add_instance("r0", PRE[0], ready_delay=0.0)
    sim.add_instance("r0", PRE[1], ready_delay=0.0)
    sim.add_instance("r0", DEC[0], ready_delay=0.0)
    sim.add_instance("r0", DEC[1], ready_delay=0.0)
    reqs = gen_requests(MODEL.name, MODEL.trace, rate, 120, seed=seed)
    for r in reqs:
        sim.submit(r)
    sim.run_until(t_kill)
    victim = sim.instances[victim_idx]
    if drain_first:
        sim.drain_instance(victim)
    sim.kill_instance(victim)
    sim.run_until(7200.0)
    return sim, reqs


def _assert_kill_equiv(t_kill, victim_idx, **kw):
    s1, r1 = _kill_run(False, t_kill=t_kill, victim_idx=victim_idx, **kw)
    s2, r2 = _kill_run(True, t_kill=t_kill, victim_idx=victim_idx, **kw)
    m = MODEL.name
    assert s1.dropped == s2.dropped
    assert {r.rid for r in s1.finished} == {r.rid for r in s2.finished}
    fin = {r.rid for r in s1.finished}
    d1 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r1 if r.rid in fin}
    d2 = {r.rid: (r.finish, r.prefill_done, r.decode_slo_ok,
                  r.decode_tokens_ok) for r in r2 if r.rid in fin}
    assert d1 == d2
    assert s1.goodput(m, 0, 7200) == s2.goodput(m, 0, 7200)
    return s1, s2, r1


# ------------------------------------------------- kill edge cases
def test_kill_draining_instance():
    """Killing an instance that is already draining: the drain's
    finish-in-flight promise is superseded, work re-routes, and both
    loops agree bit-for-bit."""
    s1, s2, reqs = _assert_kill_equiv(90.0, 2, drain_first=True)
    for s in (s1, s2):
        inst = s.instances[2]
        assert inst.dead and inst.draining
        assert not inst.resident and not inst.queue
        assert s.dropped == 0
        assert {r.rid for r in s.finished} == {r.rid for r in reqs}


def test_kill_prefill_with_admission_queue():
    """Killing a prefill instance whose admission queue is non-empty:
    the queued (never-prefilled) requests re-enter via _on_arrival and
    prefill exactly once, on the surviving instance."""
    # flood so the strongest prefill instance holds a backlog at t=40
    s1, s2, reqs = _assert_kill_equiv(40.0, 0, rate=30.0, seed=12)
    for s in (s1, s2):
        n_prefilled = len([r for r in reqs if r.prefill_done >= 0])
        assert s.reqlog.n_first[MODEL.name] == n_prefilled
        assert {r.rid for r in s.finished} == {r.rid for r in reqs}


def test_kill_prefill_queue_was_nonempty():
    """The companion probe for the edge above: the victim really held
    queued work when the kill landed (otherwise the test is vacuous)."""
    sim2 = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL})
    sim2.add_instance("r0", PRE[0], ready_delay=0.0)
    sim2.add_instance("r0", PRE[1], ready_delay=0.0)
    sim2.add_instance("r0", DEC[0], ready_delay=0.0)
    sim2.add_instance("r0", DEC[1], ready_delay=0.0)
    for r in gen_requests(MODEL.name, MODEL.trace, 30.0, 120, seed=12):
        sim2.submit(r)
    sim2.run_until(40.0)
    assert len(sim2.instances[0].queue) > 0


def test_kill_exactly_on_span_boundary():
    """A kill landing exactly on a batched-span iteration boundary
    counts that iteration as complete — the same accounting the oracle
    produces when its _decode_done at that instant fires first."""
    # probe (batched): find a decode span boundary strictly ahead
    probe = Simulator({MODEL.name: MODEL}, CFG_BY_NAME, {MODEL.name: WL},
                      batched=True)
    probe.add_instance("r0", PRE[0], ready_delay=0.0)
    probe.add_instance("r0", PRE[1], ready_delay=0.0)
    probe.add_instance("r0", DEC[0], ready_delay=0.0)
    probe.add_instance("r0", DEC[1], ready_delay=0.0)
    for r in gen_requests(MODEL.name, MODEL.trace, 3.0, 120, seed=11):
        probe.submit(r)
    probe.run_until(60.0)
    victim = probe.instances[2]
    assert victim.span is not None, "probe expects an in-flight span"
    ahead = [b for b in victim.span.bounds if b > probe.now + 1e-9]
    assert ahead, "probe expects future iteration boundaries"
    t_star = ahead[0]
    s1, s2, _ = _assert_kill_equiv(t_star, 2)
    assert s1.instances[2].dead and s2.instances[2].dead


def test_tokenruns_window_counts():
    from repro.simulator.sim import TokenRuns
    tr = TokenRuns()
    # run 1: boundaries 1.5, 2.5, 3.5 at b=2, ok
    tr.add(0.5, 1.0, 3, 2, True, 3.5)
    # run 2: single boundary at 4.0, b=3, not ok
    tr.add(3.0, 1.0, 1, 3, False, 4.0)
    assert len(tr) == 9
    assert tr.count(0.0, 10.0) == 9
    assert tr.count(0.0, 10.0, ok_only=True) == 6
    assert tr.count(2.0, 3.6) == 4          # boundaries 2.5, 3.5
    assert tr.count(3.9, 4.1) == 3
    assert tr.count(4.0, 10.0) == 3         # boundary exactly at q0
    assert tr.count(0.0, 1.5) == 0          # q1 exclusive
