"""MILP substrate: numpy branch-and-bound vs HiGHS (property-based)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from repro.solver.milp import MilpModel


@st.composite
def milp_instances(draw):
    n = draw(st.integers(2, 5))
    m = draw(st.integers(1, 4))
    obj = [draw(st.integers(-5, 5)) for _ in range(n)]
    ubs = [draw(st.integers(1, 6)) for _ in range(n)]
    integer = [draw(st.booleans()) for _ in range(n)]
    rows = []
    for _ in range(m):
        coeffs = {j: draw(st.integers(-3, 3)) for j in range(n)}
        ub = draw(st.integers(0, 12))
        rows.append((coeffs, ub))
    return obj, ubs, integer, rows


def _build(obj, ubs, integer, rows):
    mdl = MilpModel()
    for o, u, i in zip(obj, ubs, integer):
        mdl.add_var(obj=float(o), lb=0.0, ub=float(u), integer=i)
    for coeffs, ub in rows:
        mdl.add_constr({k: float(v) for k, v in coeffs.items()},
                       ub=float(ub))
    return mdl


@settings(max_examples=40, deadline=None)
@given(milp_instances())
def test_bb_matches_highs(inst):
    obj, ubs, integer, rows = inst
    r1 = _build(obj, ubs, integer, rows).solve(backend="scipy")
    r2 = _build(obj, ubs, integer, rows).solve(backend="numpy",
                                               time_limit=20)
    assert r1.ok == r2.ok
    if r1.ok:
        assert abs(r1.obj - r2.obj) < 1e-5, (r1.obj, r2.obj)


def test_solution_respects_constraints():
    mdl = MilpModel()
    x = mdl.add_var(obj=-3, ub=10, integer=True)
    y = mdl.add_var(obj=-2, ub=10, integer=True)
    mdl.add_constr({x: 1, y: 1}, ub=7)
    mdl.add_constr({x: 2, y: 1}, ub=10)
    res = mdl.solve()
    assert res.ok
    assert res.x[x] + res.x[y] <= 7 + 1e-6
    assert 2 * res.x[x] + res.x[y] <= 10 + 1e-6
    assert abs(res.obj - (-17)) < 1e-6        # x=3,y=4


def test_infeasible_detected():
    mdl = MilpModel()
    x = mdl.add_var(obj=1, lb=0, ub=5, integer=True)
    mdl.add_constr({x: 1}, lb=10)             # impossible
    assert not mdl.solve().ok
