"""MILP substrate: numpy branch-and-bound vs HiGHS (property-based)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from repro.solver.milp import MilpModel


@st.composite
def milp_instances(draw):
    n = draw(st.integers(2, 5))
    m = draw(st.integers(1, 4))
    obj = [draw(st.integers(-5, 5)) for _ in range(n)]
    ubs = [draw(st.integers(1, 6)) for _ in range(n)]
    integer = [draw(st.booleans()) for _ in range(n)]
    rows = []
    for _ in range(m):
        coeffs = {j: draw(st.integers(-3, 3)) for j in range(n)}
        ub = draw(st.integers(0, 12))
        rows.append((coeffs, ub))
    return obj, ubs, integer, rows


def _build(obj, ubs, integer, rows):
    mdl = MilpModel()
    for o, u, i in zip(obj, ubs, integer):
        mdl.add_var(obj=float(o), lb=0.0, ub=float(u), integer=i)
    for coeffs, ub in rows:
        mdl.add_constr({k: float(v) for k, v in coeffs.items()},
                       ub=float(ub))
    return mdl


@settings(max_examples=40, deadline=None)
@given(milp_instances())
def test_bb_matches_highs(inst):
    obj, ubs, integer, rows = inst
    r1 = _build(obj, ubs, integer, rows).solve(backend="scipy")
    r2 = _build(obj, ubs, integer, rows).solve(backend="numpy",
                                               time_limit=20)
    assert r1.ok == r2.ok
    if r1.ok:
        assert abs(r1.obj - r2.obj) < 1e-5, (r1.obj, r2.obj)


def test_solution_respects_constraints():
    mdl = MilpModel()
    x = mdl.add_var(obj=-3, ub=10, integer=True)
    y = mdl.add_var(obj=-2, ub=10, integer=True)
    mdl.add_constr({x: 1, y: 1}, ub=7)
    mdl.add_constr({x: 2, y: 1}, ub=10)
    res = mdl.solve()
    assert res.ok
    assert res.x[x] + res.x[y] <= 7 + 1e-6
    assert 2 * res.x[x] + res.x[y] <= 10 + 1e-6
    assert abs(res.obj - (-17)) < 1e-6        # x=3,y=4


def test_infeasible_detected():
    mdl = MilpModel()
    x = mdl.add_var(obj=1, lb=0, ub=5, integer=True)
    mdl.add_constr({x: 1}, lb=10)             # impossible
    assert not mdl.solve().ok


# ------------------------------------------------- batched construction
@st.composite
def ranged_instances(draw):
    """Instances with <=, ranged and equality rows (the shapes the
    columnar allocator emits through add_constrs_coo)."""
    n = draw(st.integers(2, 5))
    m = draw(st.integers(1, 4))
    obj = [draw(st.integers(-5, 5)) for _ in range(n)]
    ubs = [draw(st.integers(1, 6)) for _ in range(n)]
    integer = [draw(st.booleans()) for _ in range(n)]
    rows = []
    for _ in range(m):
        coeffs = {j: draw(st.integers(-3, 3)) for j in range(n)}
        kind = draw(st.integers(0, 2))
        hi = draw(st.integers(0, 12))
        if kind == 0:                          # one-sided <=
            lo = -np.inf
        elif kind == 1:                        # ranged
            lo = hi - draw(st.integers(0, 8))
        else:                                  # equality
            lo = hi
        rows.append((coeffs, float(lo), float(hi)))
    return obj, ubs, integer, rows


def _build_pervar(obj, ubs, integer, rows):
    mdl = MilpModel()
    for o, u, i in zip(obj, ubs, integer):
        mdl.add_var(obj=float(o), lb=0.0, ub=float(u), integer=i)
    for coeffs, lo, hi in rows:
        mdl.add_constr({k: float(v) for k, v in coeffs.items()},
                       lb=lo, ub=hi)
    return mdl


def _build_batched(obj, ubs, integer, rows):
    mdl = MilpModel()
    idx = mdl.add_vars(np.array(obj, dtype=float), lb=0.0,
                       ub=np.array(ubs, dtype=float),
                       integer=np.array(integer))
    assert list(idx) == list(range(len(obj)))
    data, ri, ci, lbs, his = [], [], [], [], []
    for i, (coeffs, lo, hi) in enumerate(rows):
        for j, v in coeffs.items():
            data.append(float(v))
            ri.append(i)
            ci.append(j)
        lbs.append(lo)
        his.append(hi)
    rid = mdl.add_constrs_coo(data, ri, ci, lb=np.array(lbs),
                              ub=np.array(his))
    assert len(rid) == len(rows)
    return mdl


@settings(max_examples=40, deadline=None)
@given(ranged_instances())
def test_batched_matches_pervar_and_bb(inst):
    """add_vars/add_constrs_coo build the same model as the per-var API,
    on both the HiGHS and the numpy branch-and-bound backends."""
    obj, ubs, integer, rows = inst
    r_ref = _build_pervar(*inst).solve(backend="scipy")
    r_coo = _build_batched(*inst).solve(backend="scipy")
    r_bb = _build_batched(*inst).solve(backend="numpy", time_limit=20)
    assert r_ref.ok == r_coo.ok == r_bb.ok
    if r_ref.ok:
        assert abs(r_ref.obj - r_coo.obj) < 1e-5, (r_ref.obj, r_coo.obj)
        assert abs(r_ref.obj - r_bb.obj) < 1e-5, (r_ref.obj, r_bb.obj)


def test_mixed_pervar_and_coo_rows():
    """Per-var rows and COO blocks can be interleaved; duplicate COO
    entries accumulate (scipy.sparse semantics), matching _densify."""
    def build():
        mdl = MilpModel()
        x, y = mdl.add_vars([-3.0, -2.0], ub=[10.0, 10.0], integer=True)
        mdl.add_constr({int(x): 1.0, int(y): 1.0}, ub=7.0)
        # 2x + y <= 10, with the x coefficient split across two entries
        mdl.add_constrs_coo([1.0, 1.0, 1.0], [0, 0, 0], [x, x, y],
                            ub=np.array([10.0]))
        mdl.add_constr({int(y): 1.0}, lb=1.0)          # y >= 1
        return mdl
    for backend in ("scipy", "numpy"):
        res = build().solve(backend=backend)
        assert res.ok
        assert abs(res.obj - (-17)) < 1e-6             # x=3, y=4
        assert res.x[1] >= 1 - 1e-9
