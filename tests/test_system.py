"""End-to-end behaviour tests for the paper's system (replaces the
scaffold placeholder): Coral's joint optimization vs baselines, the
heterogeneity opportunity (Fig 1/2 phenomena), sharding utilities."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.allocator import AllocProblem, Demand, allocate
from repro.core.baselines import (cauchy_allocate, helix_placement,
                                  homo_allocate, homo_library)
from repro.core.hardware import CORE_REGIONS, DEVICE_TYPES, NodeConfig, \
    make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import build_library
from repro.traces.workloads import workload_stats

CONFIGS = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
MODELS = [PAPER_MODELS["qwen3-32b"], PAPER_MODELS["phi4-14b"]]
WLS = {m.name: workload_stats(m.trace) for m in MODELS}


@pytest.fixture(scope="module")
def libs():
    lib = build_library(MODELS, CONFIGS, WLS, n_max=3, rho=8.0)
    hlib = homo_library(MODELS, CONFIGS, WLS, n_max=3, rho=8.0)
    return lib, hlib


def _demands(rate):
    out = []
    for m in MODELS:
        wl = WLS[m.name]
        out.append(Demand(m.name, "prefill", rate * wl.avg_prompt))
        out.append(Demand(m.name, "decode", rate * wl.avg_output))
    return out


def test_heterogeneous_templates_exist(libs):
    """Fig 1: mixed-GPU templates appear and some beat every homogeneous
    template on cost efficiency."""
    lib, hlib = libs
    for m in MODELS:
        temps = lib.get(m.name, "prefill")
        hetero = [t for t in temps if len(t.counts) > 1]
        assert hetero, f"no heterogeneous templates for {m.name}"

    def best_eff(ts):
        return max(t.throughput / t.cost(CORE_REGIONS[0],
                                         lib.config_by_name)
                   for t in ts)

    m = MODELS[0].name
    assert best_eff(lib.get(m, "prefill")) >= \
        best_eff(hlib.get(m, "prefill")) - 1e-9


def test_throughput_spectrum_density(libs):
    """Fig 1b: heterogeneous combos fill throughput gaps between
    homogeneous plans (max relative gap shrinks)."""
    lib, hlib = libs

    def max_gap(ts):
        v = sorted(t.throughput for t in ts)
        gaps = [(b - a) / b for a, b in zip(v, v[1:]) if b > 0]
        return max(gaps) if gaps else 1.0

    m = MODELS[0].name
    assert max_gap(lib.get(m, "decode")) <= max_gap(hlib.get(m, "decode"))


def test_joint_beats_greedy_under_contention(libs):
    """Fig 2: under scarce availability, joint optimization satisfies
    more demand than greedy per-model allocation."""
    lib, hlib = libs
    avail = {(r.name, c.name): 0 for r in CORE_REGIONS for c in CONFIGS}
    r0 = CORE_REGIONS[0].name
    for c in CONFIGS:
        avail[(r0, c.name)] = 3
    demands = _demands(rate=3.0)
    coral = allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                  demands, lib, time_limit=60))
    homo = homo_allocate(AllocProblem(CORE_REGIONS, CONFIGS, dict(avail),
                                      demands, hlib), hlib)
    unmet_c = sum(coral.unmet.values())
    unmet_h = sum(homo.unmet.values())
    assert unmet_c <= unmet_h + 1e-6


def test_helix_monolithic_vs_coral_decomposition(libs):
    """Fig 12 phenomenon: decomposing a fixed pool into multiple Serving
    Instances yields >= the per-node throughput of one monolithic
    pipeline."""
    lib, _ = libs
    m = PAPER_MODELS["qwen3-32b"]
    wl = WLS[m.name]
    pool = [NodeConfig(DEVICE_TYPES["L40S"], 1)] * 4 \
        + [NodeConfig(DEVICE_TYPES["L4"], 1)] * 6
    mono = helix_placement(m, "decode", wl, pool)
    temps = lib.get(m.name, "decode")
    best = max(temps, key=lambda t: t.throughput / t.n_nodes)
    if mono is not None:
        assert best.throughput / best.n_nodes >= \
            mono.throughput / len(pool) * 0.99


def test_sanitize_spec_divisibility():
    from repro.distributed.sharding import sanitize_spec, use_mesh
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh("data")
    with use_mesh(mesh):
        s = sanitize_spec(P("data", None), (12, 7))
        assert s == P("data", None)          # axis size 1 always divides
        s = sanitize_spec(P("model", None), (12, 7))
        assert s == P(None, None)            # unknown axis dropped


def test_constrain_noop_without_mesh():
    from repro.distributed.sharding import constrain
    x = jax.numpy.ones((4, 4))
    y = constrain(x, "data", None)
    np.testing.assert_allclose(x, y)
