"""PR-4 dominance pruning: the level-wise frontier must produce the
exact post-``pareto_prune`` template set of exhaustive enumeration, the
box-probing Pareto pass must match the pairwise reference on arbitrary
usage vectors, and incumbent-gated ``solve_batch`` must stay equivalent
to the reference solver."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.hardware import make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.placement import Placement, PlacementCache, \
    optimal_placement_exact
from repro.core.templates import (ServingTemplate, _pareto_mask_boxes,
                                  _pareto_mask_pairwise,
                                  _template_order_key, generate_templates,
                                  pareto_prune)
from repro.traces.workloads import workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))


# ------------------------------------------------- frontier equivalence
@pytest.mark.parametrize("phase", ["prefill", "decode"])
def test_frontier_equals_exhaustive(phase):
    """Frontier fast path == exhaustive exact solver + pareto_prune:
    identical keys and bit-exact throughputs, in identical order."""
    fast, fstats = generate_templates(MODEL, phase, CONFIGS, WL, n_max=4,
                                      rho=8.0, solver="fast")
    assert fstats.get("frontier"), "fast path should take the frontier"
    ref, rstats = generate_templates(MODEL, phase, CONFIGS, WL, n_max=4,
                                     rho=8.0, solver="exact")
    assert [(t.key, t.throughput) for t in fast] \
        == [(t.key, t.throughput) for t in ref]
    assert fstats["combos"] == rstats["combos"]
    assert fstats["templates_raw"] == rstats["templates_raw"]
    # every placement the frontier emits is a valid layer split
    for t in fast:
        assert sum(t.placement.layer_counts) == MODEL.n_layers
        assert all(j >= 1 for j in t.placement.layer_counts)


def test_cross_check_flag():
    """cross_check=True runs the exhaustive reference in-process and
    records the bit-identity proof in the stats."""
    temps, stats = generate_templates(MODEL, "decode", CONFIGS, WL,
                                      n_max=3, rho=8.0, cross_check=True)
    assert stats["cross_check"] == "ok"
    assert stats["templates"] == len(temps)
    assert stats["dominated"] == stats["templates_raw"] - len(temps)


def test_pruned_set_is_pareto_front():
    """The kept set is exactly the Pareto front of the raw set: every
    dropped template is dominated by a kept one, no kept template is
    dominated by any other raw template."""
    raw, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=4,
                                rho=8.0, prune=False)
    kept, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=4,
                                 rho=8.0)
    names = sorted({c.name for c in CONFIGS})

    def u(t):
        return tuple(t.usage().get(c, 0) for c in names)

    def dominates(a, b):            # a dominates b (distinct usages)
        return (a.throughput >= b.throughput and u(a) != u(b)
                and all(x <= y for x, y in zip(u(a), u(b))))

    kept_keys = {t.key for t in kept}
    for t in raw:
        if t.key in kept_keys:
            assert not any(dominates(o, t) for o in raw)
        else:
            assert any(dominates(o, t) for o in kept)


def test_equal_throughput_superset_dropped():
    """A superset combo that gains no throughput over a sub-combo must
    be pruned (the pre-PR-4 tie-break kept whichever enumerated first)."""
    def tmpl(counts, thr):
        nodes = tuple(n for n, c in counts for _ in range(c))
        return ServingTemplate("m", "decode", 80.0, counts,
                               Placement(1, (4,), (nodes,), thr), thr)

    small = tmpl((("a", 1),), 10.0)
    superset = tmpl((("a", 1), ("b", 2)), 10.0)
    better = tmpl((("b", 2),), 12.0)
    kept = pareto_prune([superset, small, better], ["a", "b"])
    assert [t.counts for t in kept] == [(("b", 2),), (("a", 1),)]
    # order is the deterministic dominance-compatible key
    assert kept == sorted(kept, key=_template_order_key)


# ------------------------------------------- box vs pairwise property
@st.composite
def _usage_case(draw):
    n = draw(st.integers(2, 60))
    d = draw(st.integers(1, 5))
    maxc = draw(st.integers(1, 9))
    quant = draw(st.integers(1, 6))     # coarse throughputs force ties
    rows, thr = [], []
    for i in range(n):
        rows.append([draw(st.integers(0, maxc)) for _ in range(d)])
        thr.append(draw(st.integers(1, quant)) * 7.5)
    return rows, thr


@settings(max_examples=120, deadline=None)
@given(_usage_case())
def test_box_prune_matches_pairwise(case):
    """Property: the sub-quadratic box pass and the pairwise reference
    keep exactly the same rows on random usage vectors (with heavy
    throughput ties), after the shared dominance-compatible sort."""
    rows, thr = case
    order = sorted(range(len(rows)),
                   key=lambda i: (-thr[i], sum(rows[i]), tuple(rows[i])))
    usage = np.array([rows[i] for i in order], dtype=np.int64)
    t = np.array([thr[i] for i in order], dtype=float)
    got = _pareto_mask_boxes(usage, t)
    assert got is not None, "cases are sized to fit the box path"
    ref = _pareto_mask_pairwise(usage)
    assert got.tolist() == ref.tolist(), (usage.tolist(), t.tolist())


def test_box_prune_budget_fallback():
    """Oversized boxes must defer to the pairwise path (None)."""
    usage = np.full((50, 8), 30, dtype=np.int64)
    thr = np.arange(50, dtype=float)
    assert _pareto_mask_boxes(usage, thr, budget=1e3) is None


# -------------------------------------------------- incumbent solving
def _make_tables(names, L, seed):
    r = np.random.default_rng(seed)
    base = {n: r.uniform(10, 200) for n in set(names)}
    cache = {}

    def tables(name, S):
        key = (name, S)
        if key not in cache:
            j = np.arange(1, L + 1)
            v = base[name] / (j ** (0.7 + 0.05 * S))
            cut = r.integers(max(L // 2, 1), L + 1)
            v = np.where(j <= cut, v, 0.0)
            cache[key] = np.minimum.accumulate(v)
        return cache[key]

    return tables


def test_solve_batch_incumbents_randomized():
    """With an incumbent, solve_batch returns None iff the true optimum
    does not strictly beat it, and the returned throughput is unchanged
    when it does."""
    for seed in range(60):
        r = np.random.default_rng(seed)
        K = int(r.integers(1, 7))
        L = int(r.integers(2, 13))
        pool = ["A", "B", "C", "D"]
        names = [pool[r.integers(0, 4)] for _ in range(K)]
        tables = _make_tables(names, L, seed)
        cache = PlacementCache(tables, L)
        pe = optimal_placement_exact(names, tables, L)
        te = pe.throughput if pe else 0.0
        for inc in (0.0, te * 0.5, te, te * 1.5):
            got = cache.solve_batch([names], incumbents=np.array([inc]))[0]
            if te > inc:
                assert got is not None and got.throughput == te, \
                    (seed, names, inc, te, got)
            else:
                assert got is None, (seed, names, inc, te, got)


def test_throughput_monotone_in_nodes():
    """The property the dominated-combo prune rests on: adding a node
    never decreases the optimal throughput."""
    for seed in range(40):
        r = np.random.default_rng(seed + 500)
        L = int(r.integers(2, 13))
        pool = ["A", "B", "C", "D"]
        K = int(r.integers(1, 5))
        names = [pool[r.integers(0, 4)] for _ in range(K)]
        tables = _make_tables(pool, L, seed)
        base = optimal_placement_exact(names, tables, L)
        tb = base.throughput if base else 0.0
        for extra in pool:
            ext = optimal_placement_exact(names + [extra], tables, L)
            tx = ext.throughput if ext else 0.0
            assert tx >= tb, (seed, names, extra, tb, tx)
