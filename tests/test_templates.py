"""Template generation: enumeration bounds, pruning losslessness."""
import numpy as np
import pytest

from repro.core.hardware import CORE_CONFIGS, CORE_REGIONS, make_node_configs
from repro.core.modelspec import PAPER_MODELS
from repro.core.templates import (enumerate_combos, generate_templates,
                                  pareto_prune, build_library)
from repro.core.allocator import AllocProblem, Demand, allocate
from repro.traces.workloads import workload_stats

MODEL = PAPER_MODELS["phi4-14b"]
WL = workload_stats(MODEL.trace)
CONFIGS = make_node_configs(["L40S", "L4"], sizes=(1, 2))


def test_enumerate_combos_bounds():
    for combo in enumerate_combos(CONFIGS, n_max=3, mem_lo_gb=20,
                                  mem_hi_gb=200):
        assert 1 <= len(combo) <= 3
        mem = sum(c.mem_gb for c in combo)
        assert 20 <= mem <= 200


def test_generate_templates_valid():
    temps, stats = generate_templates(MODEL, "decode", CONFIGS, WL,
                                      n_max=3, rho=8.0)
    assert temps, "no templates generated"
    for t in temps:
        assert t.throughput > 0
        assert t.n_nodes <= 3
        assert sum(t.placement.layer_counts) == MODEL.n_layers
        mem = sum(next(c for c in CONFIGS if c.name == name).mem_gb * n
                  for name, n in t.counts)
        assert mem <= 8.0 * MODEL.bytes_total / 1e9 + 1e-9


def test_pareto_prune_lossless_for_allocator():
    """Optimal allocation cost must be unchanged by dominance pruning."""
    temps, _ = generate_templates(MODEL, "decode", CONFIGS, WL, n_max=3,
                                  rho=8.0, prune=False)
    names = sorted({c.name for c in CONFIGS})
    pruned = pareto_prune(temps, names)
    assert len(pruned) <= len(temps)

    from repro.core.templates import TemplateLibrary
    avail = {(r.name, c.name): 6 for r in CORE_REGIONS for c in CONFIGS}
    demands = [Demand(MODEL.name, "decode", 800.0)]

    def solve(ts):
        lib = TemplateLibrary(config_by_name={c.name: c for c in CONFIGS})
        lib.add((MODEL.name, "decode"), ts, {})
        prob = AllocProblem(CORE_REGIONS, CONFIGS, dict(avail), demands, lib,
                            time_limit=30)
        return allocate(prob)

    a1, a2 = solve(temps), solve(pruned)
    assert a1.ok and a2.ok
    assert not a1.unmet and not a2.unmet
    assert abs(a1.cost_per_hour - a2.cost_per_hour) < 1e-6


def test_recurrent_model_templates():
    """SSM-backed served models get templates too (arch bridge)."""
    from repro.core.modelspec import from_model_config
    from repro.configs.registry import get_config
    sm = from_model_config(get_config("zamba2-1.2b"))
    temps, _ = generate_templates(sm, "decode", CONFIGS, WL, n_max=2,
                                  rho=12.0)
    assert temps
