"""Trace generators: determinism, statistics, availability walks."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # no network in this container
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.hardware import CORE_CONFIGS, CORE_REGIONS
from repro.traces.workloads import (TRACES, default_base_availability,
                                    gen_availability, gen_requests,
                                    gen_requests_schedule, workload_stats)


def test_determinism():
    a = gen_requests("m", "burstgpt", 5.0, 100.0, seed=7)
    b = gen_requests("m", "burstgpt", 5.0, 100.0, seed=7)
    assert [(r.arrival, r.prompt_len, r.output_len) for r in a] \
        == [(r.arrival, r.prompt_len, r.output_len) for r in b]


@pytest.mark.parametrize("trace", list(TRACES))
def test_request_statistics(trace):
    reqs = gen_requests("m", trace, rate=20.0, duration=600.0, seed=0)
    spec = TRACES[trace]
    # arrival rate within 15%
    rate = len(reqs) / 600.0
    assert abs(rate - 20.0) / 20.0 < 0.15
    # mean lengths within 20% of spec
    pm = np.mean([r.prompt_len for r in reqs])
    om = np.mean([r.output_len for r in reqs])
    assert abs(pm - spec.prompt_mean) / spec.prompt_mean < 0.2
    assert abs(om - spec.output_mean) / spec.output_mean < 0.2
    assert all(r.arrival < 600.0 for r in reqs)
    assert all(r.prompt_len >= 8 and r.output_len >= 4 for r in reqs)


def test_burstgpt_burstier_than_azure():
    def cv(trace):
        reqs = gen_requests("m", trace, 10.0, 1200.0, seed=1)
        gaps = np.diff([r.arrival for r in reqs])
        return gaps.std() / gaps.mean()

    assert cv("burstgpt") > cv("azure_conv") * 1.3


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 8))
def test_availability_walk_bounds(seed, n_epochs):
    base = default_base_availability(CORE_CONFIGS, abundance=20)
    walks = gen_availability(CORE_REGIONS, CORE_CONFIGS, n_epochs, base,
                             seed=seed)
    assert len(walks) == n_epochs
    for epoch in walks:
        for (r, c), v in epoch.items():
            assert v >= 0
            assert isinstance(v, int)


def test_bursty_trace_covers_full_duration():
    """Regression (silent truncation): with the old fixed 1.5x gap
    buffer, seeds 343/737 at (rate=0.5, duration=60, burstgpt CV 2.2)
    drew gap samples summing to only ~41s/~40s — the trace ended early
    with no error.  The renewal process must now be extended until it
    passes the horizon, so arrivals cover the whole duration."""
    for seed, old_end in ((343, 41.4), (737, 40.0)):
        reqs = gen_requests("m", "burstgpt", 0.5, 60.0, seed=seed)
        assert max(r.arrival for r in reqs) > old_end
        assert all(r.arrival < 60.0 for r in reqs)
    # and the per-seed arrival count stays unbiased on average
    counts = [len(gen_requests("m", "burstgpt", 0.5, 60.0, seed=s))
              for s in range(200)]
    assert abs(np.mean(counts) / (0.5 * 60.0) - 1.0) < 0.1


def test_gen_requests_zero_rate_is_empty():
    assert gen_requests("m", "burstgpt", 0.0, 100.0, seed=0) == []


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_availability_walk_bounded_long_horizon(seed):
    """Regression (unbounded drift): the clip ceiling used to be
    recomputed from the *current* level each epoch, so the walk's bound
    drifted with the walk itself.  Over a long horizon every level must
    stay within 4x its per-(region, config) base."""
    base = default_base_availability(CORE_CONFIGS, abundance=20)
    walks = gen_availability(CORE_REGIONS, CORE_CONFIGS, 400, base,
                             seed=seed)
    for epoch in walks:
        for (r, c), v in epoch.items():
            b = base[c]
            assert v <= 4.0 * max(b, 1.0) + 0.5


def test_gen_requests_schedule_piecewise_rates():
    rates = [2.0, 0.0, 6.0]
    reqs = gen_requests_schedule("m", "azure_conv", rates, 120.0, seed=5)
    for e, r in enumerate(rates):
        n = len([q for q in reqs if e * 120.0 <= q.arrival < (e + 1) * 120.0])
        assert abs(n - r * 120.0) <= max(0.35 * r * 120.0, 2)
    assert all(q.arrival < 360.0 for q in reqs)
    rids = [q.rid for q in reqs]
    assert len(set(rids)) == len(rids)


def test_workload_stats_consistent():
    for trace, spec in TRACES.items():
        wl = workload_stats(trace)
        assert wl.avg_prompt == spec.prompt_mean
        assert wl.avg_output == spec.output_mean
        assert wl.avg_ctx_decode > wl.avg_prompt
