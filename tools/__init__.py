"""Operational tooling for the repo: CI entry points, the benchmark
regression gate (``check_bench``) and the repo-specific static-analysis
suite (``corallint``)."""
