"""Benchmark-regression gate.

Compares freshly written ``artifacts/BENCH_*.json`` files against the
committed reference points in ``tools/bench_reference.json`` and exits
non-zero when any tracked metric regressed by more than 20%.

Tracked metrics are noise-robust ratios/rates (speedups, combos/s) —
never raw wall seconds, which swing ~2x on this shared container.  All
metrics are higher-is-better.

Usage:
    python tools/check_bench.py            # compare, exit 1 on regression
    python tools/check_bench.py --update   # rewrite the reference file
    benchmarks/run.py --check              # compare after the full suite

When a new benchmark lands, run it once and ``--update`` to commit its
reference points alongside the code.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts")
REF_PATH = os.path.join(ROOT, "tools", "bench_reference.json")
THRESHOLD = 0.20        # fail when new < (1 - THRESHOLD) * reference


def _load(name: str):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def extract_metrics() -> Dict[str, float]:
    """Flatten the tracked metrics of every BENCH_*.json present."""
    out: Dict[str, float] = {}
    d = _load("BENCH_sim_loop.json")
    if d:
        for r in d.get("results", []):
            out[f"sim_loop_speedup_{r['scenario']}"] = r["speedup"]
    d = _load("BENCH_template_gen.json")
    if d:
        for r in d.get("results", []):
            out[f"template_gen_{r['solver']}_nmax{r['n_max']}"
                f"_combos_per_s"] = r["combos_per_s"]
    d = _load("BENCH_allocator.json")
    if d:
        for r in d.get("results", []):
            tag = r["scale"]
            out[f"allocator_build_speedup_{tag}"] = r["build_speedup"]
            out[f"allocator_update_speedup_{tag}"] = r["update_speedup"]
            out[f"allocator_objective_ok_{tag}"] = \
                1.0 if r.get("objective_ok") else 0.0
    return out


def check(threshold: float = THRESHOLD) -> int:
    fresh = extract_metrics()
    if not os.path.exists(REF_PATH):
        print(f"check_bench: no reference file at {REF_PATH}; "
              f"run with --update to create it")
        return 1
    with open(REF_PATH) as f:
        ref = json.load(f)
    failures = []
    for name, ref_val in sorted(ref.items()):
        new_val = fresh.get(name)
        if new_val is None:
            failures.append(f"{name}: missing from fresh artifacts "
                            f"(reference {ref_val:.3g})")
            continue
        floor = (1.0 - threshold) * ref_val
        status = "ok" if new_val >= floor else "REGRESSED"
        print(f"{name:48s} ref={ref_val:10.3g} new={new_val:10.3g} "
              f"[{status}]")
        if new_val < floor:
            failures.append(f"{name}: {new_val:.3g} < "
                            f"{floor:.3g} (-{threshold:.0%} of "
                            f"{ref_val:.3g})")
    for name in sorted(set(fresh) - set(ref)):
        print(f"{name:48s} new={fresh[name]:10.3g} [untracked — "
              f"run --update to pin]")
    if failures:
        print("\nBENCH REGRESSIONS:\n  " + "\n  ".join(failures))
        return 1
    print(f"\ncheck_bench: {len(ref)} reference metrics within "
          f"{threshold:.0%}")
    return 0


def update() -> int:
    fresh = extract_metrics()
    if not fresh:
        print("check_bench: no BENCH_*.json artifacts to pin")
        return 1
    with open(REF_PATH, "w") as f:
        json.dump(fresh, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: pinned {len(fresh)} metrics to {REF_PATH}")
    return 0


if __name__ == "__main__":
    sys.exit(update() if "--update" in sys.argv[1:] else check())
