"""Benchmark-regression gate.

Compares freshly written ``artifacts/BENCH_*.json`` files against the
committed reference points in ``tools/bench_reference.json`` and exits
non-zero when any tracked metric regressed by more than 20%.

Tracked metrics are noise-robust ratios/rates (speedups, combos/s) —
never raw wall seconds, which swing ~2x on this shared container.  All
metrics are higher-is-better.

Usage:
    python tools/check_bench.py                   # compare, exit 1 on
                                                  # regression
    python tools/check_bench.py --json out.json   # also write a
                                                  # machine-readable
                                                  # summary (CI step)
    python tools/check_bench.py --require-all     # absent BENCH files
                                                  # fail too (full gate)
    python tools/check_bench.py --update          # rewrite the
                                                  # reference file
    benchmarks/run.py --check                     # compare after the
                                                  # full suite

By default a reference metric whose *whole artifact file* is absent is
skipped (so a partial ``run.py --only`` smoke — the CI path — gates
only what it ran), as is a metric whose scenario the artifact
explicitly lists in its ``fast_trimmed`` field (BENCH_FAST trims some
scenarios, e.g. sim_loop's steady_rate6).  Any *other* missing metric
— and, under ``--require-all``, every missing metric — fails, so a
benchmark silently dropping a result is still caught.  When a new
benchmark lands, run it once and ``--update`` to commit its reference
points alongside the code.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(ROOT, "artifacts")
REF_PATH = os.path.join(ROOT, "tools", "bench_reference.json")
THRESHOLD = 0.20        # fail when new < (1 - THRESHOLD) * reference


def _load(name: str):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def extract_metrics() -> Dict[str, float]:
    """Flatten the tracked metrics of every BENCH_*.json present."""
    out: Dict[str, float] = {}
    d = _load("BENCH_sim_loop.json")
    if d:
        for r in d.get("results", []):
            if "speedup" in r:      # the obs_overhead row has none
                out[f"sim_loop_speedup_{r['scenario']}"] = r["speedup"]
        if "obs_overhead_ok" in d:
            # RequestLog instrumentation priced under the <5% budget
            # (asserted absolutely in sim_loop.py; pinned here too so
            # the row silently disappearing is caught)
            out["sim_loop_obs_overhead_ok"] = \
                1.0 if d["obs_overhead_ok"] else 0.0
    d = _load("BENCH_template_gen.json")
    if d:
        for r in d.get("results", []):
            scale = r.get("scale", "core")
            tag = "" if scale == "core" else f"_{scale}"
            out[f"template_gen_{r['solver']}{tag}"
                f"_nmax{r['n_max']}_combos_per_s"] = r["combos_per_s"]
    d = _load("BENCH_allocator.json")
    if d:
        for r in d.get("results", []):
            tag = r["scale"]
            out[f"allocator_build_speedup_{tag}"] = r["build_speedup"]
            out[f"allocator_update_speedup_{tag}"] = r["update_speedup"]
            out[f"allocator_objective_ok_{tag}"] = \
                1.0 if r.get("objective_ok") else 0.0
        s = d.get("resolve_stream")
        if s:
            # three-tier online re-solve (decomposition PR): warm-epoch
            # speedup ratios vs the forced-monolithic path plus the
            # pinned-at-1.0 acceptance booleans (sub-second p50, stream
            # objective parity)
            out["allocator_resolve_speedup_p50"] = s["resolve_speedup_p50"]
            out["allocator_resolve_speedup_p95"] = s["resolve_speedup_p95"]
            out["allocator_resolve_sub_s_ext"] = \
                1.0 if s.get("resolve_sub_s") else 0.0
            out["allocator_stream_parity_ok"] = \
                1.0 if s.get("parity_ok") else 0.0
        e = d.get("escalation")
        if e:
            out["allocator_escalated"] = \
                1.0 if e.get("escalation_ok") else 0.0
        for r in d.get("scenario_parity", []):
            out[f"allocator_parity_ok_{r['scenario']}"] = \
                1.0 if r.get("parity_ok") else 0.0
    d = _load("BENCH_control_loop.json")
    if d:
        for r in d.get("results", []):
            s = r["scenario"]
            out[f"control_loop_cost_parity_{s}"] = r["cost_parity"]
            out[f"control_loop_goodput_parity_{s}"] = r["goodput_parity"]
            if s in ("flash_crowd", "spot_preemption"):
                # regression-tracked like every other ratio; the
                # absolute beat-static (> 1.0) acceptance criterion is
                # asserted inside benchmarks/control_loop.py itself
                out[f"control_loop_vs_static_{s}"] = r["goodput_vs_static"]
            # closed-loop tail latency per model: p99 TTFT is gated as
            # its inverse (all metrics here are higher-is-better), and
            # SLO attainment fractions directly.  Model comes *before*
            # the scenario so the fast_trimmed endswith-scenario match
            # still applies to these names.
            for m, blk in sorted((r.get("slo_est") or {}).items()):
                out[f"control_loop_inv_ttft_p99_{m}_{s}"] = \
                    1.0 / max(blk["ttft_p99"], 1e-9)
                out[f"control_loop_ttft_attain_{m}_{s}"] = \
                    blk["ttft_attain"]
                out[f"control_loop_tbt_attain_{m}_{s}"] = \
                    blk["tbt_attain"]
    d = _load("BENCH_fault.json")
    if d:
        for r in d.get("results", []):
            s = r["scenario"]
            # hardened-vs-naive recovery ratios (time-to-recover and
            # post-fault coverage); the absolute beats-naive criterion
            # for crash_storm/crash_loop is asserted inside
            # benchmarks/fault_bench.py itself
            out[f"fault_recovery_speedup_{s}"] = r["recovery_speedup"]
            out[f"fault_coverage_ratio_{s}"] = r["coverage_ratio"]
            # hardened-discipline tail latency per model, gated the
            # same way as the control-loop SLO metrics above
            for m, blk in sorted((r.get("slo_hardened") or {}).items()):
                out[f"fault_inv_ttft_p99_{m}_{s}"] = \
                    1.0 / max(blk["ttft_p99"], 1e-9)
                out[f"fault_ttft_attain_{m}_{s}"] = \
                    blk["ttft_attain"]
                out[f"fault_tbt_attain_{m}_{s}"] = \
                    blk["tbt_attain"]
    return out


def _metric_file(name: str) -> str:
    """Artifact file a reference metric comes from (by name prefix)."""
    if name.startswith("sim_loop_"):
        return "BENCH_sim_loop.json"
    if name.startswith("template_gen_"):
        return "BENCH_template_gen.json"
    if name.startswith("allocator_"):
        return "BENCH_allocator.json"
    if name.startswith("control_loop_"):
        return "BENCH_control_loop.json"
    if name.startswith("fault_"):
        return "BENCH_fault.json"
    return ""


def check(threshold: float = THRESHOLD, json_out: str = None,
          require_all: bool = False) -> int:
    fresh = extract_metrics()
    summary = {"threshold": threshold, "require_all": require_all,
               "metrics": {}, "skipped_files": [], "failures": []}
    if not os.path.exists(REF_PATH):
        print(f"check_bench: no reference file at {REF_PATH}; "
              f"run with --update to create it")
        summary["failures"].append("missing reference file")
        summary["pass"] = False
        _write_json(json_out, summary)
        return 1
    with open(REF_PATH) as f:
        ref = json.load(f)
    failures = []
    skipped_files = sorted({
        _metric_file(n) for n in ref
        if n not in fresh and _metric_file(n)
        and not os.path.exists(os.path.join(ART, _metric_file(n)))})
    summary["skipped_files"] = skipped_files

    def _fast_trimmed(name):
        # the artifact names exactly which scenarios BENCH_FAST trimmed
        d = _load(_metric_file(name))
        return bool(d) and any(
            scen and name.endswith(scen)
            for scen in d.get("fast_trimmed", []))

    for name, ref_val in sorted(ref.items()):
        new_val = fresh.get(name)
        entry = {"ref": ref_val, "new": new_val}
        if new_val is None:
            if require_all:
                entry["status"] = "missing"
                failures.append(f"{name}: missing from fresh artifacts "
                                f"(reference {ref_val:.3g})")
            elif _metric_file(name) in skipped_files:
                entry["status"] = "skipped"
                print(f"{name:48s} ref={ref_val:10.3g} "
                      f"[skipped — artifact absent]")
            elif _fast_trimmed(name):
                entry["status"] = "skipped"
                print(f"{name:48s} ref={ref_val:10.3g} "
                      f"[skipped — trimmed under BENCH_FAST]")
            else:
                entry["status"] = "missing"
                failures.append(f"{name}: missing from fresh artifacts "
                                f"(reference {ref_val:.3g})")
            summary["metrics"][name] = entry
            continue
        floor = (1.0 - threshold) * ref_val
        ok = new_val >= floor
        entry["ratio"] = new_val / ref_val if ref_val else None
        entry["status"] = "ok" if ok else "regressed"
        summary["metrics"][name] = entry
        print(f"{name:48s} ref={ref_val:10.3g} new={new_val:10.3g} "
              f"[{'ok' if ok else 'REGRESSED'}]")
        if not ok:
            failures.append(f"{name}: {new_val:.3g} < "
                            f"{floor:.3g} (-{threshold:.0%} of "
                            f"{ref_val:.3g})")
    for name in sorted(set(fresh) - set(ref)):
        summary["metrics"][name] = {"ref": None, "new": fresh[name],
                                    "status": "untracked"}
        print(f"{name:48s} new={fresh[name]:10.3g} [untracked — "
              f"run --update to pin]")
    summary["failures"] = failures
    summary["pass"] = not failures
    _write_json(json_out, summary)
    if failures:
        print("\nBENCH REGRESSIONS:\n  " + "\n  ".join(failures))
        return 1
    checked = sum(1 for m in summary["metrics"].values()
                  if m["status"] in ("ok", "regressed"))
    print(f"\ncheck_bench: {checked} reference metrics within "
          f"{threshold:.0%}"
          + (f" ({len(skipped_files)} artifact file(s) absent, skipped)"
             if skipped_files else ""))
    return 0


def _write_json(path, summary) -> None:
    if not path:
        return
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: wrote summary to {path}")


def update() -> int:
    fresh = extract_metrics()
    if not fresh:
        print("check_bench: no BENCH_*.json artifacts to pin")
        return 1
    ref = {}
    if os.path.exists(REF_PATH):
        with open(REF_PATH) as f:
            ref = json.load(f)
    # re-pin only what was freshly measured; keep reference points whose
    # artifact files were not produced in this (possibly partial) run
    ref.update(fresh)
    with open(REF_PATH, "w") as f:
        json.dump(ref, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"check_bench: pinned {len(fresh)} metrics to {REF_PATH}")
    return 0


def main(argv) -> int:
    if "--update" in argv:
        return update()
    json_out = None
    if "--json" in argv:
        i = argv.index("--json") + 1
        if i >= len(argv):
            print("check_bench: --json requires a path argument")
            return 2
        json_out = argv[i]
    return check(json_out=json_out, require_all="--require-all" in argv)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
