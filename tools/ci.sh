#!/usr/bin/env bash
# CI entry point: tier-1 tests + a fast benchmark smoke with the
# machine-readable regression gate.  Runs in the project's no-network /
# no-pip container profile — nothing is installed here; numpy, scipy
# and pytest must already be on the image (the workflow preflights
# this).  Library pickles under artifacts/ are reused when present;
# .github/workflows/ci.yml caches them keyed on
# ``tools/lib_fingerprint.py`` so a cache hit skips every rebuild.
#
# Usage:
#   tools/ci.sh              # tier-1 + bench smoke + gate
#   tools/ci.sh --tests-only # tier-1 only
#
# CI_BENCH overrides the smoke's job list (see benchmarks/run.py
# ``jobs``); the default stays on the small/core points — the extended
# n_max=6 library build is exercised by the template_gen ext rows
# without building the full extended library.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export BENCH_FAST="${BENCH_FAST:-1}"
CI_BENCH="${CI_BENCH:-table1,template_gen,sim_loop,allocator,control_loop,fault}"

echo "== corallint =="
# repo-specific static analysis (tools/corallint): fails on any finding
# not in the committed baseline (tools/corallint/baseline.json)
python -m tools.corallint --json artifacts/corallint.json src tests benchmarks

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" == "--tests-only" ]]; then
    exit 0
fi

echo "== sanitize smoke (CORAL_SANITIZE=1, batched vs oracle) =="
# runtime invariant sanitizer over the control scenarios + crash_storm,
# asserting the span-batched loop stays bit-identical to the oracle
CORAL_SANITIZE=1 python tools/sanitize_smoke.py

echo "== trace smoke (crash_storm, schema + causal ordering) =="
# short crash_storm with TraceLog attached: validates every JSONL
# record against TRACE_SCHEMA, audits causal ordering (inject ->
# detect -> restart) and cross-checks trace counts vs EpochMetrics
python tools/trace_smoke.py

echo "== decompose smoke (three-tier ladder vs monolithic, both backends) =="
# core-scale auto-vs-monolithic objective parity on scipy/HiGHS plus a
# var-capped instance on the pure-numpy branch-and-bound backend
python tools/decompose_smoke.py

echo "== bench smoke (${CI_BENCH}) =="
python benchmarks/run.py --only "${CI_BENCH}"

echo "== bench gate =="
python tools/check_bench.py --json artifacts/bench_gate.json
