"""corallint — the repo-specific static-analysis suite.

Rules:

* **D1** determinism (unseeded entropy / hash-order iteration in
  ``src/repro/{simulator,control,core,solver}``)
* **L1** instance lifecycle (state-field writes outside the sanctioned
  ``sim.py`` transition methods)
* **A1** accounting (float accumulation into token/request counters,
  tokens-vs-tokens/s mixing via the ``_per_s`` convention)
* **S1** solver misuse (per-variable model API in loops, static COO
  triplet shape mismatches)
* **P1** hygiene (mutable default args / dataclass field defaults)

Run ``python -m tools.corallint src tests benchmarks`` from the repo
root; see ``tools/README.md`` for the suppression and baseline
workflow, and ``tests/test_corallint.py`` for per-rule fixtures.
"""
from .accounting import AccountingChecker
from .base import (Checker, FileContext, Finding, iter_py_files,
                   lint_paths, lint_source, load_baseline, save_baseline,
                   split_by_baseline)
from .determinism import DeterminismChecker
from .hygiene import HygieneChecker
from .lifecycle import LifecycleChecker
from .solvercheck import SolverChecker

ALL_CHECKERS = (DeterminismChecker, LifecycleChecker, AccountingChecker,
                SolverChecker, HygieneChecker)

__all__ = [
    "ALL_CHECKERS", "AccountingChecker", "Checker", "DeterminismChecker",
    "FileContext", "Finding", "HygieneChecker", "LifecycleChecker",
    "SolverChecker", "iter_py_files", "lint_paths", "lint_source",
    "load_baseline", "save_baseline", "split_by_baseline",
]
