"""corallint driver.

    python -m tools.corallint [paths...] [--json PATH] [--write-baseline]

Paths default to ``src tests benchmarks`` relative to the repo root.
Exit status is non-zero when findings exist that the committed baseline
(``tools/corallint/baseline.json``) does not accept.  ``--json`` writes
a machine-readable summary (mirroring ``check_bench.py --json``):
``{"counts": {...}, "findings": [...], "new": [...], "stale_baseline":
[...], "pass": bool}``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from . import (ALL_CHECKERS, lint_paths, load_baseline, save_baseline,
               split_by_baseline)

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.json")
DEFAULT_PATHS = ("src", "tests", "benchmarks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m tools.corallint")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/directories to lint (repo-relative; "
                         "default: src tests benchmarks)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable summary "
                         "('-' for stdout)")
    ap.add_argument("--baseline", default=BASELINE,
                    help="baseline file (default: the committed one)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings into the baseline")
    args = ap.parse_args(argv)

    findings = lint_paths(args.paths, ROOT, ALL_CHECKERS)
    if args.write_baseline:
        save_baseline(args.baseline, findings)
        print(f"baseline written: {len(findings)} finding(s) -> "
              f"{os.path.relpath(args.baseline, ROOT)}")
        return 0

    baseline = load_baseline(args.baseline)
    new, accepted, stale = split_by_baseline(findings, baseline)

    for f in new:
        print(f.format())
    if accepted:
        print(f"({len(accepted)} finding(s) accepted by baseline)")
    if stale:
        print(f"note: {len(stale)} stale baseline entr"
              f"{'y' if len(stale) == 1 else 'ies'} no longer observed: "
              + ", ".join(stale))

    ok = not new
    if args.json:
        counts = {}
        for f in findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        summary = {
            "counts": counts,
            "findings": [f.format() for f in findings],
            "new": [f.format() for f in new],
            "stale_baseline": stale,
            "pass": ok,
        }
        text = json.dumps(summary, indent=1)
        if args.json == "-":
            print(text)
        else:
            d = os.path.dirname(os.path.abspath(args.json))
            os.makedirs(d, exist_ok=True)
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(text + "\n")
    if ok:
        n = len(findings)
        print(f"corallint: OK ({n} accepted finding(s))" if n
              else "corallint: OK (0 findings)")
        return 0
    print(f"corallint: {len(new)} new finding(s) not in baseline",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
