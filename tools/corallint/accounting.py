"""A1 — accounting: token/request counters are exact integers (the
bit-identical batched-vs-oracle claims compare them with ``==``), and
token *totals* must never mix with token *rates*.

Flags:

* ``+=`` into a counter-named target with an evidently-float RHS
  (float literal, division, ``float()`` call);
* counters initialized as float literals (``self.x_total = 0.0``) and
  then ``+=``-accumulated anywhere in the class — an int counter
  accumulating through a float drifts once past 2**53 and breaks exact
  equality long before that under reordering;
* ``+``/``-`` arithmetic directly mixing a ``*_per_s`` rate name with a
  token-count name (the lightweight naming convention: rates carry a
  ``_per_s`` suffix, totals never do).
"""
from __future__ import annotations

import ast
import re
from typing import List, Optional, Set

from .base import Checker

COUNTER_RE = re.compile(
    r"token|(^|_)(count|counts|total|dropped|shed|arrived|finished|"
    r"iters|n_req)($|_)")
# money/time/score totals are legitimately float — not request counters
NOT_COUNTER_RE = re.compile(r"cost|price|weight|score|seconds|secs|rate")


def _term_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_counter(name: Optional[str]) -> bool:
    return bool(name) and bool(COUNTER_RE.search(name)) \
        and not NOT_COUNTER_RE.search(name) \
        and not name.endswith("per_s")


def _is_floaty(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.BinOp):
        if isinstance(node.op, ast.Div):
            return True
        return _is_floaty(node.left) or _is_floaty(node.right)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id == "float"
    if isinstance(node, ast.UnaryOp):
        return _is_floaty(node.operand)
    return False


class AccountingChecker(Checker):
    rule = "A1"
    description = "float accumulation into token/request counters or " \
                  "tokens-vs-tokens/s mixing"

    # ------------------------------------------------- float +=
    def visit_AugAssign(self, node: ast.AugAssign):
        if isinstance(node.op, ast.Add):
            name = _term_name(node.target)
            if _is_counter(name) and _is_floaty(node.value):
                self.report(node, f"float += into counter '{name}' — "
                                  "token/request counters are exact "
                                  "ints")
        self.generic_visit(node)

    # ------------------------------- float-initialized class counters
    def visit_ClassDef(self, node: ast.ClassDef):
        float_counters: Set[str] = set()
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and item.name == "__init__":
                for sub in ast.walk(item):
                    if isinstance(sub, ast.Assign) \
                            and isinstance(sub.value, ast.Constant) \
                            and isinstance(sub.value.value, float):
                        for tgt in sub.targets:
                            if isinstance(tgt, ast.Attribute) \
                                    and isinstance(tgt.value, ast.Name) \
                                    and tgt.value.id == "self" \
                                    and _is_counter(tgt.attr):
                                float_counters.add(tgt.attr)
        if float_counters:
            for sub in ast.walk(node):
                if isinstance(sub, ast.AugAssign) \
                        and isinstance(sub.op, ast.Add) \
                        and isinstance(sub.target, ast.Attribute) \
                        and sub.target.attr in float_counters:
                    self.report(
                        sub, f"counter '{sub.target.attr}' is "
                             "initialized as a float literal and "
                             "+=-accumulated — initialize it as int "
                             "for exact accounting")
        self.generic_visit(node)

    # -------------------------------------------- rate/total mixing
    def visit_BinOp(self, node: ast.BinOp):
        if isinstance(node.op, (ast.Add, ast.Sub)):
            ln, rn = _term_name(node.left), _term_name(node.right)
            sides = [(ln, rn), (rn, ln)]
            for a, b in sides:
                if a and a.endswith("per_s") and b and "token" in b \
                        and not b.endswith("per_s"):
                    self.report(
                        node, f"mixing rate '{a}' (tokens/s) with "
                              f"total '{b}' (tokens) in +/- arithmetic")
                    break
        self.generic_visit(node)
