"""corallint core: findings, suppression pragmas, the checker registry
and the committed-baseline workflow.

Every checker is a small ``ast.NodeVisitor`` with a rule ID (``D1``,
``L1``, ``A1``, ``S1``, ``P1``).  A finding is suppressed by a
``# corallint: disable=RULE[,RULE...]`` comment either trailing the
statement's first physical line or standing alone on the line above it
— always with a justification after the rule list, e.g.::

    t0 = time.time()   # corallint: disable=D1 - telemetry only

The committed baseline (``tools/corallint/baseline.json``) lists
accepted findings by ``rule:path`` key; the driver fails only on
findings *not* in the baseline, so the enforced repo state is "zero
new findings" (and the committed baseline is kept empty — true
positives get fixed, false positives get inline suppressions).
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set

SUPPRESS_RE = re.compile(
    r"#\s*corallint:\s*disable=([A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str                   # repo-relative, posix separators
    line: int
    col: int
    message: str

    @property
    def key(self) -> str:
        """Baseline identity: stable across unrelated edits (no line
        number — a baseline entry accepts the rule for the whole file,
        which is why the committed baseline stays empty instead)."""
        return f"{self.rule}:{self.path}"

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule} {self.message}"


class FileContext:
    """One parsed file handed to every checker."""

    def __init__(self, relpath: str, source: str,
                 tree: Optional[ast.AST] = None):
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.tree = tree if tree is not None \
            else ast.parse(source, filename=relpath)
        self.lines = source.splitlines()
        self.suppressions = parse_suppressions(self.lines)

    def suppressed(self, rule: str, line: int) -> bool:
        return rule in self.suppressions.get(line, ())


def parse_suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """Map 1-based line number -> suppressed rule IDs.  A trailing
    pragma covers its own line; a standalone comment line covers the
    *next* line (so multi-line statements are annotated above)."""
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip().upper() for r in m.group(1).split(",")}
        target = i + 1 if text.lstrip().startswith("#") else i
        out.setdefault(target, set()).update(rules)
        if "ALL" in rules:
            out[target].update(("D1", "L1", "A1", "S1", "P1"))
    return out


class Checker(ast.NodeVisitor):
    """Base class: subclasses set ``rule``/``description`` and call
    ``self.report(node, msg)``.  Suppression filtering is central
    (``lint_source``), so checkers just report."""

    rule = "X0"
    description = ""

    def __init__(self, ctx: FileContext):
        self.ctx = ctx
        self.findings: List[Finding] = []

    def report(self, node: ast.AST, message: str):
        self.findings.append(Finding(
            self.rule, self.ctx.relpath,
            getattr(node, "lineno", 0), getattr(node, "col_offset", 0),
            message))

    def run(self) -> List[Finding]:
        self.visit(self.ctx.tree)
        return self.findings


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ------------------------------------------------------------- running
def lint_source(source: str, relpath: str,
                checkers: Iterable[type]) -> List[Finding]:
    """Lint one file's source; returns unsuppressed findings."""
    ctx = FileContext(relpath, source)
    out: List[Finding] = []
    for cls in checkers:
        for f in cls(ctx).run():
            if not ctx.suppressed(f.rule, f.line):
                out.append(f)
    out.sort(key=lambda f: (f.path, f.line, f.rule))
    return out


def iter_py_files(paths: Sequence[str], root: str) -> List[str]:
    """Expand files/directories into .py files (absolute paths)."""
    out: List[str] = []
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if not d.startswith(".") and d != "__pycache__")
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.append(os.path.join(dirpath, fn))
    return out


def lint_paths(paths: Sequence[str], root: str,
               checkers: Iterable[type]) -> List[Finding]:
    findings: List[Finding] = []
    for ap in iter_py_files(paths, root):
        rel = os.path.relpath(ap, root)
        with open(ap, encoding="utf-8") as f:
            src = f.read()
        try:
            findings.extend(lint_source(src, rel, checkers))
        except SyntaxError as e:
            findings.append(Finding("E0", rel.replace(os.sep, "/"),
                                    e.lineno or 0, e.offset or 0,
                                    f"syntax error: {e.msg}"))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ------------------------------------------------------------ baseline
def load_baseline(path: str) -> List[str]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return list(data.get("findings", []))


def save_baseline(path: str, findings: Sequence[Finding]):
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"findings": sorted({x.key for x in findings})},
                  f, indent=1)
        f.write("\n")


def split_by_baseline(findings: Sequence[Finding],
                      baseline: Sequence[str]):
    """(new, accepted, stale) — findings not in the baseline, findings
    covered by it, and baseline keys no longer observed."""
    base = set(baseline)
    new = [f for f in findings if f.key not in base]
    accepted = [f for f in findings if f.key in base]
    seen = {f.key for f in findings}
    stale = sorted(base - seen)
    return new, accepted, stale
