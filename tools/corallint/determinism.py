"""D1 — determinism: the simulator/control/core/solver layers must be
bit-reproducible from their seeds (every lossless/bit-identical claim
in CHANGES.md rests on it).  Inside ``src/repro/{simulator,control,
core,solver}`` this flags:

* ambient-entropy calls: module-level ``random.*`` / ``np.random.*``
  RNG functions, argless ``default_rng()`` / ``random.Random()`` /
  ``np.random.RandomState()``, wall-clock reads (``time.time``,
  ``time.monotonic``, ``time.perf_counter``, ``datetime.now`` ...);
* iteration over ``set`` values — hash-order-dependent for strings
  under PYTHONHASHSEED, so float accumulation or any order-sensitive
  consumption over a set varies across runs (iterate ``sorted(...)``);
* ``dict.values()/.items()/.keys()`` loops whose body feeds an
  order-sensitive sink (heap pushes, solver row/var assembly, router
  calls) — insertion order is deterministic only when every inserter
  is, so these sites deserve an explicit ordering.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .base import Checker, dotted_name

D1_DIRS = ("src/repro/simulator/", "src/repro/control/",
           "src/repro/core/", "src/repro/solver/")

_RANDOM_MODULE_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "expovariate",
    "betavariate", "triangular", "seed", "getrandbits", "vonmisesvariate",
}
_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
}
_DATETIME_CALLS = {
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}
_ORDER_SINKS = {"push", "heappush", "heappop", "heapify", "add_var",
                "add_constr", "add_vars", "add_constrs_coo", "route"}


def _is_setlike(node: ast.AST,
                assigns: Optional[Dict[str, List[ast.AST]]] = None,
                depth: int = 0) -> bool:
    """Statically set-typed: literals, set()/frozenset() calls, set
    unions/intersections/differences, set-method chains, and names with
    a single visible set-typed assignment in the enclosing scopes."""
    if depth > 4:
        return False
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("set", "frozenset"):
            return True
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("union", "intersection",
                                       "difference",
                                       "symmetric_difference") \
                and _is_setlike(node.func.value, assigns, depth + 1):
            return True
        return False
    if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
        return _is_setlike(node.left, assigns, depth + 1) \
            or _is_setlike(node.right, assigns, depth + 1)
    if isinstance(node, ast.Name) and assigns is not None:
        vals = assigns.get(node.id)
        if vals is not None and len(vals) == 1:
            return _is_setlike(vals[0], assigns, depth + 1)
    return False


def _collect_assigns(scope: ast.AST) -> Dict[str, List[ast.AST]]:
    """name -> assigned value nodes within ``scope``, not descending
    into nested function/class scopes."""
    out: Dict[str, List[ast.AST]] = {}

    def walk(node: ast.AST):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            if isinstance(child, ast.Assign):
                for tgt in child.targets:
                    if isinstance(tgt, ast.Name):
                        out.setdefault(tgt.id, []).append(child.value)
            elif isinstance(child, ast.AnnAssign) and child.value is not None:
                if isinstance(child.target, ast.Name):
                    out.setdefault(child.target.id, []).append(child.value)
            elif isinstance(child, (ast.AugAssign, ast.For)):
                # reassignment makes single-assignment tracking unsafe
                tgt = child.target
                if isinstance(tgt, ast.Name):
                    out.setdefault(tgt.id, []).append(ast.Constant(None))
            walk(child)

    walk(scope)
    return out


class DeterminismChecker(Checker):
    rule = "D1"
    description = "unseeded entropy / hash-order iteration in " \
                  "determinism-critical layers"

    def __init__(self, ctx):
        super().__init__(ctx)
        self._scopes: List[Dict[str, List[ast.AST]]] = []
        self.enabled = any(ctx.relpath.startswith(d) for d in D1_DIRS)

    def run(self):
        if not self.enabled:
            return self.findings
        self._scopes.append(_collect_assigns(self.ctx.tree))
        self.visit(self.ctx.tree)
        return self.findings

    # ------------------------------------------------------- scoping
    def _with_scope(self, node):
        self._scopes.append(_collect_assigns(node))
        self.generic_visit(node)
        self._scopes.pop()

    visit_FunctionDef = _with_scope
    visit_AsyncFunctionDef = _with_scope

    def _lookup(self) -> Dict[str, List[ast.AST]]:
        merged: Dict[str, List[ast.AST]] = {}
        for sc in self._scopes:
            merged.update(sc)
        return merged

    # --------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call):
        name = dotted_name(node.func)
        if name:
            self._check_entropy(node, name)
        self.generic_visit(node)

    def _check_entropy(self, node: ast.Call, name: str):
        parts = name.split(".")
        head = parts[0]
        if name in _CLOCK_CALLS:
            self.report(node, f"wall-clock read {name}() in a "
                              "determinism-critical layer")
            return
        if name in _DATETIME_CALLS or \
                (len(parts) >= 2 and parts[-1] in ("now", "utcnow")
                 and parts[-2] == "datetime"):
            self.report(node, f"wall-clock read {name}() in a "
                              "determinism-critical layer")
            return
        if head in ("np", "numpy") and len(parts) >= 3 \
                and parts[1] == "random":
            tail = parts[2]
            if tail in ("default_rng", "RandomState", "Generator"):
                if not node.args and not node.keywords:
                    self.report(node, f"argless {name}() seeds from OS "
                                      "entropy; pass an explicit seed")
            else:
                self.report(node, f"global numpy RNG {name}() — use a "
                                  "seeded np.random.Generator stream")
            return
        if head == "random" and len(parts) == 2:
            tail = parts[1]
            if tail in ("Random", "SystemRandom"):
                if tail == "SystemRandom" or not node.args:
                    self.report(node, f"unseeded {name}() — pass an "
                                      "explicit seed")
            elif tail in _RANDOM_MODULE_FNS:
                self.report(node, f"module-level {name}() uses the "
                                  "shared global RNG — use a seeded "
                                  "random.Random instance")
            return
        if name == "default_rng" and not node.args and not node.keywords:
            self.report(node, "argless default_rng() seeds from OS "
                              "entropy; pass an explicit seed")

    # ----------------------------------------------------- iteration
    def visit_For(self, node: ast.For):
        self._check_iter(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node):
        for gen in node.generators:
            self._check_iter(gen.iter, node)
        self.generic_visit(node)

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def _check_iter(self, it: ast.AST, node: ast.AST):
        if _is_setlike(it, self._lookup()):
            self.report(node, "iteration over a set is hash-order-"
                              "dependent; iterate sorted(...) instead")
            return
        if isinstance(node, ast.For) and isinstance(it, ast.Call) \
                and isinstance(it.func, ast.Attribute) \
                and it.func.attr in ("values", "items", "keys") \
                and not it.args:
            sink = self._body_sink(node)
            if sink:
                self.report(
                    node, f"dict .{it.func.attr}() loop feeds "
                          f"order-sensitive sink {sink}(); iterate a "
                          "sorted or explicitly-ordered view")

    @staticmethod
    def _body_sink(node: ast.For) -> str:
        for stmt in node.body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    fn = sub.func
                    nm = fn.attr if isinstance(fn, ast.Attribute) \
                        else fn.id if isinstance(fn, ast.Name) else None
                    if nm in _ORDER_SINKS:
                        return nm
        return ""
