"""P1 — general hygiene: mutable default arguments (shared across
calls) and mutable dataclass field defaults (``= []`` raises at class
creation for list/dict/set, but mutable *constructor* defaults like
``= deque()`` slip through and are shared across instances — use
``field(default_factory=...)``).
"""
from __future__ import annotations

import ast

from .base import Checker, dotted_name

MUTABLE_CTORS = {"list", "dict", "set", "deque", "defaultdict",
                 "OrderedDict", "Counter", "bytearray"}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return True
    if isinstance(node, ast.Call):
        name = dotted_name(node.func)
        if name and name.split(".")[-1] in MUTABLE_CTORS:
            return True
    return False


def _is_dataclass(node: ast.ClassDef) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name and name.split(".")[-1] == "dataclass":
            return True
    return False


class HygieneChecker(Checker):
    rule = "P1"
    description = "mutable default argument / mutable dataclass field " \
                  "default"

    def _visit_func(self, node):
        args = node.args
        for default in list(args.defaults) + [
                d for d in args.kw_defaults if d is not None]:
            if _is_mutable_literal(default):
                self.report(default, "mutable default argument is "
                                     "shared across calls — default to "
                                     "None and construct inside")
        self.generic_visit(node)

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func
    visit_Lambda = _visit_func

    def visit_ClassDef(self, node: ast.ClassDef):
        if _is_dataclass(node):
            for item in node.body:
                value = None
                if isinstance(item, ast.AnnAssign):
                    value = item.value
                elif isinstance(item, ast.Assign):
                    value = item.value
                if value is not None and _is_mutable_literal(value):
                    self.report(value, "mutable dataclass field default "
                                       "is shared across instances — "
                                       "use field(default_factory=...)")
        self.generic_visit(node)
