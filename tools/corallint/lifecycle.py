"""L1 — instance lifecycle: the init -> ready -> draining -> dead state
machine hardened in the fault-injection PR is only sound when state
fields are written through the sanctioned ``Simulator`` transition
methods (``drain_instance`` / ``kill_instance`` / ``crash_instance`` /
``degrade_instance`` and their internal completions), which settle the
batched accounting and re-route work atomically with the flag flip.  A
bare ``inst.dead = True`` anywhere else silently corrupts routing pools
and token conservation.

Allowed writes: ``self.<field> = ...`` inside any ``__init__`` (initial
state), and writes inside the sanctioned methods of
``simulator/sim.py``.
"""
from __future__ import annotations

import ast
from typing import List

from .base import Checker

STATE_FIELDS = {"state", "dead", "draining", "failed"}
SANCTIONED = {"drain_instance", "kill_instance", "crash_instance",
              "degrade_instance", "_restore_speed", "_after_decode_iter"}


class LifecycleChecker(Checker):
    rule = "L1"
    description = "direct instance state-field write outside the " \
                  "sanctioned sim.py transition methods"

    def __init__(self, ctx):
        super().__init__(ctx)
        self._funcs: List[str] = []
        self._in_sim = ctx.relpath.endswith("simulator/sim.py")

    def _visit_func(self, node):
        self._funcs.append(node.name)
        self.generic_visit(node)
        self._funcs.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _check_target(self, tgt: ast.AST):
        if not (isinstance(tgt, ast.Attribute)
                and tgt.attr in STATE_FIELDS):
            return
        fn = self._funcs[-1] if self._funcs else ""
        if fn == "__init__" and isinstance(tgt.value, ast.Name) \
                and tgt.value.id == "self":
            return                          # initial state
        if self._in_sim and fn in SANCTIONED:
            return                          # sanctioned transition
        self.report(tgt, f"direct write to .{tgt.attr} outside the "
                         "sanctioned lifecycle transitions (use "
                         "drain/kill/crash/degrade_instance)")

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            if isinstance(tgt, ast.Tuple):
                for el in tgt.elts:
                    self._check_target(el)
            else:
                self._check_target(tgt)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        self._check_target(node.target)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._check_target(node.target)
        self.generic_visit(node)
