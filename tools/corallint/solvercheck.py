"""S1 — solver misuse: the columnar allocator PR made the epoch loop's
model assembly batched (``add_vars`` + ``add_constrs_coo`` over COO
triplets); per-variable ``add_var``/``add_constr`` calls inside loops
re-introduce the O(n) python-level assembly that PR measured at ~35x
slower, so they are banned on epoch-loop call paths (the reference
oracle ``allocate_reference`` keeps them under an inline suppression).

Also flags COO triplet calls whose (data, rows, cols) arguments are
literals of statically-unequal lengths — a shape mismatch the solver
would only surface at runtime as a scipy broadcast error.

The decomposition PR added a third check: on epoch-loop paths every
``MilpModel(...).solve(...)`` call must pass an explicit ``time_limit``
keyword — an unbounded solve inside the online loop stalls the whole
epoch cadence, and the three-tier escalation ladder relies on each
tier respecting its slice of the deadline. Names bound via
``name = MilpModel(...)`` are tracked per file so ``name.solve()`` is
caught too, not just direct chaining.
"""
from __future__ import annotations

import ast

from .base import Checker

PER_VAR_API = {"add_var", "add_constr"}

# epoch-loop call paths: the online allocator and everything above it.
# The offline placement solver, the milp wrapper's own internals, and
# solver unit tests legitimately exercise the per-variable API.
S1_DIRS = ("src/repro/core/allocator.py", "src/repro/runtime/",
           "src/repro/control/", "src/repro/solver/decompose.py")


class SolverChecker(Checker):
    rule = "S1"
    description = "per-variable solver API in a loop / static COO " \
                  "triplet shape mismatch"

    def __init__(self, ctx):
        super().__init__(ctx)
        self._loop_depth = 0
        self._per_var_scope = any(ctx.relpath.startswith(d)
                                  for d in S1_DIRS)
        self._milp_names = set()    # names bound via `x = MilpModel(...)`

    @staticmethod
    def _is_milp_ctor(node) -> bool:
        if not isinstance(node, ast.Call):
            return False
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) \
            else fn.id if isinstance(fn, ast.Name) else None
        return name == "MilpModel"

    def visit_Assign(self, node: ast.Assign):
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                if self._is_milp_ctor(node.value):
                    self._milp_names.add(tgt.id)
                else:
                    self._milp_names.discard(tgt.id)    # rebound
        self.generic_visit(node)

    def _visit_loop(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop

    def _visit_comp(self, node):
        self._loop_depth += 1
        self.generic_visit(node)
        self._loop_depth -= 1

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    def visit_Call(self, node: ast.Call):
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) \
            else fn.id if isinstance(fn, ast.Name) else None
        if name in PER_VAR_API and self._loop_depth > 0 \
                and self._per_var_scope:
            self.report(node, f"per-variable {name}() inside a loop — "
                              "use the batched add_vars/"
                              "add_constrs_coo (COO) API on epoch-loop "
                              "paths")
        if name == "add_constrs_coo":
            self._check_coo(node)
        if name == "solve" and self._per_var_scope \
                and isinstance(fn, ast.Attribute) \
                and self._is_milp_target(fn.value) \
                and not any(kw.arg == "time_limit"
                            for kw in node.keywords):
            self.report(node, "MilpModel.solve() without time_limit on "
                              "an epoch-loop path — an unbounded solve "
                              "stalls the online re-solve cadence")
        self.generic_visit(node)

    def _is_milp_target(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self._milp_names
        return self._is_milp_ctor(node)     # MilpModel(...).solve(...)

    def _check_coo(self, node: ast.Call):
        lens = []
        for arg in node.args[:3]:
            if isinstance(arg, (ast.List, ast.Tuple)) \
                    and not any(isinstance(e, ast.Starred)
                                for e in arg.elts):
                lens.append(len(arg.elts))
            else:
                return                  # dynamic: not statically checkable
        if len(lens) == 3 and len(set(lens)) > 1:
            self.report(node, "COO triplet shape mismatch: "
                              f"len(data)={lens[0]}, len(rows)={lens[1]}, "
                              f"len(cols)={lens[2]} must be equal")
