"""Decomposition-equivalence smoke (CI leg; see tools/README.md).

Proves the three-tier online solve ladder (price-coordinated per-model
decomposition -> LP-relax + greedy rounding -> monolithic MIP; see
``repro/solver/decompose.py`` and ``AllocatorState.solve``) lands on
the monolithic optimum on both solver backends:

* scipy/HiGHS, core scale — ``solve_mode="auto"`` vs forced
  ``"monolithic"`` over a cold + warm epoch pair on the core
  (12-config / 3-model) universe, identical inputs, objective parity
  within the combined certification gaps.
* numpy branch-and-bound — the same ladder on a var-capped instance
  (``max_templates_per_demand`` trims the template sets) with
  ``repro.solver.milp.HAVE_SCIPY`` forced off, so every escalation
  solve runs the pure-numpy backend.  The decomposed tier itself is
  scipy-free either way.

Usage (from the repo root):
    PYTHONPATH=src python tools/decompose_smoke.py
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import cached_library, make_avail  # noqa: E402
from benchmarks.common import make_demands, scenario  # noqa: E402
from repro.core.allocator import AllocProblem, AllocatorState  # noqa: E402
from repro.solver import milp as _milp  # noqa: E402

# auto certifies within ACCEPT_GAP=5e-4 of a lower bound, monolithic
# solves to MIP_GAP=1e-4: the two can legitimately differ by the sum
PARITY_TOL = 2e-3


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-9)


def _epoch_pair(regions, configs, avail, demands, lib, mode, **kw):
    """Cold + warm solve with a shared ``current`` trajectory."""
    st = AllocatorState()
    cold = st(AllocProblem(regions, configs, dict(avail[0]), demands,
                           lib, time_limit=120.0, solve_mode=mode, **kw))
    assert cold.ok, f"{mode} cold solve failed"
    warm = st(AllocProblem(regions, configs, dict(avail[1]), demands,
                           lib, current=dict(cold.instances),
                           time_limit=120.0, solve_mode=mode, **kw))
    assert warm.ok, f"{mode} warm solve failed"
    return cold, warm


def _leg(tag, regions, configs, avail, demands, lib, **kw):
    t0 = time.time()
    mono = _epoch_pair(regions, configs, avail, demands, lib,
                       "monolithic", **kw)
    auto = _epoch_pair(regions, configs, avail, demands, lib,
                       "auto", **kw)
    rel = max(_rel(a.objective, m.objective)
              for a, m in zip(auto, mono))
    paths = [a.solve_path for a in auto]
    print(f"decompose_smoke: {tag:12s} rel diff {rel:.2e} "
          f"paths {'/'.join(paths)} auto "
          f"{sum(a.solve_seconds for a in auto)*1e3:.0f}ms vs mono "
          f"{sum(m.solve_seconds for m in mono)*1e3:.0f}ms "
          f"({time.time() - t0:.1f}s)")
    assert rel <= PARITY_TOL, \
        f"{tag}: auto diverged from monolithic by {rel:.2e}"
    return paths


def main() -> int:
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    demands = make_demands(models, wls, 10.0)
    avail = make_avail(regions, configs, 2, 40, seed=0)
    paths = _leg("scipy/core", regions, configs, avail, demands, lib)

    # numpy branch-and-bound: trim the template sets so the ~50-var
    # escalation model stays inside the pure-python solver's reach,
    # and force the backend by hiding scipy from the milp wrapper
    tight = make_avail(regions, configs, 2, 3, seed=1)
    small = make_demands(models, wls, 0.5)
    have_scipy = _milp.HAVE_SCIPY
    _milp.HAVE_SCIPY = False
    try:
        paths += _leg("numpy/tiny", regions, configs, tight, small, lib,
                      max_templates_per_demand=2)
    finally:
        _milp.HAVE_SCIPY = have_scipy

    assert "decomposed" in paths, \
        f"the decomposed tier never certified: paths {paths}"
    print("decompose_smoke: ladder at parity on both backends")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
