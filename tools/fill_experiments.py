"""Inject benchmark/dry-run/roofline tables into EXPERIMENTS.md.

Usage: PYTHONPATH=src:. python tools/fill_experiments.py
Replaces the <!-- BENCH_RESULTS -->, <!-- DRYRUN_TABLE -->,
<!-- ROOFLINE_TABLE --> markers (idempotent: regenerates between marker
and the next section header).
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")


def bench_table() -> str:
    path = os.path.join(ART, "bench_results.csv")
    if not os.path.exists(path):
        return "(benchmarks not yet run)\n"
    out = ["| benchmark | wall (s) | result |", "|---|---|---|"]
    with open(path) as f:
        next(f)
        for line in f:
            name, us, derived = line.strip().split(",", 2)
            out.append(f"| {name} | {float(us)/1e6:.1f} | `{derived}` |")
    return "\n".join(out) + "\n"


def dryrun_table() -> str:
    rows = []
    for p in sorted(glob.glob(os.path.join(ART, "dryrun", "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    if not rows:
        return "(dry-run not yet executed)\n"
    out = ["| arch | shape | mesh | status | compile (s) | per-dev FLOPs "
           "(corrected) | collective GB (corrected) | peak mem GB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "__" in r["cell"] and r["cell"].count("__") >= 3:
            continue            # tagged perf-iteration artifacts
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skip | — | — | — | — |")
            continue
        mem = r.get("memory", {})
        peak = mem.get("peak_memory_in_bytes", 0) / 1e9
        fl = r.get("flops_corrected", r.get("flops_total", 0))
        cb = r.get("collective_bytes_corrected_total",
                   r.get("collective_bytes_total", 0)) / 1e9
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                   f"{r['seconds_compile']:.0f} | {fl:.3g} | {cb:.2f} | "
                   f"{peak:.1f} |")
    return "\n".join(out) + "\n"


def roofline_table() -> str:
    try:
        from benchmarks import roofline as rl
    except Exception as e:          # noqa: BLE001
        return f"(roofline import failed: {e})\n"
    out = []
    for mesh in ("16x16",):
        recs = rl.load_all(mesh)
        out.append(f"**{mesh} mesh** (roofline table is single-pod per "
                   "the assignment; the multi-pod pass proves the pod "
                   "axis shards — see §Dry-run)\n")
        out.append("| arch | shape | compute (s) | memory (s) | "
                   "collective (s) | dominant | MODEL/HLO flops | "
                   "roofline frac | next lever |")
        out.append("|---|---|---|---|---|---|---|---|---|")
        for rec in recs:
            if rec["status"] == "skipped":
                out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — "
                           f"| skip | — | — | {rec['reason'][:60]} |")
                continue
            a = rl.analyse_cell(rec)
            lever = {
                "compute": "reduce recompute/dispatch FLOPs, MXU-align",
                "memory": "fuse/remat policy, shrink op-level traffic",
                "collective": "reshard to cut all-gathers, overlap",
            }[a["dominant"]]
            out.append(
                f"| {a['arch']} | {a['shape']} | {a['t_compute']:.4f} | "
                f"{a['t_memory']:.4f} | {a['t_collective']:.4f} | "
                f"{a['dominant']} | {a['useful_ratio']:.2f} | "
                f"{100*a['roofline_fraction']:.1f}% | {lever} |")
        out.append("")
    return "\n".join(out) + "\n"


MARKERS = {
    "<!-- BENCH_RESULTS -->": bench_table,
    "<!-- DRYRUN_TABLE -->": dryrun_table,
    "<!-- ROOFLINE_TABLE -->": roofline_table,
}


def main():
    with open(EXP) as f:
        text = f.read()
    for marker, fn in MARKERS.items():
        if marker not in text:
            continue
        start = text.index(marker) + len(marker)
        nxt = text.find("\n## ", start)
        end = nxt if nxt >= 0 else len(text)
        text = text[:start] + "\n\n" + fn() + text[end:]
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
