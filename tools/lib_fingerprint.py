"""Print a stable digest of every template-library generation input.

The benchmark suite and the test suite cache their Serving-Template
libraries under ``artifacts/lib_*.pkl``; each cached (model, phase)
pair is guarded by its ``generation_fingerprint`` (config universe,
n_max, rho, SLO, workload, solver — and ``GENERATION_VERSION``, bumped
whenever the produced set changes for identical inputs).  This tool
hashes the fingerprints of every library the suites use, giving CI a
cache key for the ``artifacts`` directory: the key drifts exactly when
some library would be regenerated, so a cache hit means no rebuild.

Usage:  PYTHONPATH=src python tools/lib_fingerprint.py
"""
from __future__ import annotations

import hashlib
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)                  # for the benchmarks package

from repro.core.hardware import make_node_configs                # noqa: E402
from repro.core.modelspec import PAPER_MODELS                    # noqa: E402
from repro.core.templates import generation_fingerprint          # noqa: E402
from repro.traces.workloads import workload_stats                # noqa: E402


def _pairs():
    """(models, configs, n_max, rho) of every cached library in use."""
    # benchmark scenarios (benchmarks/common.py: N_MAX/RHO paper
    # defaults; allocator_bench pins the ext library at n_max=4)
    from benchmarks.common import N_MAX, RHO, scenario
    from benchmarks.allocator_bench import EXT_N_MAX
    core_models, core_cfgs, _, core_wls = scenario(extended=False)
    ext_models, ext_cfgs, _, ext_wls = scenario(extended=True)
    yield list(core_models.values()), core_cfgs, core_wls, N_MAX, RHO
    yield list(ext_models.values()), ext_cfgs, ext_wls, N_MAX, RHO
    yield list(ext_models.values()), ext_cfgs, ext_wls, EXT_N_MAX, RHO
    # test-suite libraries (tests/_libcache.py callers)
    test_models = [PAPER_MODELS[m] for m in ("phi4-14b", "gpt-oss-20b")]
    test_cfgs = make_node_configs(["L40S", "L4", "A10G"], sizes=(1, 2))
    test_wls = {m.name: workload_stats(m.trace) for m in test_models}
    yield test_models, test_cfgs, test_wls, 3, 8.0


def digest() -> str:
    h = hashlib.sha256()
    for models, configs, wls, n_max, rho in _pairs():
        for m in models:
            for phase in ("prefill", "decode"):
                fp = generation_fingerprint(m, phase, configs, wls[m.name],
                                            n_max, rho, True, "fast", None)
                h.update(repr(fp).encode())
                # homo libraries fingerprint per-config sub-universes
                for c in sorted(configs, key=lambda c: c.name):
                    fp = generation_fingerprint(m, phase, [c], wls[m.name],
                                                n_max, rho, True, "fast",
                                                None)
                    h.update(repr(fp).encode())
    return h.hexdigest()


if __name__ == "__main__":
    print(digest())
