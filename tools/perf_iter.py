"""Perf-iteration harness: re-lower one (arch, shape) cell with a tagged
variant (config overrides and/or code changes) and print the roofline
terms next to the baseline.

Usage:
  PYTHONPATH=src:. python tools/perf_iter.py --arch granite-moe-3b-a800m \
      --shape train_4k --tag sorted --override moe_impl=sorted
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (value eval'd)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = eval(v)          # noqa: S307 (trusted local tool)
        except Exception:        # noqa: BLE001
            pass
        overrides[k] = v

    from repro.launch import dryrun
    rec = dryrun.run_cell(args.arch, args.shape,
                          multi_pod=(args.mesh == "multi"),
                          arch_overrides=overrides or None, tag=args.tag)
    # attach correction
    mesh_name = "2x16x16" if args.mesh == "multi" else "16x16"
    cell = f"{args.arch}__{args.shape}__{mesh_name}__{args.tag}"
    path = os.path.join(dryrun.ARTIFACT_DIR, cell + ".json")

    from benchmarks import roofline as rl
    base_path = os.path.join(dryrun.ARTIFACT_DIR,
                             f"{args.arch}__{args.shape}__{mesh_name}.json")
    with open(base_path) as f:
        base = rl.analyse_cell(json.load(f))
    with open(path) as f:
        var_rec = json.load(f)
    var = rl.analyse_cell(var_rec)

    print(f"\n{'':14s} {'compute':>10} {'memory':>10} {'collective':>11} "
          f"{'dominant':>9} {'roofl%':>7}")
    for name, a in (("baseline", base), (args.tag, var)):
        print(f"{name:14s} {a['t_compute']:10.4f} {a['t_memory']:10.4f} "
              f"{a['t_collective']:11.4f} {a['dominant']:>9} "
              f"{100*a['roofline_fraction']:7.1f}")
    for term in ("t_compute", "t_memory", "t_collective"):
        if base[term] > 0:
            print(f"  {term}: {base[term]/max(var[term],1e-12):.2f}x better"
                  if var[term] < base[term] else
                  f"  {term}: {var[term]/max(base[term],1e-12):.2f}x WORSE")


if __name__ == "__main__":
    main()
