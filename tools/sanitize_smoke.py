"""CORAL_SANITIZE=1 equivalence smoke (CI leg; see tools/README.md).

Runs the five control scenarios plus the crash_storm fault scenario
through ``ClusterRuntime`` twice — span-batched simulator vs the
per-iteration oracle (``sim_batched=False``) — with the runtime
invariant sanitizer (repro.debug.invariants) armed, and requires the
two runs to agree *bit-identically*: per-epoch goodput/throughput/cost
and the simulator's finished/dropped/shed accounting.

This is the PR's acceptance harness: the sanitizer audits conservation
laws at every epoch edge while the batched/oracle comparison proves the
span machinery still reproduces the reference loop exactly, fault
injection included.

Usage (from the repo root):
    CORAL_SANITIZE=1 PYTHONPATH=src python tools/sanitize_smoke.py
The flag is forced on if absent, so a bare invocation also works.
"""
from __future__ import annotations

import os
import sys
import time

os.environ.setdefault("CORAL_SANITIZE", "1")

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import cached_library, scenario  # noqa: E402
from repro.control import (FaultInjector, RestartPolicy,  # noqa: E402
                           SCENARIO_NAMES, make_scenario)
from repro.core.allocator import AllocatorState  # noqa: E402
from repro.debug import invariants as _inv  # noqa: E402
from repro.runtime.cluster import ClusterRuntime  # noqa: E402
from repro.simulator.sim import ShedPolicy  # noqa: E402

N_EPOCHS = 8
EPOCH_S = 240.0
BASE_RATE = 2.0
SEED = 2
SMOKE_NAMES = SCENARIO_NAMES + ("crash_storm",)


def _one_run(name, batched, models, regions, configs, wls, lib):
    # regenerate the scenario per run: the simulator mutates Request
    # objects in place, so the two disciplines must not share a trace
    sc = make_scenario(name, models, regions, configs, wls,
                       n_epochs=N_EPOCHS, epoch_s=EPOCH_S,
                       base_rate=BASE_RATE, seed=SEED)
    kw = {}
    inj = None
    if sc.faults is not None:
        inj = FaultInjector(sc.faults)
        kw = dict(health_check_s=15.0,
                  restart_policy=RestartPolicy(backoff_base_s=20.0,
                                               budget_per_epoch=4))
    rt = ClusterRuntime(models, regions, configs, lib, AllocatorState(),
                        wls, epoch_s=sc.epoch_s, sim_batched=batched,
                        spot_market=sc.spot_market,
                        shed_policy=ShedPolicy(), **kw)
    res = rt.run(sc.requests, sc.availability, sc.truth_demands,
                 fault_injector=inj)
    sim = rt.sim
    return {
        "epochs": [(e.epoch, e.cost_per_hour, tuple(sorted(
            e.goodput.items())), tuple(sorted(e.throughput.items())),
            e.n_instances, e.n_new, e.n_drained, e.n_preempted,
            e.n_failed, e.n_restarted, e.n_shed, e.alloc_source,
            e.solve_path)
            for e in res.epochs],
        "finished": sorted((r.rid, r.decode_tokens_ok, r.decode_slo_ok)
                           for r in sim.finished),
        "dropped": dict(sim.dropped_by_model),
        "shed": dict(sim.shed_by_model),
        "tokens": {m: sim.tokens[m]._total for m in sorted(sim.tokens)},
    }


def main() -> int:
    if not _inv.sanitize_enabled():
        print("sanitize_smoke: CORAL_SANITIZE is off?!")
        return 2
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    failures = []
    paths = set()
    for name in SMOKE_NAMES:
        t0 = time.time()
        batched = _one_run(name, True, models, regions, configs, wls, lib)
        oracle = _one_run(name, False, models, regions, configs, wls, lib)
        paths.update(e[-1] for e in batched["epochs"])
        ok = batched == oracle
        print(f"sanitize_smoke: {name:18s} "
              f"{'bit-identical' if ok else 'MISMATCH'} "
              f"({time.time() - t0:.1f}s)")
        if not ok:
            failures.append(name)
            for k in batched:
                if batched[k] != oracle[k]:
                    print(f"  field {k!r} differs")
    if failures:
        print(f"sanitize_smoke: FAILED for {failures}")
        return 1
    # the three-tier solve ladder must have answered at least one epoch
    # via the decomposed fast path with the sanitizer armed — the
    # per-epoch check_allocation audit then covers its solutions too
    if "decomposed" not in paths:
        print(f"sanitize_smoke: decomposed tier never ran (paths seen: "
              f"{sorted(paths)})")
        return 1
    print(f"sanitize_smoke: {len(SMOKE_NAMES)} scenarios bit-identical "
          f"(batched vs oracle) under CORAL_SANITIZE=1; solve paths "
          f"{sorted(p for p in paths if p)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
