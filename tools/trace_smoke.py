"""Trace smoke: run a short crash_storm with the control-plane
``TraceLog`` attached, write ``artifacts/trace_smoke_crash_storm.jsonl``
and audit it end to end:

  1. every record round-trips through the JSONL reader and passes the
     full TRACE_SCHEMA validation;
  2. the causal-ordering audit is clean (detect after inject, restart
     after detect, epoch-edge records in non-decreasing epoch order);
  3. trace counts agree with the runtime's own metrics (solve spans ==
     resolves, detects == failures, preempts == preemptions, ...);
  4. every epoch's ``EpochMetrics.slo`` block carries the per-model
     TTFT/TBT summary fields.

Run from repo root:  PYTHONPATH=src python tools/trace_smoke.py
Wired into tools/ci.sh as the trace-schema leg.
"""
from __future__ import annotations

import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from benchmarks.common import cached_library, scenario  # noqa: E402
from repro.control import (FaultInjector, RestartPolicy,  # noqa: E402
                           make_scenario)
from repro.obs import TraceLog  # noqa: E402
from repro.core.allocator import AllocatorState  # noqa: E402
from repro.runtime.cluster import ClusterRuntime  # noqa: E402
from repro.simulator.sim import ShedPolicy  # noqa: E402
from tools.trace_tools import (assert_causal, read_trace,  # noqa: E402
                               summarize)

N_EPOCHS = 8
EPOCH_S = 240.0
BASE_RATE = 2.0
SEED = 2

_SLO_KEYS = ("ttft_p50", "ttft_p95", "ttft_p99", "tbt_p50", "tbt_p95",
             "tbt_p99", "ttft_attain", "tbt_attain")


def main() -> int:
    t_start = time.time()
    models, configs, regions, wls = scenario(extended=False)
    lib = cached_library("core", models, configs, wls)
    sc = make_scenario("crash_storm", models, regions, configs, wls,
                       n_epochs=N_EPOCHS, epoch_s=EPOCH_S,
                       base_rate=BASE_RATE, seed=SEED)
    assert sc.faults is not None
    trace = TraceLog()
    rt = ClusterRuntime(
        models, regions, configs, lib, AllocatorState(), wls,
        epoch_s=sc.epoch_s, sim_batched=True,
        spot_market=sc.spot_market, shed_policy=ShedPolicy(),
        health_check_s=15.0,
        restart_policy=RestartPolicy(backoff_base_s=20.0,
                                     budget_per_epoch=4),
        trace=trace)
    res = rt.run(sc.requests, sc.availability, sc.truth_demands,
                 fault_injector=FaultInjector(sc.faults))

    out_dir = os.path.join(_ROOT, "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "trace_smoke_crash_storm.jsonl")
    n_written = trace.write(path)

    # 1. read back through the schema-validating reader
    records = read_trace(path)
    assert len(records) == n_written, \
        f"round-trip lost records: wrote {n_written}, read {len(records)}"
    summ = summarize(records)
    for kind in ("trigger", "solve", "reconcile", "fault_inject",
                 "fault_detect", "restart"):
        assert summ["kinds"].get(kind, 0) > 0, \
            f"expected at least one {kind!r} record, got none"

    # 2. causal ordering
    violations = assert_causal(records)
    assert not violations, "causal violations:\n" + "\n".join(violations)

    # 3. trace counts agree with the runtime's own metrics
    n_solves = sum(1 for e in res.epochs if e.resolve_triggered)
    n_failed = sum(e.n_failed for e in res.epochs)
    n_preempt = sum(e.n_preempted for e in res.epochs)
    n_mid = sum(e.n_mid_resolves for e in res.epochs)
    n_started = sum(e.n_restarted for e in res.epochs)
    assert summ["kinds"]["solve"] == n_solves, \
        (summ["kinds"]["solve"], n_solves)
    assert summ["kinds"]["fault_detect"] == n_failed, \
        (summ["kinds"]["fault_detect"], n_failed)
    assert summ["kinds"].get("preempt", 0) == n_preempt, \
        (summ["kinds"].get("preempt", 0), n_preempt)
    assert summ["kinds"].get("mid_resolve", 0) == n_mid, \
        (summ["kinds"].get("mid_resolve", 0), n_mid)
    n_rec_started = sum(1 for r in records
                        if r["kind"] == "restart"
                        and r["outcome"] == "started")
    assert n_rec_started == n_started, (n_rec_started, n_started)
    assert summ["kinds"]["reconcile"] == len(res.epochs), \
        (summ["kinds"]["reconcile"], len(res.epochs))

    # 4. SLO summaries present on every epoch for every model
    for e in res.epochs:
        for name in models:
            blk = e.slo.get(name)
            assert blk is not None, f"epoch {e.epoch}: no slo for {name}"
            for k in _SLO_KEYS:
                assert k in blk, f"epoch {e.epoch} {name}: missing {k}"

    print(f"[trace_smoke] crash_storm: {n_written} records -> {path}")
    print(f"[trace_smoke] kinds: {summ['kinds']}")
    print(f"[trace_smoke] counts OK (solves={n_solves} detects={n_failed}"
          f" preempts={n_preempt} mid={n_mid} restarts={n_started}),"
          f" 0 causal violations, SLO blocks present"
          f" ({time.time() - t_start:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
