"""Schema-validating reader for Coral control-plane traces
(``artifacts/trace_*.jsonl``, written by ``repro.obs.TraceLog``).

Library API: ``read_trace`` (parse + full-schema validation),
``summarize`` (per-kind counts, solve breakdown, trigger reasons,
fault tally), ``diff`` (two traces' summaries side by side) and
``assert_causal`` — the causal-ordering audit:

* every ``fault_detect`` names an instance with a prior (by ``t``)
  ``fault_inject``;
* every ``restart`` follows (by ``t``) a ``fault_detect`` for the
  instance it replaces;
* ``trigger`` / ``solve`` / ``reconcile`` records appear in
  non-decreasing epoch order.

Ordering is judged on the ``t`` *fields*, never on record position:
``fault_inject`` records are emitted when the injector plans an epoch,
so they legitimately appear in the file before records with smaller
timestamps.

CLI:
    PYTHONPATH=src python tools/trace_tools.py summarize FILE
    PYTHONPATH=src python tools/trace_tools.py validate  FILE
    PYTHONPATH=src python tools/trace_tools.py causal    FILE
    PYTHONPATH=src python tools/trace_tools.py diff      FILE_A FILE_B
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.obs.trace import TraceError, validate_record  # noqa: E402

# epoch order must be non-decreasing in *record order* for the
# epoch-edge kinds (planned-future kinds like fault_inject are exempt)
_EPOCH_ORDERED = ("trigger", "solve", "reconcile")


def read_trace(path: str) -> List[dict]:
    """Parse a JSONL trace, validating every record against
    TRACE_SCHEMA; raises ``TraceError`` on the first bad record."""
    records = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceError(f"{path}:{ln}: not JSON ({e})")
            err = validate_record(rec)
            if err is not None:
                raise TraceError(f"{path}:{ln}: {err}")
            records.append(rec)
    return records


def summarize(records: List[dict]) -> Dict:
    """Compact rollup of one trace: per-kind counts, epoch span,
    solve-path/trigger-reason/fault-class tallies, restart outcomes,
    and total/mean solve milliseconds."""
    kinds: Dict[str, int] = {}
    reasons: Dict[str, int] = {}
    paths: Dict[str, int] = {}
    faults: Dict[str, int] = {}
    outcomes: Dict[str, int] = {}
    solve_ms: List[float] = []
    epochs = set()
    for r in records:
        kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
        epochs.add(r["epoch"])
        if r["kind"] == "trigger":
            reasons[r["reason"]] = reasons.get(r["reason"], 0) + 1
        elif r["kind"] == "solve":
            paths[r["path"]] = paths.get(r["path"], 0) + 1
            solve_ms.append(float(r["solve_ms"]))
        elif r["kind"] == "fault_inject":
            faults[r["fault"]] = faults.get(r["fault"], 0) + 1
        elif r["kind"] == "restart":
            outcomes[r["outcome"]] = outcomes.get(r["outcome"], 0) + 1
    return {
        "n_records": len(records),
        "kinds": dict(sorted(kinds.items())),
        "epochs": [min(epochs), max(epochs)] if epochs else [],
        "trigger_reasons": dict(sorted(reasons.items())),
        "solve_paths": dict(sorted(paths.items())),
        "faults": dict(sorted(faults.items())),
        "restart_outcomes": dict(sorted(outcomes.items())),
        "solve_ms_total": sum(solve_ms),
        "solve_ms_mean": sum(solve_ms) / len(solve_ms)
        if solve_ms else 0.0,
    }


def diff(a: List[dict], b: List[dict]) -> Dict:
    """Field-by-field comparison of two traces' summaries (count
    deltas per kind / reason / path / fault class)."""
    sa, sb = summarize(a), summarize(b)
    out: Dict = {}
    for section in ("kinds", "trigger_reasons", "solve_paths", "faults",
                    "restart_outcomes"):
        da, db = sa[section], sb[section]
        delta = {k: db.get(k, 0) - da.get(k, 0)
                 for k in sorted(set(da) | set(db))
                 if db.get(k, 0) != da.get(k, 0)}
        if delta:
            out[section] = delta
    out["n_records"] = [sa["n_records"], sb["n_records"]]
    return out


def assert_causal(records: List[dict]) -> List[str]:
    """Causal-ordering audit; returns violation strings (empty =
    clean).  Compares ``t`` fields, not record positions."""
    errs: List[str] = []
    injects: Dict[int, List[float]] = {}
    detects: Dict[int, List[float]] = {}
    for r in records:
        if r["kind"] == "fault_inject":
            injects.setdefault(r["iid"], []).append(r["t"])
        elif r["kind"] == "fault_detect":
            detects.setdefault(r["iid"], []).append(r["t"])
    eps = 1e-9
    for r in records:
        if r["kind"] == "fault_detect":
            ts = injects.get(r["iid"], [])
            if not any(t <= r["t"] + eps for t in ts):
                errs.append(
                    f"fault_detect for iid={r['iid']} at t={r['t']:.3f}"
                    f" has no prior fault_inject")
        elif r["kind"] == "restart":
            ts = detects.get(r["for_iid"], [])
            if not any(t <= r["t"] + eps for t in ts):
                errs.append(
                    f"restart for iid={r['for_iid']} at t={r['t']:.3f}"
                    f" has no prior fault_detect")
    last_epoch = {k: -1 for k in _EPOCH_ORDERED}
    for i, r in enumerate(records):
        k = r["kind"]
        if k in last_epoch:
            if r["epoch"] < last_epoch[k]:
                errs.append(f"record {i}: {k} epoch {r['epoch']} after "
                            f"epoch {last_epoch[k]}")
            last_epoch[k] = r["epoch"]
    return errs


def main(argv: List[str]) -> int:
    if len(argv) < 2:
        print(__doc__)
        return 2
    cmd, paths = argv[0], argv[1:]
    if cmd == "diff":
        if len(paths) != 2:
            print("diff needs exactly two trace files")
            return 2
        print(json.dumps(diff(read_trace(paths[0]),
                              read_trace(paths[1])), indent=1))
        return 0
    records = read_trace(paths[0])
    if cmd == "validate":
        print(f"{paths[0]}: {len(records)} records, schema OK")
        return 0
    if cmd == "summarize":
        print(json.dumps(summarize(records), indent=1))
        return 0
    if cmd == "causal":
        errs = assert_causal(records)
        for e in errs:
            print(f"VIOLATION: {e}")
        print(f"{paths[0]}: {len(records)} records, "
              f"{len(errs)} causal violations")
        return 1 if errs else 0
    print(f"unknown command {cmd!r}")
    return 2


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
